"""CI lint gate: statically analyze every example / benchmark / NL2WF
workflow and exit nonzero if any produces an ERROR diagnostic.

The corpus (``collect_workflows``) covers each front workflows arrive
from — hand-written unified-API programs (the examples' DAG shapes),
benchmark workloads, SQLFlow translation, and LLM-generated NL2WF
programs — so a lint pass regression that would start rejecting valid
workflows (false positives) fails CI immediately. Warnings are reported
but do not fail the gate.

    PYTHONPATH=src python scripts/lint_workflows.py       # -v for detail

Also callable in-process: ``run_gate()`` returns
``(n_workflows, n_errors, n_warnings)`` (used by scripts/sanity.py).
"""
import sys
from pathlib import Path
from typing import List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from repro.core import couler  # noqa: E402
from repro.core.ir import WorkflowIR  # noqa: E402


def _example_diamond() -> WorkflowIR:
    with couler.workflow("diamond") as ir:
        def job(name):
            return couler.run_container(
                image="docker/whalesay:latest", command=["cowsay"],
                args=[name], step_name=name, fn=lambda n=name: f"[{n}]")
        couler.dag([
            [lambda: job("A")],
            [lambda: job("A"), lambda: job("B")],
            [lambda: job("A"), lambda: job("C")],
            [lambda: job("B"), lambda: job("D")],
            [lambda: job("C"), lambda: job("D")],
        ])
    return ir


def _example_coinflip() -> WorkflowIR:
    state = {"flips": 0}

    def flip_coin():
        state["flips"] += 1
        return "heads" if state["flips"] >= 3 else "tails"

    with couler.workflow("coinflip") as ir:
        r = couler.run_step(flip_coin, step_name="flip")
        couler.exec_while(couler.equal(r, "tails"), lambda: r)
        couler.when(couler.equal(r, "heads"),
                    lambda: couler.run_step(lambda: "it was heads",
                                            step_name="announce"))
    return ir


def _example_automl() -> WorkflowIR:
    # same DAG shape as examples/automl_pipeline.py (hyperparameter
    # dicts stand in for the tune() result — the IR is identical)
    from repro.core.autotune import train_real_model
    ours = {"learning_rate": 3e-4, "batch_size": 32, "weight_decay": 0.01}
    base = {"learning_rate": 1e-4, "batch_size": 64, "weight_decay": 0.0}
    with couler.workflow("automl") as ir:
        outs = couler.concurrent([
            lambda: couler.run_step(train_real_model, ours,
                                    step_name="train-ours", est_time_s=30),
            lambda: couler.run_step(train_real_model, base,
                                    step_name="train-baseline",
                                    est_time_s=30),
        ])
        couler.run_step(
            lambda a, b: a if a["final_loss"] < b["final_loss"] else b,
            outs[0], outs[1], step_name="select")
    return ir


def _example_train_lm() -> WorkflowIR:
    # the examples/train_lm.py chain shape (stub fns; flags preserved)
    with couler.workflow("train-lm") as ir:
        corpus = couler.run_step(lambda: "corpus",
                                 step_name="prepare-corpus", est_time_s=0.5)
        result = couler.run_step(lambda c, n: {"first": 1.0, "last": 0.5},
                                 corpus, 10, step_name="train",
                                 cacheable=False, est_time_s=60.0)
        couler.run_step(lambda r: r["last"] < r["first"], result,
                        step_name="evaluate")
    return ir


def _example_streaming() -> WorkflowIR:
    with couler.workflow("stream-pipeline") as ir:
        cur = couler.run_stream(lambda: iter(range(8)), step_name="p",
                                cacheable=False)
        for k in range(3):
            cur = couler.map_stream(lambda c, _k=k: c + _k, cur,
                                    step_name=f"m{k}", cacheable=False)
    return ir


def _sqlflow_workflows() -> List[WorkflowIR]:
    from repro.core.sqlflow import to_workflow
    train = """
SELECT * FROM iris.train
TO TRAIN DNNClassifier
WITH model.n_classes = 3, model.hidden_units = [10]
COLUMN sepal_len, sepal_width, petal_length, petal_width
LABEL class
INTO sqlflow_models.my_dnn_model;
"""
    predict = """
SELECT * FROM iris.test
TO PREDICT iris.predict.class
USING sqlflow_models.my_dnn_model;
"""
    return [to_workflow(train, name="sqlflow-train"),
            to_workflow(predict, name="sqlflow-predict")]


def _bench_workloads() -> List[WorkflowIR]:
    from benchmarks.workloads import build_scenario
    return [build_scenario(n, scale=0.2, seed=0)
            for n in ("multimodal", "image_seg", "lm_finetune")]


def _nl2wf_corpus() -> List[WorkflowIR]:
    """Successfully generated NL2WF workflows (paper §III corpus): every
    one the generator managed to build must lint error-free."""
    from benchmarks.bench_nl2wf import SUITE
    from repro.core.llm import TemplateLLM
    from repro.core.nl2wf import nl_to_workflow
    out = []
    for i, (desc, _grader) in enumerate(SUITE):
        for seed in range(2):
            res = nl_to_workflow(desc, TemplateLLM("gpt-4"), seed=seed,
                                 temperature=0.0)
            if res.workflow is not None:
                res.workflow.name = f"nl2wf-{i}-s{seed}"
                out.append(res.workflow)
    return out


def collect_workflows() -> List[WorkflowIR]:
    wfs = [_example_diamond(), _example_coinflip(), _example_automl(),
           _example_train_lm(), _example_streaming()]
    wfs += _sqlflow_workflows()
    wfs += _bench_workloads()
    wfs += _nl2wf_corpus()
    return wfs


def run_gate(verbose: bool = True) -> Tuple[int, int, int]:
    """Lint the whole corpus; returns (n_workflows, n_errors, n_warnings)."""
    from repro.core.analysis import lint
    n_err = n_warn = 0
    wfs = collect_workflows()
    for wf in wfs:
        res = lint(wf)
        n_err += len(res.errors)
        n_warn += len(res.warnings)
        status = ("ERROR" if res.errors
                  else "warn " if res.warnings else "ok   ")
        if verbose or res.errors:
            print(f"{status} {wf.name:24s} jobs={len(wf.jobs):3d} "
                  f"edges={len(wf.edges):3d}", flush=True)
            for d in res.diagnostics:
                print(f"      {d}")
    return len(wfs), n_err, n_warn


def main() -> int:
    verbose = "-v" in sys.argv or "--verbose" in sys.argv
    n_wf, n_err, n_warn = run_gate(verbose=verbose)
    print(f"linted {n_wf} workflows: {n_err} error(s), {n_warn} warning(s)")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
