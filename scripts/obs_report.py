"""Offline observability report: critical-path attribution + Perfetto
export from a span-tree JSONL dump.

    PYTHONPATH=src python scripts/obs_report.py spans.jsonl
    PYTHONPATH=src python scripts/obs_report.py spans.jsonl --run <run_id>
    PYTHONPATH=src python scripts/obs_report.py spans.jsonl --chrome t.json
    PYTHONPATH=src python scripts/obs_report.py --demo [--chaos]

Input is whatever ``ObsCollector.export_jsonl`` wrote (one finished run
per line). For each selected run the critical-path makespan breakdown is
printed (``MakespanReport.render``); ``--chrome`` additionally writes the
runs as Chrome trace-event JSON — validated against the schema Perfetto
loads — for ``ui.perfetto.dev`` / ``chrome://tracing``.

``--demo`` runs a small observed pipeline in-process (add ``--chaos`` for
a seeded fault plan with retries and a requeue) and reports on it; useful
for a first look at the span taxonomy without instrumenting anything.
"""
import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.obs.attribution import build_report  # noqa: E402
from repro.core.obs.spans import (  # noqa: E402
    ObsCollector, chrome_trace, load_jsonl, validate_chrome_trace)


def _demo_trees(chaos: bool):
    """Run one observed streaming pipeline (optionally under a seeded
    fault plan) and return (collector, [run_id])."""
    from repro.core import couler
    from repro.core.caching import CacheStore
    from repro.core.engines.local import LocalEngine
    from repro.core.faults import FaultPlan, ReadmissionPolicy

    kw = dict(cache=CacheStore(), enable_speculation=False,
              retry_backoff_s=0.001, retry_backoff_max_s=0.01)
    if chaos:
        kw["fault_plan"] = FaultPlan(seed=1, crash_rate=1.0,
                                     max_failures_per_site=5)
        kw["readmission"] = ReadmissionPolicy(base_backoff_s=0.02,
                                              max_backoff_s=0.1)
    eng = LocalEngine(**kw)
    try:
        c = couler.observe(eng)
        with couler.workflow("obs-demo") as ir:
            if chaos:
                a = couler.run_step(lambda: (time.sleep(0.005), 2)[1],
                                    step_name="a")
                b = couler.run_step(lambda x: (time.sleep(0.005), x * 3)[1],
                                    a, step_name="b")
                couler.run_step(lambda x: x + 1, b, step_name="c")
            else:
                def gen(n=4):
                    for i in range(n):
                        time.sleep(0.005)
                        yield i
                cur = couler.run_stream(gen, step_name="gen",
                                        cacheable=False)
                for i in range(3):
                    cur = couler.map_stream(
                        lambda x, _i=i: (time.sleep(0.002), x + 1)[1], cur,
                        step_name=f"stage{i}", cacheable=False)
        run = eng.submit(ir, optimize=False)
        return c, [run.run_id]
    finally:
        eng.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("jsonl", nargs="?", default=None,
                    help="span-tree JSONL file (from export_jsonl)")
    ap.add_argument("--run", default=None,
                    help="report only this run id (default: every run)")
    ap.add_argument("--chrome", metavar="OUT.json", default=None,
                    help="also write Chrome trace-event JSON for Perfetto")
    ap.add_argument("--demo", action="store_true",
                    help="run a small observed pipeline and report on it")
    ap.add_argument("--chaos", action="store_true",
                    help="with --demo: inject a seeded fault plan")
    args = ap.parse_args(argv)

    if args.demo:
        collector, _ = _demo_trees(chaos=args.chaos)
        trees = collector.trees()
    elif args.jsonl:
        trees = load_jsonl(Path(args.jsonl).read_text())
    else:
        ap.error("give a JSONL file or --demo")
        return 2

    if args.run:
        trees = [t for t in trees if t.run_id == args.run]
        if not trees:
            print(f"no finished run {args.run!r} in input", file=sys.stderr)
            return 1
    if not trees:
        print("no finished runs in input", file=sys.stderr)
        return 1

    for t in trees:
        print(build_report(t).render())
        print()

    if args.chrome:
        trace = chrome_trace(trees)
        problems = validate_chrome_trace(trace)
        if problems:
            for p in problems:
                print(f"chrome-trace problem: {p}", file=sys.stderr)
            return 1
        Path(args.chrome).write_text(json.dumps(trace))
        print(f"# chrome trace ({len(trace['traceEvents'])} events) "
              f"-> {args.chrome}  (load at ui.perfetto.dev)",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
