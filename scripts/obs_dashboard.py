"""Live terminal fleet dashboard over the continuous-telemetry fabric.

    PYTHONPATH=src python scripts/obs_dashboard.py telemetry.jsonl
    PYTHONPATH=src python scripts/obs_dashboard.py --demo [--frames N]

Offline mode replays a ``TimeSeriesDB`` JSONL dump (whatever
``couler.telemetry(engine, path=...)`` persisted) and renders one frame
from the final sample. ``--demo`` runs a small multi-tenant fleet
in-process — stragglers injected for one tenant, an SLO per tenant —
and renders a frame per sampling window so the burn-rate / alert panels
actually light up.

Three panels per frame (plain text, no curses dependency):

* **fleet summary** — submitted / completed / failed workflow counters,
  admission depth + sheds, cache hit ratio, inflight steps and the
  windowed submit rate;
* **SLO status** — per-tenant objective burn rates (short / long
  window) and whether the tenant is currently burning;
* **firing alerts** — alerts from the anomaly + SLO monitors within the
  last ``--window`` seconds, most recent first.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.engines.local import LocalEngine  # noqa: E402  (import
# order: engines first — repro.core.faults alone trips a pre-existing
# circular import)
from repro.core.obs.anomaly import AnomalyMonitor  # noqa: E402
from repro.core.obs.slo import SLO, SLOMonitor  # noqa: E402
from repro.core.obs.timeseries import TimeSeriesDB  # noqa: E402

WIDTH = 72


def _bar(title: str) -> str:
    pad = WIDTH - len(title) - 4
    return f"== {title} " + "=" * max(0, pad)


def _v(tsdb: TimeSeriesDB, name: str, default: float = 0.0) -> float:
    v = tsdb.latest(name)
    return v if v is not None else default


def _sum_prefix(tsdb: TimeSeriesDB, prefix: str) -> float:
    return sum(_v(tsdb, n) for n in tsdb.names() if n.startswith(prefix))


def render_frame(tsdb: TimeSeriesDB, anomaly=None, slo=None,
                 window_s: float = 60.0, now=None) -> str:
    """One dashboard frame as a string (also the unit the tests pin)."""
    now = now if now is not None else (tsdb.latest_ts() or time.time())
    lines = [_bar("fleet summary")]
    sub = _v(tsdb, "gateway_workflows_submitted_total")
    done = _v(tsdb, "gateway_workflows_completed_total")
    fail = _v(tsdb, "gateway_workflows_failed_total")
    rate = tsdb.rate("gateway_workflows_submitted_total", window_s, now=now)
    lines.append(f"workflows   submitted={sub:.0f} completed={done:.0f} "
                 f"failed={fail:.0f}  ({rate:.2f}/s over {window_s:.0f}s)")
    depth = _v(tsdb, "admission_depth")
    shed = _v(tsdb, "admission_shed_total")
    lines.append(f"admission   depth={depth:.0f} shed_total={shed:.0f} "
                 f"tenants={_v(tsdb, 'admission_tenants'):.0f}")
    hits = _sum_prefix(tsdb, "cache_hits_total")
    misses = _sum_prefix(tsdb, "cache_misses_total")
    total = hits + misses
    ratio = hits / total if total else 0.0
    lines.append(f"cache       hits={hits:.0f} misses={misses:.0f} "
                 f"hit_ratio={ratio:.2f}")
    lines.append(f"steps       inflight={_v(tsdb, 'gateway_inflight_steps'):.0f} "
                 f"peak={_v(tsdb, 'gateway_peak_inflight_steps'):.0f}  "
                 f"samples={tsdb.samples_taken}")

    lines.append(_bar("slo status"))
    if slo is None or not slo.objectives:
        lines.append("(no SLOs configured)")
    else:
        st = slo.status(now=now)
        for tenant, s in sorted(st.items()):
            flag = "BURNING" if s["burning"] else "ok"
            lines.append(f"{tenant:<16} {flag:<8} runs={s['runs_seen']}")
            for name, o in s["objectives"].items():
                lines.append(
                    f"  {name:<20} burn {o['burn_short']:.1f}x/"
                    f"{o['burn_long']:.1f}x (n={o['n_short']}/{o['n_long']})")

    lines.append(_bar("firing alerts"))
    firing = []
    if anomaly is not None:
        firing += list(anomaly.firing(within_s=window_s))
    if slo is not None:
        lo = now - window_s
        firing += [a for a in slo.alerts if a.ts >= lo]
    if not firing:
        lines.append("(none)")
    for a in sorted(firing, key=lambda a: -a.ts)[:10]:
        scope = f" [{a.scope}]" if a.scope else ""
        lines.append(f"{a.severity.upper():<8} {a.detector}{scope}: "
                     f"{a.reason}"[:WIDTH])
    return "\n".join(lines)


def _offline(path: str, window_s: float) -> int:
    tsdb = TimeSeriesDB.load_jsonl(path)
    if not len(tsdb):
        print(f"no samples in {path}", file=sys.stderr)
        return 1
    print(f"{path}: {tsdb.samples_taken} samples, "
          f"{len(tsdb.names())} series")
    print(render_frame(tsdb, window_s=window_s))
    return 0


def _demo(frames: int, window_s: float) -> int:
    import repro.core.api as couler
    from repro.core.caching import CacheStore
    from repro.core.faults import FaultPlan

    mon = AnomalyMonitor()
    # seed a fast baseline so the injected straggler is an outlier
    for k in range(10):
        mon.straggler.note("demo-batch/train", 0.01, ts=float(k))
    slos = SLOMonitor([
        SLO(tenant="research", completion_rate=0.9),
        SLO(tenant="prod", completion_rate=0.99, makespan_budget_s=5.0),
    ], short_window_s=30.0, long_window_s=120.0, min_runs=3)
    eng = LocalEngine(
        max_workers=4, cache=CacheStore(), enable_speculation=False,
        fault_plan=FaultPlan(seed=11, straggler_rate=1.0,
                             straggler_delay_s=0.3,
                             targets=frozenset({"train"})),
        telemetry_interval_s=0.1, anomaly=mon, slo=slos)
    try:
        def prep(i):
            return i + 1

        def train(x):
            return x * 2
        for frame in range(frames):
            for tenant in ("research", "prod"):
                with couler.workflow("demo-batch") as wf:
                    p = couler.run_step(prep, frame, step_name="prep")
                    couler.run_step(train, p, step_name="train")
                eng.submit(wf, tenant=tenant)
            time.sleep(0.15)    # let a sampling tick land
            gw = eng.gateway
            print(f"\n--- frame {frame + 1}/{frames} ---")
            print(render_frame(gw.tsdb, anomaly=gw.anomaly, slo=gw.slo,
                               window_s=window_s))
        return 0
    finally:
        eng.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("jsonl", nargs="?", help="TimeSeriesDB JSONL dump")
    ap.add_argument("--demo", action="store_true",
                    help="run a live in-process fleet demo")
    ap.add_argument("--frames", type=int, default=3,
                    help="demo frames to render (default 3)")
    ap.add_argument("--window", type=float, default=60.0,
                    help="alert/rate window in seconds (default 60)")
    args = ap.parse_args(argv)
    if args.demo:
        return _demo(args.frames, args.window)
    if not args.jsonl:
        ap.error("give a telemetry JSONL file or --demo")
    return _offline(args.jsonl, args.window)


if __name__ == "__main__":
    sys.exit(main())
