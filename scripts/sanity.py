"""Quick CPU sanity loop: forward + train step on all reduced archs, plus
a tier-consistency check of the cache subsystem (bytes conserved across
demotions/promotions, capacity respected, no duplicate private copies)."""
import random
import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.models import transformer as T
from repro.training import train as TR

ok = True
only = sys.argv[1:] or ARCH_IDS


def cache_tier_sanity() -> bool:
    """Randomized offer/get/promote traffic on a 3-tier store; the store's
    check_invariants() asserts the per-tier byte ledgers balance."""
    from repro.core.cache import (CacheTier, CoulerPolicy, TieredCacheStore,
                                  mem_spec, remote_spec, ssd_spec)
    from repro.core.ir import Job, WorkflowIR
    wf = WorkflowIR("sanity")
    wf.add_job(Job(name="root", est_time_s=2))
    for i in range(4):
        wf.add_job(Job(name=f"leaf{i}", est_time_s=1))
        wf.add_edge("root", f"leaf{i}")
    store = TieredCacheStore(
        tiers=[CacheTier(mem_spec(500)), CacheTier(ssd_spec(1000)),
               CacheTier(remote_spec(2000))],
        policy=CoulerPolicy(), auto_promote_every=5)
    store.attach_workflow(wf)
    rng = random.Random(0)
    try:
        for i in range(400):
            r = rng.random()
            if r < 0.55:
                store.offer(f"k{rng.randrange(16)}", None,
                            rng.uniform(0.1, 3.0),
                            producer=rng.choice(list(wf.jobs)),
                            nbytes=rng.choice([40, 90, 180, 450, 1100]))
            elif r < 0.9:
                store.get(f"k{rng.randrange(16)}")
            else:
                store.promote()
            if i % 40 == 0:
                store.check_invariants()
        store.check_invariants()
    except AssertionError as e:
        print(f"FAIL cache_tiers {e}")
        return False
    s = store.stats
    print(f"OK   cache_tiers hits={s['hits']} demotions={s['demotions']} "
          f"promotions={s['promotions']} evictions={s['evictions']}")
    return True


ok = cache_tier_sanity() and ok
for aid in only:
    spec = get_arch(aid)
    cfg = reduced(spec.model).replace(param_dtype="float32",
                                      compute_dtype="float32")
    tcfg = spec.train
    key = jax.random.PRNGKey(0)
    try:
        B, S = 2, 32
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "targets": jnp.ones((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["patches"] = jnp.ones((B, cfg.num_patches, cfg.d_model), jnp.float32)
        state = TR.init_train_state(cfg, tcfg, key)
        step = jax.jit(TR.make_train_step(cfg, tcfg))
        state, m = step(state, batch)
        loss = float(m["loss"])
        assert loss == loss, "NaN loss"
        # decode one token
        caches = T.init_caches(cfg, B, 64, jnp.float32)
        logits, caches = jax.jit(
            lambda p, t, c: T.apply_lm_decode(p, cfg, t, c, jnp.int32(0))
        )(state["params"], jnp.ones((B, 1), jnp.int32), caches)
        assert logits.shape == (B, 1, cfg.padded_vocab), logits.shape
        assert not bool(jnp.any(jnp.isnan(logits))), "NaN decode logits"
        print(f"OK   {aid:20s} loss={loss:.4f}")
    except Exception as e:
        ok = False
        print(f"FAIL {aid:20s} {type(e).__name__}: {e}")
        traceback.print_exc()
print("ALL OK" if ok else "FAILURES")
sys.exit(0 if ok else 1)
