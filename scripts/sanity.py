"""Quick CPU sanity loop: forward + train step on all reduced archs."""
import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.models import transformer as T
from repro.training import train as TR

ok = True
only = sys.argv[1:] or ARCH_IDS
for aid in only:
    spec = get_arch(aid)
    cfg = reduced(spec.model).replace(param_dtype="float32",
                                      compute_dtype="float32")
    tcfg = spec.train
    key = jax.random.PRNGKey(0)
    try:
        B, S = 2, 32
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "targets": jnp.ones((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["patches"] = jnp.ones((B, cfg.num_patches, cfg.d_model), jnp.float32)
        state = TR.init_train_state(cfg, tcfg, key)
        step = jax.jit(TR.make_train_step(cfg, tcfg))
        state, m = step(state, batch)
        loss = float(m["loss"])
        assert loss == loss, "NaN loss"
        # decode one token
        caches = T.init_caches(cfg, B, 64, jnp.float32)
        logits, caches = jax.jit(
            lambda p, t, c: T.apply_lm_decode(p, cfg, t, c, jnp.int32(0))
        )(state["params"], jnp.ones((B, 1), jnp.int32), caches)
        assert logits.shape == (B, 1, cfg.padded_vocab), logits.shape
        assert not bool(jnp.any(jnp.isnan(logits))), "NaN decode logits"
        print(f"OK   {aid:20s} loss={loss:.4f}")
    except Exception as e:
        ok = False
        print(f"FAIL {aid:20s} {type(e).__name__}: {e}")
        traceback.print_exc()
print("ALL OK" if ok else "FAILURES")
sys.exit(0 if ok else 1)
