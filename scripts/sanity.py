"""Quick CPU sanity loop: forward + train step on all reduced archs, plus
a tier-consistency check of the cache subsystem (bytes conserved across
demotions/promotions, capacity respected, no duplicate private copies) and
event-stream ordering fuzzes of the async workflow gateway (plain DAGs and
chunked streaming pipelines)."""
import random
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.models import transformer as T
from repro.training import train as TR

ok = True
only = sys.argv[1:] or ARCH_IDS


def cache_tier_sanity() -> bool:
    """Randomized offer/get/promote traffic on a 3-tier store; the store's
    check_invariants() asserts the per-tier byte ledgers balance."""
    from repro.core.cache import (CacheTier, CoulerPolicy, TieredCacheStore,
                                  mem_spec, remote_spec, ssd_spec)
    from repro.core.ir import Job, WorkflowIR
    wf = WorkflowIR("sanity")
    wf.add_job(Job(name="root", est_time_s=2))
    for i in range(4):
        wf.add_job(Job(name=f"leaf{i}", est_time_s=1))
        wf.add_edge("root", f"leaf{i}")
    store = TieredCacheStore(
        tiers=[CacheTier(mem_spec(500)), CacheTier(ssd_spec(1000)),
               CacheTier(remote_spec(2000))],
        policy=CoulerPolicy(), auto_promote_every=5)
    store.attach_workflow(wf)
    rng = random.Random(0)
    try:
        for i in range(400):
            r = rng.random()
            if r < 0.55:
                store.offer(f"k{rng.randrange(16)}", None,
                            rng.uniform(0.1, 3.0),
                            producer=rng.choice(list(wf.jobs)),
                            nbytes=rng.choice([40, 90, 180, 450, 1100]))
            elif r < 0.9:
                store.get(f"k{rng.randrange(16)}")
            else:
                store.promote()
            if i % 40 == 0:
                store.check_invariants()
        store.check_invariants()
    except AssertionError as e:
        print(f"FAIL cache_tiers {e}")
        return False
    s = store.stats
    print(f"OK   cache_tiers hits={s['hits']} demotions={s['demotions']} "
          f"promotions={s['promotions']} evictions={s['evictions']}")
    return True


def gateway_event_sanity() -> bool:
    """Fuzz: random DAGs (some randomly cancelled mid-flight) through the
    asyncio gateway. Every run's event stream is validated twice by the
    shared executable spec (repro.core.analysis.TraceChecker): inline at
    each publish (check_events=True sanitizer mode) and post-hoc over the
    collected stream."""
    import asyncio

    from repro.core.analysis import TraceChecker
    from repro.core.engines.local import LocalEngine
    from repro.core.ir import Job, WorkflowIR

    rng = random.Random(0)
    eng = LocalEngine(max_workers=4, enable_speculation=False,
                      promote_interval_s=0.0, check_events=True)

    def build(i: int) -> WorkflowIR:
        wf = WorkflowIR(f"fuzz-{i}")
        n = rng.randint(2, 6)
        for j in range(n):
            wf.add_job(Job(name=f"s{j}", fn=lambda: time.sleep(0.001),
                           cacheable=False, outputs=[f"s{j}:out"]))
        for j in range(1, n):
            for k in range(j):
                if rng.random() < 0.4:
                    wf.add_edge(f"s{k}", f"s{j}")
        return wf

    async def one(i: int) -> None:
        wf = build(i)
        h = await eng.submit_async(wf, tenant=f"t{i % 3}", block=True)
        if rng.random() < 0.3:
            delay = rng.uniform(0, 0.01)

            async def canceller():
                await asyncio.sleep(delay)
                h.cancel()
            asyncio.get_running_loop().create_task(canceller())
        evs = [ev async for ev in h.events()]
        TraceChecker.check(evs, wf=wf)
        run = await h
        assert run.status in ("Succeeded", "Failed", "Cancelled"), run.status
        assert evs[-1].status == run.status, (evs[-1], run.status)

    async def _all():
        await asyncio.wait_for(
            asyncio.gather(*[one(i) for i in range(24)]), timeout=120)

    try:
        asyncio.run(_all())
    except AssertionError as e:
        print(f"FAIL gateway_events {e}")
        return False
    finally:
        eng.close()
    print("OK   gateway_events 24 runs, invariants held")
    return True


def streaming_event_sanity() -> bool:
    """Fuzz: random LINEAR streaming pipelines (run_stream -> map_stream^k,
    some randomly cancelled mid-stream) through the gateway. Stream/chunk
    ordering (STREAMING before chunks, monotone indices resetting only on
    rewind, consumers never ahead of their producer's STREAMING) is
    validated by the shared TraceChecker — inline via check_events=True
    and post-hoc with workflow topology for the invariant-6 check."""
    import asyncio

    from repro.core import couler
    from repro.core.analysis import TraceChecker
    from repro.core.engines.local import LocalEngine

    rng = random.Random(1)
    eng = LocalEngine(max_workers=6, enable_speculation=False,
                      promote_interval_s=0.0, check_events=True)

    def build(i: int):
        n_chunks = rng.randint(3, 10)
        stages = rng.randint(1, 3)

        def gen(_n=n_chunks):
            for c in range(_n):
                time.sleep(0.001)
                yield c

        with couler.workflow(f"sfuzz-{i}") as ir:
            cur = couler.run_stream(gen, step_name="p", cacheable=False,
                                    buffer_chunks=rng.choice([2, 4, 8]))
            for k in range(stages):
                cur = couler.map_stream(lambda c: c + 1, cur,
                                        step_name=f"m{k}", cacheable=False)
        return ir, n_chunks, stages

    async def one(i: int) -> None:
        ir, n_chunks, stages = build(i)
        h = await eng.submit_async(ir, tenant=f"t{i % 3}", block=True)
        cancelled = rng.random() < 0.3
        if cancelled:
            delay = rng.uniform(0, 0.01)

            async def canceller():
                await asyncio.sleep(delay)
                h.cancel()
            asyncio.get_running_loop().create_task(canceller())
        evs = [ev async for ev in h.events()]
        run = await h
        assert evs[-1].status == run.status, (evs[-1], run.status)
        TraceChecker.check(evs, wf=ir)
        if run.status == "Succeeded":
            job = "p" if stages == 0 else f"m{stages - 1}"
            exp = [c + stages for c in range(n_chunks)]
            assert run.artifacts[f"{job}:out"] == exp, run.artifacts

    async def _all():
        await asyncio.wait_for(
            asyncio.gather(*[one(i) for i in range(24)]), timeout=120)

    try:
        asyncio.run(_all())
    except AssertionError as e:
        print(f"FAIL streaming_events {e}")
        return False
    finally:
        eng.close()
    print("OK   streaming_events 24 runs, chunk invariants held")
    return True


def chaos_sanity() -> bool:
    """Chaos fuzz: >=32 concurrent workflows through a LocalEngine with
    seeded fault injection (transient/permanent crashes + worker loss),
    frontier recording, and straggler-aware re-admission. Every run must
    reach Succeeded with artifacts bit-identical to a fault-free engine,
    and every event stream passes the TraceChecker sanitizer inline
    (check_events=True) plus a post-hoc replay. A second phase batches
    preemption-struck workflows through the MultiClusterEngine simulator."""
    import asyncio

    from repro.core.analysis import TraceChecker
    from repro.core.engines.cluster import Cluster, MultiClusterEngine
    from repro.core.engines.local import LocalEngine
    from repro.core.faults import FaultPlan, ReadmissionPolicy
    from repro.core.ir import Job, Resources, WorkflowIR

    n_wf = 32

    def build_batch():
        # fresh seeded rng per batch -> the chaos and fault-free batches
        # are structurally identical (required for the bit-identity check)
        rng = random.Random(2)
        wfs = []
        for i in range(n_wf):
            wf = WorkflowIR(f"chaos-{i}")
            n = rng.randint(3, 6)
            for j in range(n):
                wf.add_job(Job(name=f"s{j}",
                               fn=lambda i=i, j=j: (i, j, i * j),
                               cacheable=False, outputs=[f"s{j}:out"],
                               retry_limit=3))
            for j in range(1, n):
                for k in range(j):
                    if rng.random() < 0.4:
                        wf.add_edge(f"s{k}", f"s{j}")
            wfs.append(wf)
        return wfs

    batches = [build_batch(), build_batch()]
    plan = FaultPlan(seed=9, crash_rate=0.25, permanent_rate=0.1,
                     worker_loss_rate=0.1, max_failures_per_site=4)

    async def drive(eng: LocalEngine, wfs) -> list:
        async def one(wf):
            h = await eng.submit_async(wf, tenant=f"t{hash(wf.name) % 3}",
                                       block=True)
            evs = [ev async for ev in h.events()]
            TraceChecker.check(evs, wf=wf)
            return await h
        return await asyncio.wait_for(
            asyncio.gather(*[one(w) for w in wfs]), timeout=240)

    try:
        chaos_eng = LocalEngine(
            max_workers=6, enable_speculation=False, promote_interval_s=0.0,
            check_events=True, fault_plan=plan, frontier=True,
            retry_backoff_s=0.002, retry_backoff_max_s=0.02,
            readmission=ReadmissionPolicy(base_backoff_s=0.01,
                                          max_backoff_s=0.1))
        clean_eng = LocalEngine(max_workers=6, enable_speculation=False,
                                promote_interval_s=0.0, check_events=True)
        chaos_runs = asyncio.run(drive(chaos_eng, batches[0]))
        clean_runs = asyncio.run(drive(clean_eng, batches[1]))
        inj = chaos_eng.injector.stats
        assert inj["crash"] + inj["crash_permanent"] + inj["worker_lost"] > 0
        for cr, fr in zip(chaos_runs, clean_runs):
            assert cr.status == "Succeeded", \
                f"{cr.workflow.name}: {cr.status}"
            assert cr.artifacts == fr.artifacts, \
                f"{cr.workflow.name}: artifacts diverged under chaos"
        chaos_eng.close()
        clean_eng.close()

        # cluster preemption: every struck batch still completes
        cplan = FaultPlan(seed=4, preemption_rate_per_s=0.3,
                          preemption_dark_s=2.0)
        ceng = MultiClusterEngine(clusters=[
            Cluster("a", cpu=16, mem_bytes=1 << 40),
            Cluster("b", cpu=16, mem_bytes=1 << 40)], fault_plan=cplan)
        wfs = []
        for i in range(12):
            wf = WorkflowIR(f"mc-chaos-{i}")
            prev = None
            for j in range(3):
                wf.add_job(Job(name=f"j{j}", est_time_s=1.0,
                               resources=Resources(cpu=4)))
                if prev:
                    wf.add_edge(prev, f"j{j}")
                prev = f"j{j}"
            wfs.append(wf)
        cruns = ceng.submit_many([(w, "u0", 0) for w in wfs])
        assert all(r.succeeded() for r in cruns.values())
        assert ceng.metrics["preempted_jobs"] > 0
    except AssertionError as e:
        print(f"FAIL chaos {e}")
        return False
    readm = chaos_eng.gateway.stats.get("readmitted", 0)
    print(f"OK   chaos {n_wf} runs bit-identical under "
          f"{inj['crash']}+{inj['crash_permanent']}+{inj['worker_lost']} "
          f"injected faults ({readm} readmissions); "
          f"{ceng.metrics['preempted_jobs']} cluster evictions recovered")
    return True


def obs_sanity() -> bool:
    """Observability consistency fuzz: random DAGs (half under a seeded
    fault plan) through an observed engine; the registry counters must
    reconcile exactly with the derived span trees — per-type event
    totals, per-status run counts, retry/readmission segment counts —
    and no builder may leak (open_run_ids drains to empty)."""
    from repro.core import couler
    from repro.core.caching import CacheStore
    from repro.core.engines.local import LocalEngine
    from repro.core.faults import FaultPlan, ReadmissionPolicy
    from repro.core.ir import Job, WorkflowIR

    rng = random.Random(7)

    def build(i: int) -> WorkflowIR:
        wf = WorkflowIR(f"obs-fuzz-{i}")
        n = rng.randint(2, 5)
        for j in range(n):
            wf.add_job(Job(name=f"s{j}", fn=lambda i=i, j=j: i * 10 + j,
                           cacheable=False, retry_limit=3))
        for j in range(1, n):
            for k in range(j):
                if rng.random() < 0.5:
                    wf.add_edge(f"s{k}", f"s{j}")
        return wf

    def engine(chaos: bool) -> LocalEngine:
        kw = dict(cache=CacheStore(), enable_speculation=False,
                  check_events=True, retry_backoff_s=0.002,
                  retry_backoff_max_s=0.02)
        if chaos:
            kw["fault_plan"] = FaultPlan(seed=13, crash_rate=0.3,
                                         worker_loss_rate=0.15,
                                         max_failures_per_site=4)
            kw["readmission"] = ReadmissionPolicy(base_backoff_s=0.005,
                                                  max_backoff_s=0.05)
        return LocalEngine(**kw)

    try:
        streams = []
        trees = []
        for chaos in (False, True):
            eng = engine(chaos)
            try:
                c = couler.observe(eng)
                handles = [eng.gateway.submit_nowait(build(i), block=True)
                           for i in range(8)]
                runs = [h.result() for h in handles]
                assert all(r.succeeded() for r in runs)
                assert c.open_run_ids == [], "span builders leaked"
                for h, r in zip(handles, runs):
                    evs = h.events_so_far()
                    t = c.tree(r.run_id)
                    assert t is not None and t.status == "Succeeded"
                    # tree event totals mirror the raw stream exactly
                    assert t.events_total == len(evs)
                    by_type = {}
                    for ev in evs:
                        by_type[ev.type.name] = by_type.get(ev.type.name,
                                                            0) + 1
                    assert t.counts == by_type
                    for sp in t.steps:
                        assert sp.end is not None, f"open span {sp.step}"
                    streams.append(evs)
                    trees.append(t)
                # registry totals reconcile with the span trees this
                # collector derived
                reg = c.registry
                these = [c.tree(r.run_id) for r in runs]
                assert reg.get_value("obs_runs_total",
                                     status="Succeeded") == len(runs)
                assert reg.get_value("obs_retries_total") == sum(
                    len(t.retry_segments) for t in these)
                for tname in ("STEP_STARTED", "STEP_SUCCEEDED",
                              "WORKFLOW_DONE", "STEP_RETRY"):
                    assert reg.get_value("obs_events_total",
                                         type=tname) == sum(
                        t.counts.get(tname, 0) for t in these)
            finally:
                eng.close()
        # offline replay into a fresh collector reproduces the trees
        from repro.core.obs import ObsCollector
        c2 = ObsCollector()
        for evs, t in zip(streams, trees):
            rid = c2.ingest(evs, run_id=t.run_id, tenant=t.tenant)
            t2 = c2.tree(rid)
            assert t2.counts == t.counts
            assert t2.status == t.status
            assert len(t2.retry_segments) == len(t.retry_segments)
        assert c2.open_run_ids == []
    except AssertionError as e:
        print(f"FAIL obs {e}")
        traceback.print_exc()
        return False
    n_retries = sum(len(t.retry_segments) for t in trees)
    print(f"OK   obs {len(trees)} runs reconciled "
          f"({sum(t.events_total for t in trees)} events, "
          f"{n_retries} retries), no span leaks")
    return True


def workflow_lint_sanity() -> bool:
    """CI lint gate: every example/bench/NL2WF workflow must lint with
    zero errors (scripts/lint_workflows.py has the corpus)."""
    import lint_workflows
    try:
        n_wf, n_err, n_warn = lint_workflows.run_gate(verbose=False)
    except Exception as e:  # noqa: BLE001
        print(f"FAIL workflow_lint {type(e).__name__}: {e}")
        traceback.print_exc()
        return False
    if n_err:
        print(f"FAIL workflow_lint {n_err} error(s) across {n_wf} workflows")
        return False
    print(f"OK   workflow_lint {n_wf} workflows, 0 errors, "
          f"{n_warn} warning(s)")
    return True


def telemetry_sanity() -> bool:
    """Continuous-telemetry fuzz, four claims: (a) seeded chaos produces
    the deterministic in-band ALERTs — a targeted straggler against a
    pre-seeded baseline, a readmission storm under targeted permanent
    faults — and every stream still passes the TraceChecker (invariant
    9); (b) every alert in the monitor logs re-derives from its own
    ``context`` (no unjustified alert can survive this fuzz); (c) a
    clean 24-workflow corpus — both a deterministic direct feed and a
    live fuzz batch — raises zero alerts; (d) the merged telemetry
    snapshot round-trips through the OpenMetrics renderer/parser."""
    from repro.core.analysis import TraceChecker
    from repro.core.engines.local import LocalEngine
    from repro.core.faults import FaultPlan, ReadmissionPolicy
    from repro.core.gateway import EventType
    from repro.core.ir import Job, WorkflowIR
    from repro.core.obs.anomaly import AnomalyMonitor
    from repro.core.obs.exposition import (parse_openmetrics,
                                           render_openmetrics)
    from repro.core.obs.slo import SLO, SLOMonitor

    def justified(a, mon) -> bool:
        c = a.context
        if a.detector == "straggler":
            z = 0.6745 * (c["duration_s"] - c["median_s"]) / c["scale_s"]
            return (abs(z - a.value) < 1e-6 and z > a.threshold
                    and c["n_samples"] >= mon.straggler.min_samples
                    and c["duration_s"] > 2.0 * c["median_s"])
        if a.detector == "readmission_storm":
            return a.value == c["count"] and c["count"] >= a.threshold
        if a.detector == "slo_burn":
            return (c["burn_short"] > a.threshold
                    and c["burn_long"] > a.threshold)
        if a.detector == "cache_hit_drift":
            drop = c["ratio_long"] - c["ratio_short"]
            return abs(drop - a.value) < 1e-9 and drop > a.threshold
        if a.detector == "admission_saturation":
            return a.value >= a.threshold
        return False            # unknown detector == unjustified

    monitors = []
    try:
        # (a1) straggler: baseline pre-seeded, one targeted 0.4s delay
        mon = AnomalyMonitor()
        for k in range(10):
            mon.straggler.note("tele/s1", 0.01 + 0.001 * k, ts=float(k))
        eng = LocalEngine(
            max_workers=2, enable_speculation=False, check_events=True,
            fault_plan=FaultPlan(seed=7, straggler_rate=1.0,
                                 straggler_delay_s=0.4,
                                 targets=frozenset({"s1"})),
            telemetry_interval_s=0.05, anomaly=mon,
            slo=SLOMonitor([SLO(tenant="t0")]))
        try:
            wf = WorkflowIR("tele")
            wf.add_job(Job(name="s0", fn=lambda: 1, cacheable=False))
            wf.add_job(Job(name="s1", fn=lambda: 2, cacheable=False))
            wf.add_edge("s0", "s1")
            h = eng.gateway.submit_nowait(wf, tenant="t0", block=True)
            run = h.result()
            assert run.succeeded(), run.status
            evs = h.events_so_far()
            TraceChecker.check(evs, wf=wf)
            inband = [e for e in evs if e.type is EventType.ALERT]
            assert [e.status for e in inband] == ["straggler"], inband
            assert inband[0].step == "s1", inband[0]
            assert eng.gateway.tsdb.samples_taken > 0, "no sampling ticks"
            # (d) merged snapshot -> OpenMetrics -> parse, counters agree
            merged = {}
            for reg in eng.gateway._telemetry_sources():
                merged.update(reg.snapshot())
            parsed = parse_openmetrics(render_openmetrics(merged))
            assert parsed["gateway_workflows_submitted_total"] == float(
                merged["gateway_workflows_submitted_total"])
            assert parsed['alerts_total{detector="straggler"}'] == 1.0
            n_series = len(parsed)
        finally:
            eng.close()
        monitors.append(mon)

        # (a2) readmission storm: targeted permanent faults, capped at 3
        # failures per site -> exactly 3 requeues -> one storm alert
        mon2 = AnomalyMonitor()
        eng = LocalEngine(
            max_workers=2, enable_speculation=False, check_events=True,
            fault_plan=FaultPlan(seed=5, permanent_rate=1.0,
                                 targets=frozenset({"s0"}),
                                 max_failures_per_site=3),
            readmission=ReadmissionPolicy(base_backoff_s=0.005,
                                          max_backoff_s=0.02),
            telemetry_interval_s=0.05, anomaly=mon2)
        try:
            wf = WorkflowIR("storm")
            wf.add_job(Job(name="s0", fn=lambda: 3, cacheable=False))
            h = eng.gateway.submit_nowait(wf, tenant="t1", block=True)
            run = h.result()
            assert run.succeeded(), run.status
            evs = h.events_so_far()
            TraceChecker.check(evs, wf=wf)
            req = [e for e in evs if e.type is EventType.WORKFLOW_REQUEUED]
            storm = [e for e in evs if e.type is EventType.ALERT
                     and e.status == "readmission_storm"]
            assert len(req) == 3, f"{len(req)} requeues"
            assert len(storm) == 1, f"{len(storm)} storm alerts (hysteresis)"
        finally:
            eng.close()
        monitors.append(mon2)

        # (b) justification fuzz over every recorded alert
        n_alerts = 0
        for m in monitors:
            for a in list(m.alerts):
                assert justified(a, m), f"unjustified alert: {a.to_dict()}"
                n_alerts += 1
        assert n_alerts >= 2, "expected straggler + storm alerts"

        # (c1) deterministic clean feed: 24 workflows x 6 uniform steps
        clean = AnomalyMonitor()
        rng = random.Random(21)
        t = 0.0
        for i in range(24):
            for j in range(6):
                t += 0.5
                a = clean.note_step_duration("clean", f"s{j}",
                                             0.01 + rng.uniform(0, 0.004),
                                             ts=t)
                assert a is None, f"false positive: {a.to_dict()}"
        assert len(clean.alerts) == 0

        # (c2) live clean corpus: 24 fuzz DAGs under full telemetry
        clean2 = AnomalyMonitor()
        slos = SLOMonitor([SLO(tenant=f"t{i}") for i in range(3)])
        eng = LocalEngine(max_workers=4, enable_speculation=False,
                          check_events=True, telemetry_interval_s=0.02,
                          anomaly=clean2, slo=slos)
        try:
            rng = random.Random(3)
            handles = []
            for i in range(24):
                wf = WorkflowIR(f"tclean-{i}")
                n = rng.randint(2, 5)
                for j in range(n):
                    wf.add_job(Job(name=f"s{j}",
                                   fn=lambda: time.sleep(0.001),
                                   cacheable=False))
                for j in range(1, n):
                    for k in range(j):
                        if rng.random() < 0.4:
                            wf.add_edge(f"s{k}", f"s{j}")
                handles.append(eng.gateway.submit_nowait(
                    wf, tenant=f"t{i % 3}", block=True))
            runs = [h.result() for h in handles]
            assert all(r.succeeded() for r in runs)
            for h in handles:
                evs = h.events_so_far()
                assert not any(e.type is EventType.ALERT for e in evs)
            assert len(clean2.alerts) == 0, list(clean2.alerts)
            assert len(slos.alerts) == 0, list(slos.alerts)
        finally:
            eng.close()
    except AssertionError as e:
        print(f"FAIL telemetry {e}")
        traceback.print_exc()
        return False
    print(f"OK   telemetry {n_alerts} seeded alerts justified, "
          f"0 false positives on 24 clean runs, "
          f"{n_series} OpenMetrics samples round-tripped")
    return True


def bench_trajectory_sanity() -> bool:
    """Bench regression watchdog: the recorded BENCH trajectory must be
    judged green by benchmarks/run.py --check (no suite >25% slower than
    the previous consolidated file; <2 files is a skip, not a failure)."""
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    try:
        from benchmarks.run import check_trajectory
        bad = check_trajectory(25.0)
    except Exception as e:  # noqa: BLE001
        print(f"FAIL bench_trajectory {type(e).__name__}: {e}")
        traceback.print_exc()
        return False
    if bad:
        print(f"FAIL bench_trajectory {bad} suite(s) regressed >25%")
        return False
    print("OK   bench_trajectory no suite regressed >25%")
    return True


ok = cache_tier_sanity() and ok
ok = gateway_event_sanity() and ok
ok = streaming_event_sanity() and ok
ok = chaos_sanity() and ok
ok = obs_sanity() and ok
ok = telemetry_sanity() and ok
ok = workflow_lint_sanity() and ok
ok = bench_trajectory_sanity() and ok
for aid in only:
    spec = get_arch(aid)
    cfg = reduced(spec.model).replace(param_dtype="float32",
                                      compute_dtype="float32")
    tcfg = spec.train
    key = jax.random.PRNGKey(0)
    try:
        B, S = 2, 32
        batch = {"tokens": jnp.ones((B, S), jnp.int32),
                 "targets": jnp.ones((B, S), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["patches"] = jnp.ones((B, cfg.num_patches, cfg.d_model), jnp.float32)
        state = TR.init_train_state(cfg, tcfg, key)
        step = jax.jit(TR.make_train_step(cfg, tcfg))
        state, m = step(state, batch)
        loss = float(m["loss"])
        assert loss == loss, "NaN loss"
        # decode one token
        caches = T.init_caches(cfg, B, 64, jnp.float32)
        logits, caches = jax.jit(
            lambda p, t, c: T.apply_lm_decode(p, cfg, t, c, jnp.int32(0))
        )(state["params"], jnp.ones((B, 1), jnp.int32), caches)
        assert logits.shape == (B, 1, cfg.padded_vocab), logits.shape
        assert not bool(jnp.any(jnp.isnan(logits))), "NaN decode logits"
        print(f"OK   {aid:20s} loss={loss:.4f}")
    except Exception as e:
        ok = False
        print(f"FAIL {aid:20s} {type(e).__name__}: {e}")
        traceback.print_exc()
print("ALL OK" if ok else "FAILURES")
sys.exit(0 if ok else 1)
