"""Async workflow gateway: event streams, cancellation, backpressure,
multi-tenant fairness, background promotion, and the sync facade.

Pins the package's documented invariants (repro/core/gateway/__init__.py):
ADMITTED first, exactly one terminal WORKFLOW_DONE last, STEP_* terminal
events preceded by their own STEP_STARTED; cancel mid-flight leaves a
resumable run; >=200 concurrent submit_async calls share one
TieredCacheStore with the in-flight step bound enforced.
"""
import asyncio
import threading
import time

import pytest

from repro.core import couler
from repro.core.analysis import TraceChecker
from repro.core.cache import (CacheTier, CoulerPolicy, TieredCacheStore,
                              mem_spec, remote_spec, ssd_spec)
from repro.core.engines.base import StepStatus, WorkflowRun
from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.engines.local import LocalEngine
from repro.core.gateway import (AdmissionQueue, AdmittedItem, EventType,
                                QueueFull)
from repro.core.ir import Job, Resources, WorkflowIR


def chain_wf(name, k=3, fns=None, sleep=0.0):
    """k-step chain; fns overrides individual step callables."""
    wf = WorkflowIR(name)
    prev = None
    for i in range(k):
        def mk(i=i):
            def fn(*a):
                if sleep:
                    time.sleep(sleep)
                return i
            return fn
        fn = (fns or {}).get(i) or mk()
        wf.add_job(Job(name=f"s{i}", fn=fn, cacheable=False,
                       outputs=[f"s{i}:out"], retry_limit=0))
        if prev is not None:
            wf.add_edge(prev, f"s{i}")
        prev = f"s{i}"
    return wf


def _engine(**kw):
    kw.setdefault("enable_speculation", False)
    kw.setdefault("promote_interval_s", 0.0)
    # sanitizer mode: every published event is validated inline by the
    # TraceChecker, so the whole suite doubles as an invariant check
    kw.setdefault("check_events", True)
    return LocalEngine(**kw)


# ---------------------------------------------------------------------------
# awaitable handle + event-stream invariants
# ---------------------------------------------------------------------------

def test_await_returns_same_run_as_sync_submit():
    eng = _engine(max_workers=2)

    async def main():
        h = await eng.submit_async(chain_wf("aw", 3))
        run = await h
        return h, run

    h, run = asyncio.run(main())
    assert run.succeeded()
    assert h.run is run and h.done()
    # sync facade produces identical statuses/artifacts on an equal workflow
    run2 = eng.submit(chain_wf("aw2", 3))
    assert {n: r.status for n, r in run.steps.items()} == \
        {n: r.status for n, r in run2.steps.items()}
    assert {k.split(":")[0]: v for k, v in run.artifacts.items()} == \
        {k.split(":")[0]: v for k, v in run2.artifacts.items()}
    eng.close()


def _check_stream_invariants(evs, wf=None):
    # single executable spec of the gateway invariants (no local copy)
    TraceChecker.check(evs, wf=wf)


def test_event_stream_ordering_success_and_failure():
    eng = _engine(max_workers=2)

    def boom():
        raise ValueError("boom")

    async def main():
        h_ok = await eng.submit_async(chain_wf("ev-ok", 3))
        h_bad = await eng.submit_async(chain_wf("ev-bad", 3, fns={1: boom}))
        ev_ok = [e async for e in h_ok.events()]
        ev_bad = [e async for e in h_bad.events()]
        return h_ok, ev_ok, ev_bad, await h_ok, await h_bad

    h_ok, ev_ok, ev_bad, run_ok, run_bad = asyncio.run(main())
    _check_stream_invariants(ev_ok)
    _check_stream_invariants(ev_bad)
    assert ev_ok[-1].status == "Succeeded" and run_ok.succeeded()
    assert ev_bad[-1].status == "Failed" and not run_bad.succeeded()
    assert any(e.type is EventType.STEP_FAILED and e.step == "s1"
               for e in ev_bad)
    # s2 never launched -> no events for it, record stays Pending
    assert not any(e.step == "s2" for e in ev_bad)
    assert run_bad.steps["s2"].status == StepStatus.PENDING

    # late subscription (fresh loop, run long finished) replays the
    # identical, complete stream from history
    async def late():
        return [e async for e in h_ok.events()]

    assert asyncio.run(late()) == ev_ok
    eng.close()


def test_step_cached_and_skipped_events():
    eng = _engine(max_workers=2)
    calls = {"n": 0}

    def expensive():
        calls["n"] += 1
        return 42

    def build(name):
        wf = WorkflowIR(name)
        wf.add_job(Job(name="big", fn=expensive, outputs=["big:out"],
                       cacheable=True))
        return wf

    async def main():
        h1 = await eng.submit_async(build("c1"))
        await h1
        h2 = await eng.submit_async(build("c2"))
        return [e async for e in h2.events()], await h2

    evs, run2 = asyncio.run(main())
    assert calls["n"] == 1
    assert run2.steps["big"].status == StepStatus.CACHED
    assert any(e.type is EventType.STEP_CACHED and e.step == "big"
               for e in evs)
    _check_stream_invariants(evs)

    # skipped-by-condition step emits STEP_SKIPPED
    with couler.workflow("skipwf") as ir:
        a = couler.run_step(lambda: "no", step_name="a", cacheable=False)
        couler.when(couler.equal(a, "yes"),
                    lambda: couler.run_step(lambda: 1, step_name="b",
                                            cacheable=False))

    async def main2():
        h = await eng.submit_async(ir, optimize=False)
        return [e async for e in h.events()], await h

    evs2, run3 = asyncio.run(main2())
    assert run3.succeeded()
    assert run3.steps["b"].status == StepStatus.SKIPPED
    assert any(e.type is EventType.STEP_SKIPPED and e.step == "b"
               for e in evs2)
    eng.close()


# ---------------------------------------------------------------------------
# cooperative cancellation -> resumable run
# ---------------------------------------------------------------------------

def test_cancel_mid_flight_leaves_resumable_run():
    eng = _engine(max_workers=2)
    gate = threading.Event()
    counts = {0: 0, 1: 0, 2: 0, 3: 0}

    def mk(i, wait=False):
        def fn(*a):
            counts[i] += 1
            if wait:
                assert gate.wait(10)
            return i
        return fn

    wf = chain_wf("cxl", 4, fns={0: mk(0), 1: mk(1, wait=True),
                                 2: mk(2), 3: mk(3)})

    async def main():
        h = await eng.submit_async(wf, optimize=False)
        async for ev in h.events():
            if ev.type is EventType.STEP_STARTED and ev.step == "s1":
                # cancel while s1 is executing, THEN let it finish: the
                # running step completes, s2/s3 must never launch
                assert h.cancel()
                gate.set()
            if ev.terminal:
                term = ev
        return await h, term

    run, term = asyncio.run(main())
    assert term.status == "Cancelled" and run.status == "Cancelled"
    assert run.steps["s0"].status == StepStatus.SUCCEEDED
    assert run.steps["s1"].status == StepStatus.SUCCEEDED
    assert run.steps["s2"].status == StepStatus.PENDING
    assert run.steps["s3"].status == StepStatus.PENDING

    run2 = eng.resume(run)
    assert run2.succeeded()
    assert counts[0] == 1 and counts[1] == 1      # not re-executed
    assert counts[2] == 1 and counts[3] == 1      # ran exactly once now
    eng.close()


def test_cancel_while_queued_never_starts():
    # one in-flight-step slot: h0's gate-blocked step holds it, so h1's
    # first step is parked at the semaphore when the cancel lands -> it
    # must observe the flag and never launch
    eng = _engine(max_workers=2, max_inflight_steps=1)
    gate = threading.Event()
    wf_block = chain_wf("blk", 1, fns={0: lambda *a: gate.wait(10) and 0})

    async def main():
        h0 = await eng.submit_async(wf_block, optimize=False)
        h1 = await eng.submit_async(chain_wf("q", 2), optimize=False)
        h1.cancel()
        gate.set()
        r0, r1 = await h0, await h1
        return r0, r1, [e async for e in h1.events()]

    run0, run1, evs1 = asyncio.run(main())
    assert run0.succeeded()
    assert run1.status == "Cancelled"
    assert all(r.status == StepStatus.PENDING for r in run1.steps.values())
    assert not any(e.is_step_event for e in evs1)    # nothing ever started
    eng.close()


# ---------------------------------------------------------------------------
# backpressure + multi-tenant fairness
# ---------------------------------------------------------------------------

def test_admission_queue_wrr_order_and_bounds():
    q = AdmissionQueue(max_depth_per_tenant=4, max_total=16,
                       weights={"A": 2, "B": 1})

    def item(t, i):
        return AdmittedItem(wf=WorkflowIR(f"{t}{i}"), tenant=t)

    for i in range(4):
        q.offer(item("A", i))
    for i in range(2):
        q.offer(item("B", i))
    order = [it.wf.name for it in q.drain()]
    assert order == ["A0", "A1", "B0", "A2", "A3", "B1"]   # classic WRR 2:1
    assert len(q) == 0

    for i in range(4):
        q.offer(item("C", i))
    with pytest.raises(QueueFull) as exc:
        q.offer(item("C", 9))
    assert exc.value.tenant == "C" and exc.value.depth == 4
    assert q.try_offer(item("D", 0))        # other tenants unaffected
    assert q.stats["shed"] == 1


def test_gateway_sheds_load_when_tenant_queue_full():
    # one workflow slot: the gate-blocked run pins the pump, so later
    # offers pile into tenant T's depth-2 queue and overflow sheds
    gate = threading.Event()
    eng = _engine(max_workers=2, max_inflight_workflows=1,
                  admission=AdmissionQueue(max_depth_per_tenant=2,
                                           max_total=64))

    async def main():
        h0 = await eng.submit_async(
            chain_wf("full-0", 1, fns={0: lambda *a: gate.wait(10) and 0}),
            optimize=False, tenant="T")
        handles, shed = [h0], 0
        for i in range(1, 10):
            try:
                handles.append(await eng.submit_async(
                    chain_wf(f"full-{i}", 1, sleep=0.001),
                    optimize=False, tenant="T"))
            except QueueFull:
                shed += 1
        gate.set()
        runs = await asyncio.gather(*handles)
        return shed, runs

    shed, runs = asyncio.run(main())
    assert shed >= 1                        # backpressure actually bit
    assert all(r.succeeded() for r in runs)  # admitted ones all completed
    eng.close()


# ---------------------------------------------------------------------------
# stress: >=200 concurrent submissions, one shared tiered store
# ---------------------------------------------------------------------------

def test_stress_200_concurrent_share_one_store_bounded_steps():
    store = TieredCacheStore(
        tiers=[CacheTier(mem_spec(64 << 10)), CacheTier(ssd_spec(256 << 10)),
               CacheTier(remote_spec(1 << 20))], policy=CoulerPolicy())
    eng = _engine(max_workers=8, cache=store, max_inflight_steps=6,
                  promote_interval_s=0.01)
    running = {"cur": 0, "peak": 0}
    lock = threading.Lock()

    def work(i, tag):
        with lock:
            running["cur"] += 1
            running["peak"] = max(running["peak"], running["cur"])
        time.sleep(0.001)
        with lock:
            running["cur"] -= 1
        return (i, tag)

    def build(i):
        wf = WorkflowIR(f"stress-{i}")
        wf.add_job(Job(name="a", fn=work, args=(i, "a"), cacheable=True,
                       outputs=["a:out"], est_mem_bytes=256))
        wf.add_job(Job(name="b", fn=work, args=(i, "b"), cacheable=True,
                       outputs=["b:out"], est_mem_bytes=256))
        wf.add_edge("a", "b")
        return wf

    async def main():
        handles = []
        for i in range(210):
            handles.append(await eng.submit_async(
                build(i), tenant=f"t{i % 7}", block=True))
        return await asyncio.gather(*handles)

    runs = asyncio.run(asyncio.wait_for(main(), timeout=300))
    assert len(runs) == 210
    assert all(r.succeeded() for r in runs)
    assert running["peak"] <= 6             # bounded in-flight steps held
    assert eng.gateway.stats["peak_inflight_steps"] <= 6
    store.check_invariants()                # shared store stayed consistent
    assert store.stats["admitted"] > 0
    eng.close()


# ---------------------------------------------------------------------------
# background promotion task (gateway-owned)
# ---------------------------------------------------------------------------

def test_background_promote_task_runs_and_stops_on_close():
    store = TieredCacheStore(
        tiers=[CacheTier(mem_spec(400)), CacheTier(ssd_spec(1000)),
               CacheTier(remote_spec(4000))], policy=CoulerPolicy())
    assert store.auto_promote_every == 0     # hit-count fallback disabled
    eng = _engine(max_workers=2, cache=store, promote_interval_s=0.02)

    def build(i):
        wf = WorkflowIR(f"promo-{i}")
        wf.add_job(Job(name="a", fn=lambda i=i: bytes(120), cacheable=True,
                       outputs=["a:out"], est_mem_bytes=120))
        return wf

    for i in range(6):
        assert eng.submit(build(i)).succeeded()
    deadline = time.time() + 5
    while store.stats["promote_passes"] == 0 and time.time() < deadline:
        time.sleep(0.02)
    assert store.stats["promote_passes"] >= 1    # driven by the gateway task

    eng.close()
    assert not eng._gateway._thread.is_alive()   # loop joined cleanly
    passes = store.stats["promote_passes"]
    time.sleep(0.1)
    assert store.stats["promote_passes"] == passes   # task actually stopped


def test_single_tier_cache_gets_no_promote_task():
    eng = _engine(max_workers=2, promote_interval_s=0.01)
    assert eng.submit(chain_wf("nt", 1)).succeeded()
    assert eng.gateway._promote_task is None
    eng.close()


# ---------------------------------------------------------------------------
# persist collision regression
# ---------------------------------------------------------------------------

def test_persist_no_collision_same_second(tmp_path):
    wf = WorkflowIR("dup")
    r1, r2 = WorkflowRun(workflow=wf), WorkflowRun(workflow=wf)
    r2.submitted = r1.submitted              # same wall-clock second
    f1 = r1.persist(str(tmp_path))
    f2 = r2.persist(str(tmp_path))
    assert f1 != f2
    assert f1.exists() and f2.exists()
    assert r1.run_id != r2.run_id


# ---------------------------------------------------------------------------
# generic fallback + admission-queue feed of the cluster engine
# ---------------------------------------------------------------------------

def test_base_submit_async_fallback_multicluster():
    wf = WorkflowIR("mc-async")
    for i in range(4):
        wf.add_job(Job(name=f"j{i}", est_time_s=1.0,
                       resources=Resources(cpu=2)))
    eng = MultiClusterEngine(clusters=[
        Cluster("a", cpu=16, mem_bytes=1 << 40)])

    async def main():
        h = await eng.submit_async(wf)
        evs = [e async for e in h.events()]
        return evs, await h

    evs, run = asyncio.run(main())
    assert run.succeeded()
    assert [e.type for e in evs] == [EventType.WORKFLOW_ADMITTED,
                                     EventType.WORKFLOW_DONE]
    assert evs[-1].status == "Succeeded"


def test_submit_admitted_drains_queue_in_wrr_order():
    q = AdmissionQueue(weights={"heavy": 2})
    for i in range(4):
        wf = WorkflowIR(f"h{i}")
        wf.add_job(Job(name="j", est_time_s=1.0))
        q.offer(AdmittedItem(wf=wf, tenant="heavy", priority=0))
    for i in range(2):
        wf = WorkflowIR(f"l{i}")
        wf.add_job(Job(name="j", est_time_s=1.0))
        q.offer(AdmittedItem(wf=wf, tenant="light", priority=0))
    eng = MultiClusterEngine(clusters=[
        Cluster("a", cpu=64, mem_bytes=1 << 40)])
    runs = eng.submit_admitted(q)
    assert len(runs) == 6 and len(q) == 0
    assert all(r.succeeded() for r in runs.values())
    assert eng.metrics["completed_workflows"] == 6
    assert set(eng.quotas) == {"heavy", "light"}   # tenants became users

    # duplicate workflow names across tenants: explicit error, not a
    # silent wrong-run handoff (submit_many results are keyed by name)
    q2 = AdmissionQueue()
    for t in ("t1", "t2"):
        wf = WorkflowIR("same-name")
        wf.add_job(Job(name="j", est_time_s=1.0))
        q2.offer(AdmittedItem(wf=wf, tenant=t))
    with pytest.raises(ValueError, match="duplicate workflow name"):
        eng.submit_admitted(q2)


# ---------------------------------------------------------------------------
# couler API entry points
# ---------------------------------------------------------------------------

def test_couler_run_async_and_stream():
    eng = _engine(max_workers=2)
    with couler.workflow("api-async") as ir:
        a = couler.run_step(lambda: 2, step_name="a", cacheable=False)
        couler.run_step(lambda x: x * 3, a, step_name="b", cacheable=False)

    async def main():
        h = await couler.run_async(submitter=eng, workflow_ir=ir)
        return await h

    run = asyncio.run(main())
    assert run.succeeded() and run.artifacts["b:out"] == 6

    with couler.workflow("api-stream") as ir2:
        couler.run_step(lambda: 7, step_name="only", cacheable=False)

    async def main2():
        return [ev async for ev in couler.stream(submitter=eng,
                                                 workflow_ir=ir2)]

    evs = asyncio.run(main2())
    _check_stream_invariants(evs)
    assert evs[-1].status == "Succeeded"
    eng.close()
