"""End-to-end behaviour tests: NL -> workflow -> optimized execution with
caching / split / fault tolerance — the paper's full loop on a real (small)
JAX training payload."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import couler
from repro.core.autosplit import Budget
from repro.core.caching import CacheStore, CoulerPolicy
from repro.core.engines.base import StepStatus
from repro.core.engines.local import LocalEngine
from repro.core.nl2wf import nl_to_workflow
from repro.core.llm import TemplateLLM


def test_nl_to_execution_end_to_end():
    """NL description -> generated COULER code -> IR -> local engine run."""
    res = nl_to_workflow(
        "Load the dataset named demo, preprocess it, train the ResNet and "
        "ViT models, evaluate accuracy, select the best model and generate "
        "a report.", llm=TemplateLLM("gpt-4"), temperature=0.0, seed=3)
    assert res.error is None
    run = LocalEngine().submit(res.workflow)
    assert run.succeeded(), run.counts()
    assert any(k.startswith("select-best") for k in run.artifacts)


def test_ml_workflow_with_real_training_and_cache_reuse():
    """Iterative-development loop: data prep cached across submissions,
    second run skips tokenization (the paper's core §IV.A motivation)."""
    from repro.configs import get_arch, reduced
    from repro.training import train as TR

    spec = get_arch("stablelm-1.6b")
    cfg = reduced(spec.model).replace(param_dtype="float32",
                                      compute_dtype="float32")
    tcfg = spec.train.__class__(optimizer="adamw", learning_rate=1e-3,
                                remat="none")
    prep_calls = {"n": 0}

    def tokenize():
        prep_calls["n"] += 1
        rng = np.random.default_rng(0)
        return rng.integers(0, cfg.vocab_size, (8, 33)).astype(np.int32)

    def train(data, steps=3):
        state = TR.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        step = jax.jit(TR.make_train_step(cfg, tcfg))
        losses = []
        for _ in range(steps):
            batch = {"tokens": jnp.asarray(data[:, :-1]),
                     "targets": jnp.asarray(data[:, 1:])}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        return losses

    def evaluate(losses):
        return losses[-1] < losses[0]

    cache = CacheStore(capacity_bytes=1 << 24, policy=CoulerPolicy())
    eng = LocalEngine(cache=cache, enable_speculation=False)

    def build():
        with couler.workflow("train-pipeline") as ir:
            d = couler.run_step(tokenize, step_name="tokenize")
            t = couler.run_step(train, d, step_name="train")
            couler.run_step(evaluate, t, step_name="eval")
        return ir

    r1 = eng.submit(build())
    assert r1.succeeded()
    assert r1.artifacts["eval:out"] is True          # loss went down
    r2 = eng.submit(build())
    assert r2.steps["tokenize"].status == StepStatus.CACHED
    assert prep_calls["n"] == 1


def test_big_workflow_split_and_execute():
    """A 300-step workflow is auto-split (Alg. 3) and still executes
    correctly through the engine."""
    with couler.workflow("big") as ir:
        prev = couler.run_step(lambda: 0, step_name="s0", cacheable=False)
        for i in range(1, 300):
            prev = couler.run_step(lambda x: x + 1, prev,
                                   step_name=f"s{i}", cacheable=False)
    eng = LocalEngine(budget=Budget(steps=64))
    run = eng.submit(ir, optimize=True)
    assert run.succeeded()
    assert run.artifacts["s299:out"] == 299


def test_model_selection_workflow_automl():
    """Paper App. F: concurrent model training + selection."""
    def train_model(kind):
        return {"xgboost": 0.91, "lightgbm": 0.93}[kind]

    with couler.workflow("automl") as ir:
        outs = couler.concurrent([
            lambda: couler.run_step(train_model, "xgboost",
                                    step_name="train-xgboost"),
            lambda: couler.run_step(train_model, "lightgbm",
                                    step_name="train-lgbm"),
        ])
        best = couler.run_step(lambda a, b: "lightgbm" if b > a else "xgboost",
                               outs[0], outs[1], step_name="select")
    run = LocalEngine().submit(ir)
    assert run.artifacts["select:out"] == "lightgbm"
