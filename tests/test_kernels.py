"""Per-kernel shape/dtype sweeps, assert_allclose vs the ref.py oracles
(interpret mode on CPU; the kernels TARGET TPU via BlockSpecs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("S,D,blocks", [(128, 64, (128, 128)),
                                        (256, 64, (128, 128)),
                                        (256, 128, (128, 64)),
                                        (512, 32, (128, 128))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, D, blocks, dtype):
    key = jax.random.PRNGKey(S + D)
    q = _rand(key, (2, S, D), dtype)
    k = _rand(jax.random.fold_in(key, 1), (2, S, D), dtype)
    v = _rand(jax.random.fold_in(key, 2), (2, S, D), dtype)
    o = ops.flash_attention(q, k, v, block_q=blocks[0], block_k=blocks[1])
    o_ref = ref.reference_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_non_causal():
    key = jax.random.PRNGKey(9)
    q = _rand(key, (1, 128, 32), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (1, 128, 32), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (1, 128, 32), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=False)
    o_ref = ref.reference_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)


def test_flash_attention_mixed_v_dim():
    """MLA-style: qk head dim != v head dim."""
    key = jax.random.PRNGKey(10)
    q = _rand(key, (2, 128, 48), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (2, 128, 48), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (2, 128, 32), jnp.float32)
    o = ops.flash_attention(q, k, v)
    o_ref = ref.reference_attention(q, k, v)
    assert o.shape == (2, 128, 32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)


@pytest.mark.parametrize("S,P,N,chunk", [(128, 16, 32, 32), (256, 32, 16, 64),
                                         (128, 64, 64, 128), (64, 8, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(S, P, N, chunk, dtype):
    key = jax.random.PRNGKey(S * P + N)
    x = _rand(key, (2, S, P), dtype)
    dA = (-jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(key, 1), (2, S)))).astype(jnp.float32)
    Bm = (_rand(jax.random.fold_in(key, 2), (2, S, N), dtype) * 0.5).astype(dtype)
    Cm = (_rand(jax.random.fold_in(key, 3), (2, S, N), dtype) * 0.5).astype(dtype)
    y = ops.ssd_scan(x, dA, Bm, Cm, chunk=chunk)
    y_ref, _ = ref.reference_ssd(x, dA, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=TOL[dtype] * 10, rtol=TOL[dtype] * 10)


@pytest.mark.parametrize("R,D,br", [(256, 64, 128), (512, 128, 256),
                                    (128, 96, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(R, D, br, dtype):
    key = jax.random.PRNGKey(R + D)
    x = _rand(key, (R, D), dtype)
    s = _rand(jax.random.fold_in(key, 1), (D,), jnp.float32)
    y = ops.rmsnorm(x, s, block_rows=br)
    y_ref = ref.reference_rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_model_blockwise_attention_vs_oracle():
    """The model's jnp blockwise (flash-semantics) attention vs oracle."""
    from repro.models.attention import blockwise_attention
    key = jax.random.PRNGKey(11)
    B, H, S, hd = 2, 3, 200, 16        # S deliberately NOT block-divisible
    q = jax.random.normal(key, (B, H, S, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = blockwise_attention(q, k, v, pos, pos, block=64)
    o_ref = ref.reference_attention(q.reshape(B * H, S, hd),
                                    k.reshape(B * H, S, hd),
                                    v.reshape(B * H, S, hd))
    np.testing.assert_allclose(np.asarray(out.reshape(B * H, S, hd)),
                               np.asarray(o_ref), atol=3e-5, rtol=3e-5)


def test_model_ssd_chunked_vs_oracle():
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(12)
    B, S, H, P, G, N = 2, 64, 4, 16, 1, 16
    xh = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H)))
    a_log = jnp.zeros((H,))
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, G, N)) * 0.5
    y, state = ssd_chunked(xh, dt, a_log, Bm, Cm, 16)
    A = -jnp.exp(a_log)
    dA = (dt * A[None, None]).transpose(0, 2, 1).reshape(B * H, S)
    xb = (xh * dt[..., None]).transpose(0, 2, 1, 3).reshape(B * H, S, P)
    Bo = jnp.repeat(Bm, H, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Co = jnp.repeat(Cm, H, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    y_ref, st_ref = ref.reference_ssd(xb, dA, Bo, Co)
    np.testing.assert_allclose(
        np.asarray(y.transpose(0, 2, 1, 3).reshape(B * H, S, P)),
        np.asarray(y_ref), atol=3e-5, rtol=3e-5)
    # final states must match too (decode handoff correctness)
    np.testing.assert_allclose(
        np.asarray(state.transpose(0, 1, 3, 2).reshape(B * H, N, P)),
        np.asarray(st_ref), atol=3e-5, rtol=3e-5)
