"""Span derivation under chaos (satellite of the observability PR).

Replays seeded fault plans through an observed gateway engine and asserts
the collector reconstructs the fault story exactly from the event stream:
one ``retry`` segment per ``STEP_RETRY`` event (with ``WORKER_LOST``
causes attached where a loss preceded the retry), one
``readmission-backoff`` segment per ``WORKFLOW_REQUEUED``, no span leaks
(every builder finalized, every span closed), and a makespan partition
that still sums exactly despite retries and requeues.
"""
import time

import pytest

from repro.core import couler
from repro.core.caching import CacheStore
from repro.core.engines.local import LocalEngine
from repro.core.faults import FaultPlan, ReadmissionPolicy
from repro.core.gateway import EventType


def _engine(**kw):
    kw.setdefault("cache", CacheStore())
    kw.setdefault("enable_speculation", False)
    kw.setdefault("check_events", True)
    kw.setdefault("retry_backoff_s", 0.001)
    kw.setdefault("retry_backoff_max_s", 0.01)
    return LocalEngine(**kw)


def _chain(name, sleep=0.0):
    with couler.workflow(name) as ir:
        a = couler.run_step(lambda: (time.sleep(sleep), 2)[1], step_name="a")
        b = couler.run_step(lambda x: (time.sleep(sleep), x * 3)[1], a,
                            step_name="b")
        couler.run_step(lambda x: x + 1, b, step_name="c")
    return ir


def _fault_story(evs):
    retries = [e for e in evs if e.type is EventType.STEP_RETRY]
    losses = [e for e in evs if e.type is EventType.WORKER_LOST]
    requeues = [e for e in evs if e.type is EventType.WORKFLOW_REQUEUED]
    return retries, losses, requeues


def test_retry_segments_match_seeded_fault_plan():
    plan = FaultPlan(seed=9, crash_rate=0.25, permanent_rate=0.0,
                     worker_loss_rate=0.1, max_failures_per_site=4)
    eng = _engine(fault_plan=plan)
    try:
        c = couler.observe(eng)
        handle = eng.gateway.submit_nowait(_chain("chaos1"), block=True)
        run = handle.result()
        assert run.succeeded()
        retries, losses, _ = _fault_story(handle.events_so_far())
        assert retries, "seed 9 must inject at least one retry"
        tree = c.tree(run.run_id)
        segs = tree.retry_segments
        assert len(segs) == len(retries)
        # a WORKER_LOST preceding a step's retry becomes that segment's
        # cause; plain crashes keep the generic STEP_RETRY cause (a step
        # may carry both kinds across its attempts)
        assert {seg.cause for seg, _ in segs} <= \
            {"WORKER_LOST", "STEP_RETRY"}
        assert sum(1 for seg, _ in segs if seg.cause == "WORKER_LOST") == \
            len(losses)
        assert {step for seg, step in segs
                if seg.cause == "WORKER_LOST"} == {e.step for e in losses}
        assert c.open_run_ids == []
        for sp in tree.steps:
            assert sp.end is not None, f"span {sp.step} left open"
    finally:
        eng.close()


def test_readmission_backoff_segments_reconstruct_exactly():
    # every attempt crashes until the cap: the in-run retry budget
    # exhausts, the workflow requeues with backoff, then converges
    plan = FaultPlan(seed=1, crash_rate=1.0, max_failures_per_site=5)
    eng = _engine(fault_plan=plan,
                  readmission=ReadmissionPolicy(base_backoff_s=0.02,
                                                max_backoff_s=0.1))
    try:
        c = couler.observe(eng)
        t0 = time.time()
        handle = eng.gateway.submit_nowait(_chain("chaos2", sleep=0.005),
                                           block=True)
        run = handle.result()
        wall = time.time() - t0
        assert run.succeeded()
        retries, _, requeues = _fault_story(handle.events_so_far())
        assert requeues, "seed 1 at rate 1.0 must requeue at least once"
        tree = c.tree(run.run_id)
        backoffs = [s for s in tree.segments
                    if s.kind == "readmission-backoff"]
        assert len(backoffs) == len(requeues)
        for seg in backoffs:
            assert seg.end >= seg.start and seg.cause == "WORKFLOW_REQUEUED"
        assert len(tree.retry_segments) == len(retries)
        # requeue epochs recorded; re-run spans carry the later epoch
        assert max(sp.epoch for sp in tree.steps) == len(requeues)
        # spans open at the requeue were closed as Reverted, none leaked
        assert c.open_run_ids == []
        statuses = {sp.status for sp in tree.steps}
        assert "Reverted" not in statuses or \
            all(sp.end is not None for sp in tree.steps)
        # attribution still partitions the makespan exactly, and the
        # backoff windows show up as their own bucket
        rep = run.report()
        assert rep.attributed_s == pytest.approx(rep.makespan_s, abs=1e-9)
        assert rep.totals.get("readmission-backoff", 0) > 0
        assert rep.reconciles(wall), \
            f"attributed {rep.attributed_s:.4f}s vs wall {wall:.4f}s"
    finally:
        eng.close()


def test_worker_loss_cause_annotated():
    plan = FaultPlan(seed=2, worker_loss_rate=1.0, max_failures_per_site=1)
    eng = _engine(fault_plan=plan)
    try:
        c = couler.observe(eng)
        handle = eng.gateway.submit_nowait(_chain("chaos3"), block=True)
        run = handle.result()
        assert run.succeeded()
        _, losses, _ = _fault_story(handle.events_so_far())
        assert len(losses) == 3               # one per site, capped at 1
        tree = c.tree(run.run_id)
        assert [c_["type"] for c_ in tree.causes].count("WORKER_LOST") == 3
        for seg, step in tree.retry_segments:
            assert seg.cause == "WORKER_LOST"
    finally:
        eng.close()


def test_failed_run_spans_closed_and_counted():
    plan = FaultPlan(seed=0, permanent_rate=1.0, max_failures_per_site=1)
    eng = _engine(fault_plan=plan,
                  readmission=ReadmissionPolicy(base_backoff_s=0.001,
                                                max_backoff_s=0.01,
                                                max_readmissions=0))
    try:
        c = couler.observe(eng)
        run = eng.submit(_chain("chaos4"))
        assert run.status == "Failed"
        tree = c.tree(run.run_id)
        assert tree.status == "Failed"
        assert c.open_run_ids == []
        failed = [sp for sp in tree.steps if sp.status == "Failed"]
        assert failed and failed[0].segments[-1].cause  # carries the error
        assert c.registry.get_value("obs_runs_total", status="Failed") == 1
    finally:
        eng.close()


def test_identical_plan_identical_span_story():
    # determinism end to end: same seed -> same retry/requeue counts in
    # the derived trees, not just in the raw event stream
    def story():
        plan = FaultPlan(seed=11, crash_rate=0.3, worker_loss_rate=0.2,
                         max_failures_per_site=3)
        eng = _engine(fault_plan=plan,
                      readmission=ReadmissionPolicy(base_backoff_s=0.001,
                                                    max_backoff_s=0.01))
        try:
            c = couler.observe(eng)
            run = eng.submit(_chain("chaos5"))
            t = c.tree(run.run_id)
            return (run.status, len(t.retry_segments),
                    sorted((s.step, s.status, s.attempts) for s in t.steps))
        finally:
            eng.close()

    assert story() == story()
