"""Multi-device checks run in a subprocess with 8 fake host devices.
Invoked by tests/test_distributed.py; prints one OK line per check."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

assert len(jax.devices()) == 8, jax.devices()


def check_mesh_and_shard():
    from repro.launch.mesh import make_mesh
    from repro.sharding.ctx import use_mesh, shard
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = {"batch": ("data",), "mlp": "model"}

    @jax.jit
    def f(x):
        return shard(jnp.tanh(x), "batch", "mlp")

    with use_mesh(mesh, rules):
        y = f(jnp.ones((4, 8)))
        comp = jax.jit(f).lower(jax.ShapeDtypeStruct((4, 8), jnp.float32)).compile()
    assert y.shape == (4, 8)
    print("OK mesh_and_shard")


def check_reduced_arch_sharded_train():
    """A reduced MoE arch trains SPMD on a (2,4) mesh — exercises the
    shard_map EP path with real execution (not just compile)."""
    from repro.configs import get_arch, reduced
    from repro.launch.mesh import make_mesh
    from repro.sharding.ctx import use_mesh
    from repro.sharding.rules import (batch_specs, opt_state_specs,
                                      param_specs, to_named)
    from repro.training import train as TR

    spec = get_arch("olmoe-1b-7b")
    cfg = reduced(spec.model).replace(param_dtype="float32",
                                      compute_dtype="float32")
    tcfg = spec.train.__class__(optimizer="adamw", remat="none")
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = {"batch": ("data",), "heads": "model", "kv_heads": "model",
             "mlp": "model", "vocab": "model", "expert": "model",
             "embed": None, "lora": None, "tp": "model", "seq_q": "model",
             "kv_seq": "model", "ssm_inner": "model", "ssm_heads": "model"}
    with use_mesh(mesh, rules):
        state = TR.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        state_sh = {
            "params": to_named(param_specs(state["params"], mesh, rules, cfg), mesh),
            "opt": to_named(opt_state_specs(state["opt"], mesh, rules, cfg), mesh),
            "step": NamedSharding(mesh, P()),
        }
        state = jax.device_put(state, state_sh)
        batch = {"tokens": jnp.ones((4, 32), jnp.int32),
                 "targets": jnp.ones((4, 32), jnp.int32)}
        bsh = to_named(batch_specs(batch, mesh, rules), mesh)
        batch = jax.device_put(batch, bsh)
        # out_shardings must pin the state: GSPMD otherwise re-shards the
        # (2,64)/(64,) norm scales onto 'model' on output, and the second
        # call fails the pjit arg-sharding check against state_sh
        step = jax.jit(TR.make_train_step(cfg, tcfg),
                       in_shardings=(state_sh, bsh),
                       out_shardings=(state_sh, None))
        state, m = step(state, batch)
        l1 = float(m["loss"])
        state, m = step(state, batch)
        l2 = float(m["loss"])
    assert np.isfinite(l1) and np.isfinite(l2) and l2 < l1
    print("OK sharded_moe_train")


def check_moe_ep_matches_local():
    """EP shard_map output == single-device local dispatch output."""
    from repro.configs import get_arch, reduced
    from repro.launch.mesh import make_mesh
    from repro.models import moe as M
    from repro.sharding.ctx import use_mesh

    cfg = reduced(get_arch("olmoe-1b-7b").model).replace(
        param_dtype="float32", compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    y_local, aux_local = M.apply_moe(p, cfg, x)          # no mesh -> local

    mesh = make_mesh((2, 4), ("data", "model"))
    rules = {"batch": ("data",), "expert": "model"}
    with use_mesh(mesh, rules):
        y_ep, aux_ep = jax.jit(lambda pp, xx: M.apply_moe(pp, cfg, xx))(p, x)
    err = float(jnp.max(jnp.abs(y_local - y_ep)))
    assert err < 2e-4, err
    print("OK moe_ep_matches_local", err)


def check_moe_a2a_matches_local():
    """all-to-all dispatch EP (§Perf strategy) == local dispatch."""
    from repro.configs import get_arch, reduced
    from repro.launch.mesh import make_mesh
    from repro.models import moe as M
    from repro.sharding.ctx import use_mesh

    cfg = reduced(get_arch("olmoe-1b-7b").model).replace(
        param_dtype="float32", compute_dtype="float32", capacity_factor=4.0)
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
    y_local, _ = M.apply_moe(p, cfg, x)
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = {"batch": ("data",), "expert": "model"}
    with use_mesh(mesh, rules, strategy="moe_a2a"):
        y_a2a, _ = jax.jit(lambda pp, xx: M.apply_moe(pp, cfg, xx))(p, x)
    err = float(jnp.max(jnp.abs(y_local - y_a2a)))
    assert err < 2e-4, err
    print("OK moe_a2a_matches_local", err)


def check_compressed_psum():
    from repro.launch.mesh import make_mesh
    from repro.training.compression import compressed_psum_mean
    from repro.sharding.compat import shard_map
    mesh = make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))

    def f(gl):
        return compressed_psum_mean(gl[0], "data")[None]

    red = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                    check_vma=False)(g)
    exact = jnp.mean(g, axis=0)
    rel = float(jnp.max(jnp.abs(red[0] - exact)) / (jnp.max(jnp.abs(exact)) + 1e-9))
    assert rel < 0.05, rel
    print("OK compressed_psum rel_err", rel)


def check_compression_wire_bytes():
    """HLO of the int8 reduce must move ~4x fewer collective bytes than a
    plain fp32 all-reduce of the same tensor."""
    from repro.launch.mesh import make_mesh
    from repro.roofline.analysis import analyze_hlo
    from repro.training.compression import compressed_psum_mean
    from repro.sharding.compat import shard_map
    mesh = make_mesh((8,), ("data",))
    n = 1 << 16

    def plain(gl):
        return jax.lax.pmean(gl[0], "data")[None]

    def comp(gl):
        return compressed_psum_mean(gl[0], "data")[None]

    sds = jax.ShapeDtypeStruct((8, n), jnp.float32)
    def wire(fn):
        c = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_vma=False)
                    ).lower(sds).compile()
        return analyze_hlo(c.as_text()).coll_bytes
    wp, wc = wire(plain), wire(comp)
    assert wc < wp / 2.5, (wp, wc)
    print(f"OK compression_wire_bytes plain={wp:.0f} int8={wc:.0f} "
          f"ratio={wp/wc:.2f}x")


def check_pipeline_parallel():
    from repro.launch.mesh import make_mesh
    from repro.sharding.pipeline_parallel import pipeline_apply
    mesh = make_mesh((4,), ("stage",))
    S, M, mb, D = 4, 8, 2, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (S, D, D)) * 0.3

    def stage_fn(params, x):
        return jnp.tanh(x @ params["w"])

    run = pipeline_apply(stage_fn, mesh, num_microbatches=M)
    x = jax.random.normal(jax.random.fold_in(key, 1), (M, mb, D))
    y = run({"w": w}, x)
    # reference: sequential application of all 4 stages
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    err = float(jnp.max(jnp.abs(y - ref)))
    assert err < 1e-5, err
    # autodiff through the pipeline
    g = jax.grad(lambda ww: jnp.sum(run({"w": ww}, x) ** 2))(w)
    assert np.isfinite(np.asarray(g)).all()
    print("OK pipeline_parallel err", err)


def check_elastic_restore():
    """Checkpoint saved from a (2,4) mesh restores onto a (4,2) mesh."""
    import tempfile
    from repro.launch.mesh import make_mesh
    from repro.training.checkpoint import CheckpointManager
    state = {"w": jnp.arange(64.0).reshape(8, 8)}
    m1 = make_mesh((2, 4), ("data", "model"))
    st1 = jax.device_put(state, NamedSharding(m1, P("data", "model")))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, st1)
        m2 = make_mesh((4, 2), ("data", "model"))
        sh2 = {"w": NamedSharding(m2, P("data", "model"))}
        back = mgr.restore(like=state, shardings=sh2)
        assert back["w"].sharding.mesh.shape["data"] == 4
        np.testing.assert_allclose(np.asarray(back["w"]),
                                   np.asarray(state["w"]))
    print("OK elastic_restore")


def check_train_driver():
    """launch.train end-to-end on an in-process 8-device mesh (resume too)."""
    import shutil
    shutil.rmtree("out/_driver_ckpt", ignore_errors=True)
    from repro.launch.train import main as train_main
    train_main(["--arch", "stablelm-1.6b", "--steps", "6", "--mesh", "2x4",
                "--batch", "8", "--seq", "16",
                "--ckpt-dir", "out/_driver_ckpt", "--ckpt-every", "3",
                "--log-every", "3"])
    train_main(["--arch", "stablelm-1.6b", "--steps", "9", "--mesh", "2x4",
                "--batch", "8", "--seq", "16",
                "--ckpt-dir", "out/_driver_ckpt", "--ckpt-every", "3",
                "--log-every", "3"])  # resumes from step 6
    import os
    steps = sorted(os.listdir("out/_driver_ckpt"))
    assert any("00000009" in s for s in steps), steps
    shutil.rmtree("out/_driver_ckpt", ignore_errors=True)
    print("OK train_driver")


if __name__ == "__main__":
    check_mesh_and_shard()
    check_reduced_arch_sharded_train()
    check_moe_ep_matches_local()
    check_moe_a2a_matches_local()
    check_compressed_psum()
    check_compression_wire_bytes()
    check_pipeline_parallel()
    check_elastic_restore()
    check_train_driver()
    print("ALL DISTRIBUTED OK")
