from repro.core import couler
from repro.core.engines.local import LocalEngine


def test_diamond_explicit_dag():
    with couler.workflow("diamond") as ir:
        def job(name):
            return couler.run_container(image="whalesay:latest",
                                        command=["cowsay"], args=[name],
                                        step_name=name,
                                        fn=lambda n=name: n.lower())
        couler.dag([
            [lambda: job("A")],
            [lambda: job("A"), lambda: job("B")],
            [lambda: job("A"), lambda: job("C")],
            [lambda: job("B"), lambda: job("D")],
            [lambda: job("C"), lambda: job("D")],
        ])
    assert set(ir.jobs) == {"A", "B", "C", "D"}
    assert ir.edges == {("A", "B"), ("A", "C"), ("B", "D"), ("C", "D")}
    run = LocalEngine().submit(ir)
    assert run.succeeded()


def test_implicit_dataflow_edges():
    with couler.workflow("flow") as ir:
        a = couler.run_step(lambda: 41, step_name="a")
        b = couler.run_step(lambda x: x + 1, a, step_name="b")
    assert ("a", "b") in ir.edges
    run = LocalEngine().submit(ir)
    assert run.artifacts["b:out"] == 42


def test_when_condition_skips():
    with couler.workflow("cond") as ir:
        r = couler.run_step(lambda: "tails", step_name="flip")
        couler.when(couler.equal(r, "heads"),
                    lambda: couler.run_step(lambda: "H", step_name="heads"))
        couler.when(couler.equal(r, "tails"),
                    lambda: couler.run_step(lambda: "T", step_name="tails"))
    run = LocalEngine().submit(ir)
    assert run.succeeded()
    assert run.steps["heads"].status.value == "Skipped"
    assert run.artifacts["tails:out"] == "T"


def test_exec_while_loops_until_condition():
    calls = {"n": 0}

    def flip():
        calls["n"] += 1
        return "heads" if calls["n"] >= 4 else "tails"

    with couler.workflow("loop") as ir:
        r = couler.run_step(flip, step_name="flip")
        couler.exec_while(couler.equal(r, "tails"), lambda: r)
    run = LocalEngine().submit(ir)
    assert run.artifacts["flip:out"] == "heads"
    assert calls["n"] == 4


def test_map_and_concurrent():
    with couler.workflow("mapc") as ir:
        outs = couler.map_(lambda x: couler.run_step(
            lambda v=x: v * 2, step_name=f"m{x}"), [1, 2, 3])
        couler.concurrent([
            lambda: couler.run_step(lambda: "p", step_name="p1"),
            lambda: couler.run_step(lambda: "q", step_name="p2"),
        ])
    assert len(ir.jobs) == 5
    run = LocalEngine().submit(ir)
    assert [run.artifacts[o.artifact] for o in outs] == [2, 4, 6]


def test_set_dependencies():
    with couler.workflow("deps") as ir:
        a = couler.run_step(lambda: 1, step_name="a")
        b = couler.run_step(lambda: 2, step_name="b")
        couler.set_dependencies(b, depends_on=[a])
    assert ("a", "b") in ir.edges


def test_paper_appendix_a_producer_consumer():
    """Paper Code 2: artifact passing between producer and consumer pods."""
    def producer(step_name):
        out = couler.create_parameter_artifact(path="/opt/hello_world.txt",
                                               is_global=True)
        return couler.run_container(
            image="docker/whalesay:latest",
            args=[f"echo -n hello world > {out.path}"],
            command=["bash", "-c"],
            step_name=step_name,
            fn=lambda *_: "hello world")

    def consumer(step_name, inp):
        return couler.run_container(
            image="docker/whalesay:latest", command=["cowsay"],
            args=[inp], step_name=step_name,
            fn=lambda x: f"said: {x}")

    with couler.workflow("prod-cons") as ir:
        out = producer("step1")
        consumer("step2", out)
    assert ("step1", "step2") in ir.edges
    run = LocalEngine().submit(ir)
    assert run.succeeded()
    assert run.artifacts["step2:out"] == "said: hello world"
