"""Continuous fleet telemetry (PR: time-series metrics, streaming anomaly
detection, SLO burn-rate alerting).

Pins this PR's contracts: the bounded ``TimeSeriesDB`` ring semantics and
JSONL round-trip, the OpenMetrics renderer/parser inverse pair, detector
unit behavior (robust z-score floors, storm hysteresis), multi-window SLO
burn + the admission-priority nudge, registry robustness
(``gauge_fn_errors_total``, ``drop``/``drop_labeled``), per-tenant label
GC in the admission queue, and the end-to-end in-band ``ALERT`` events —
deterministic under seeded chaos, absent on a clean corpus, validated by
the ``TraceChecker`` (invariant 9).
"""
import random
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import couler
from repro.core.analysis import TraceChecker
from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.engines.local import LocalEngine
from repro.core.faults import FaultPlan, ReadmissionPolicy
from repro.core.gateway import AdmissionQueue, AdmittedItem, EventType
from repro.core.ir import Job, Resources, WorkflowIR
from repro.core.obs import MetricsRegistry
from repro.core.obs.anomaly import (AnomalyMonitor, ReadmissionStormDetector,
                                    StragglerDetector)
from repro.core.obs.exposition import parse_openmetrics, render_openmetrics
from repro.core.obs.slo import SLO, SLOMonitor
from repro.core.obs.timeseries import TimeSeriesDB


def _engine(**kw):
    kw.setdefault("enable_speculation", False)
    kw.setdefault("check_events", True)
    return LocalEngine(**kw)


def _chain_wf(name, n=2, fn=None):
    wf = WorkflowIR(name)
    prev = None
    for j in range(n):
        wf.add_job(Job(name=f"s{j}", fn=fn or (lambda j=j: j), cacheable=False))
        if prev:
            wf.add_edge(prev, f"s{j}")
        prev = f"s{j}"
    return wf


# ---------------------------------------------------------------- TimeSeriesDB

class TestTimeSeriesDB:
    def test_ring_bound_and_latest(self):
        db = TimeSeriesDB(capacity=4)
        for i in range(10):
            db.sample({"x": float(i)}, ts=float(i))
        assert db.samples_taken == 10
        pts = db.window("x", 100.0, now=10.0)
        assert len(pts) == 4                       # ring kept the last 4
        assert [v for _, v in pts] == [6.0, 7.0, 8.0, 9.0]
        assert db.latest("x") == 9.0
        assert db.latest("missing") is None
        assert db.latest_ts() == 9.0

    def test_counter_delta_and_rate(self):
        db = TimeSeriesDB()
        for ts, v in [(0.0, 0.0), (5.0, 10.0), (10.0, 30.0)]:
            db.sample({"c_total": v}, ts=ts)
        assert db.delta("c_total", 100.0, now=10.0) == 30.0
        assert db.rate("c_total", 100.0, now=10.0) == pytest.approx(3.0)
        # window excludes old points
        assert db.delta("c_total", 6.0, now=10.0) == 20.0
        # <2 points in window -> 0
        assert db.delta("c_total", 1.0, now=10.0) == 0.0

    def test_quantile_and_mean(self):
        db = TimeSeriesDB()
        for i in range(10):
            db.sample({"g": float(i)}, ts=float(i))
        assert db.quantile("g", 0.5) == 5.0
        assert db.quantile("g", 0.99) == 9.0
        assert db.mean("g", 100.0, now=9.0) == pytest.approx(4.5)
        assert db.quantile("nope", 0.5) == 0.0

    def test_histogram_flattening_and_skips(self):
        db = TimeSeriesDB()
        db.sample({"h": {"count": 3, "sum": 1.5, "buckets": {"1": 3}},
                   "flag": True, "s": "str", "v": 2}, ts=1.0)
        assert db.names() == ["h:count", "h:sum", "v"]
        assert db.latest("h:count") == 3.0
        assert db.latest("h:sum") == 1.5

    def test_jsonl_round_trip(self, tmp_path):
        live = tmp_path / "live.jsonl"
        db = TimeSeriesDB(path=str(live))
        for i in range(5):
            db.sample({"a": float(i), "b_total": float(2 * i)}, ts=float(i))
        # live-append file reloads identically
        back = TimeSeriesDB.load_jsonl(str(live))
        assert back.samples_taken == 5
        assert back.names() == db.names()
        assert back.latest("b_total") == 8.0
        # explicit export of the ring contents also round-trips
        dump = tmp_path / "dump.jsonl"
        assert db.export_jsonl(str(dump)) == 5
        again = TimeSeriesDB.load_jsonl(str(dump))
        assert again.latest("a") == 4.0


# ----------------------------------------------------------------- exposition

class TestExposition:
    def test_render_parse_round_trip(self):
        reg = MetricsRegistry()
        reg.counter("runs_total").inc(3)
        reg.counter("runs_total", tenant="a").inc(2)
        reg.gauge("depth").set(7)
        reg.histogram("lat_s", buckets=(0.1, 1.0)).observe(0.5)
        text = render_openmetrics(reg)
        assert text.endswith("# EOF\n")
        assert "# TYPE runs counter" in text
        assert "# TYPE depth gauge" in text
        assert "# TYPE lat_s histogram" in text
        parsed = parse_openmetrics(text)
        assert parsed["runs_total"] == 3.0
        assert parsed['runs_total{tenant="a"}'] == 2.0
        assert parsed["depth"] == 7.0
        assert parsed['lat_s_bucket{le="1.0"}'] == 1.0
        assert parsed["lat_s_count"] == 1.0
        assert parsed["lat_s_sum"] == 0.5

    def test_parse_rejects_malformed(self):
        with pytest.raises(ValueError, match="EOF"):
            parse_openmetrics("x 1\n")
        with pytest.raises(ValueError, match="malformed"):
            parse_openmetrics("not a sample line !!\n# EOF\n")
        with pytest.raises(ValueError, match="after # EOF"):
            parse_openmetrics("# EOF\nx 1\n")


# ------------------------------------------------------------------ detectors

class TestStragglerDetector:
    def test_fires_on_outlier_with_context(self):
        det = StragglerDetector()
        for k in range(10):
            assert det.note("w/s", 0.01 + 0.001 * k, ts=float(k)) is None
        a = det.note("w/s", 0.5, ts=11.0)
        assert a is not None and a.scope == "w/s"
        assert a.value > a.threshold == det.z_threshold
        # the context re-derives the crossing independently
        z = 0.6745 * (a.context["duration_s"] - a.context["median_s"]) \
            / a.context["scale_s"]
        assert z == pytest.approx(a.value)

    def test_cold_site_never_fires(self):
        det = StragglerDetector(min_samples=8)
        for k in range(7):
            assert det.note("cold/s", 0.01, ts=float(k)) is None
        assert det.note("cold/s", 99.0, ts=8.0) is None   # still < min_samples

    def test_duration_floor_suppresses_micro_jitter(self):
        det = StragglerDetector(min_duration_s=0.05)
        for k in range(10):
            det.note("fast/s", 0.001, ts=float(k))
        # z is huge (MAD floor) but 4ms is below the absolute floor
        assert det.note("fast/s", 0.004, ts=11.0) is None

    def test_median_ratio_floor(self):
        det = StragglerDetector(median_ratio=2.0)
        for k in range(10):
            det.note("slow/s", 0.1, ts=float(k))
        # 1.5x the median: not a straggler even though z clears threshold
        assert det.note("slow/s", 0.15, ts=11.0) is None
        assert det.note("slow/s", 0.25, ts=12.0) is not None

    def test_history_is_bounded(self):
        det = StragglerDetector(history=16)
        for k in range(100):
            det.note("b/s", 0.01, ts=float(k))
        assert len(det.site_history("b/s")) == 16


class TestReadmissionStormDetector:
    def test_hysteresis_one_alert_per_episode(self):
        det = ReadmissionStormDetector(window_s=10.0, threshold=3)
        assert det.note("w", "t", ts=1.0) is None
        assert det.note("w", "t", ts=2.0) is None
        a = det.note("w", "t", ts=3.0)
        assert a is not None and a.value == 3.0
        # still above threshold: armed, no repeat alert
        assert det.note("w", "t", ts=4.0) is None
        # window drains -> re-arms
        assert det.note("w", "t", ts=30.0) is None
        assert det.note("w", "t", ts=31.0) is None
        assert det.note("w", "t", ts=32.0) is not None


# ------------------------------------------------------------------------ SLO

class TestSLO:
    def test_validation(self):
        with pytest.raises(ValueError):
            SLO(tenant="x", completion_rate=1.5)
        with pytest.raises(ValueError):
            SLOMonitor([SLO(tenant="a"), SLO(tenant="a")])

    def test_multi_window_burn_fires_and_clears(self):
        mon = SLOMonitor([SLO(tenant="t", completion_rate=0.9)],
                         short_window_s=60.0, long_window_s=300.0,
                         burn_threshold=2.0, min_runs=5)
        now = 1000.0
        for i in range(10):          # 50% failures against a 10% budget
            mon.note_run("t", ok=(i % 2 == 0), ts=now - 30.0 + i)
        fired = mon.evaluate(now=now)
        assert len(fired) == 1
        a = fired[0]
        assert a.detector == "slo_burn" and a.scope == "t"
        assert a.context["burn_short"] == pytest.approx(5.0)
        assert a.context["burn_long"] == pytest.approx(5.0)
        assert mon.status(now=now)["t"]["burning"]
        # short window empties -> burn clears (min_runs gate)
        later = now + 120.0
        assert mon.evaluate(now=later) == []
        assert not mon.status(now=later)["t"]["burning"]

    def test_min_runs_gate(self):
        mon = SLOMonitor([SLO(tenant="t", completion_rate=0.9)], min_runs=5)
        now = 1000.0
        for i in range(3):
            mon.note_run("t", ok=False, ts=now - 1.0)
        assert mon.evaluate(now=now) == []

    def test_latency_objectives(self):
        mon = SLOMonitor([SLO(tenant="t", completion_rate=None,
                              p99_queue_wait_s=1.0,
                              makespan_budget_s=10.0)],
                         burn_threshold=2.0, min_runs=5)
        now = 1000.0
        for i in range(10):          # every run violates both bounds
            mon.note_run("t", ok=True, makespan_s=60.0, queue_wait_s=5.0,
                         ts=now - 10.0)
        fired = mon.evaluate(now=now)
        assert {a.reason.split("burning ")[1].split(" ")[0]
                for a in fired} == {"p99_queue_wait_s", "makespan_budget_s"}

    def test_nudge_boosts_then_restores_weight(self):
        q = AdmissionQueue(default_weight=1)
        q.weights["t"] = 2
        mon = SLOMonitor([SLO(tenant="t", completion_rate=0.9)],
                         burn_threshold=2.0, min_runs=5, nudge_factor=2,
                         max_weight=8)
        now = 1000.0
        for i in range(10):
            mon.note_run("t", ok=False, ts=now - 1.0)
        mon.evaluate(now=now)
        assert mon.nudge(q) == {"t": 4}            # 2 * nudge_factor
        assert q.weights["t"] == 4
        mon.evaluate(now=now + 120.0)              # burn cleared
        assert mon.nudge(q) == {"t": 2}            # base weight restored
        assert q.weights["t"] == 2


# ---------------------------------------------------------- registry hardening

class TestRegistryRobustness:
    def test_gauge_fn_errors_counted_not_fatal(self):
        reg = MetricsRegistry()
        reg.counter("good_total").inc()
        reg.gauge_fn("bad_gauge", lambda: 1 / 0)
        reg.gauge_fn("ok_gauge", lambda: 42.0)
        snap = reg.snapshot()
        assert snap["good_total"] == 1
        assert snap["ok_gauge"] == 42.0
        assert "bad_gauge" not in snap
        assert snap["gauge_fn_errors_total"] == 1
        assert reg.snapshot()["gauge_fn_errors_total"] == 2

    def test_drop_and_drop_labeled(self):
        reg = MetricsRegistry()
        reg.counter("c_total").inc()
        reg.counter("c_total", tenant="a").inc()
        reg.counter("c_total", tenant="b").inc()
        reg.gauge("d", tenant="a").set(3)
        assert reg.drop("c_total", tenant="a")
        assert not reg.drop("c_total", tenant="a")      # already gone
        assert reg.drop_labeled("tenant", "a") == 1     # the gauge
        snap = reg.snapshot()
        assert "c_total" in snap
        assert "c_total{tenant=b}" in snap
        assert "c_total{tenant=a}" not in snap
        assert "d{tenant=a}" not in snap


class TestAdmissionTenantGC:
    def test_idle_tenant_series_dropped(self):
        q = AdmissionQueue(tenant_retention_s=10.0)
        wf = _chain_wf("gc", n=1)
        q.offer(AdmittedItem(wf=wf, tenant="ghost"))
        assert q.pop() is not None
        assert "admission_depth{tenant=ghost}" in q.registry.snapshot()
        assert q.gc_idle_tenants(now=time.time() + 5.0) == []    # not idle yet
        doomed = q.gc_idle_tenants(now=time.time() + 60.0)
        assert doomed == ["ghost"]
        snap = q.registry.snapshot()
        assert not any("ghost" in k for k in snap)
        assert snap["admission_tenant_gc_total"] == 1

    def test_queued_tenant_survives_gc(self):
        q = AdmissionQueue(tenant_retention_s=10.0)
        q.offer(AdmittedItem(wf=_chain_wf("gc2", n=1), tenant="busy"))
        assert q.gc_idle_tenants(now=time.time() + 60.0) == []
        assert "admission_depth{tenant=busy}" in q.registry.snapshot()


# ---------------------------------------------------------------- integration

class TestInBandAlerts:
    def test_seeded_straggler_alert_is_deterministic(self):
        mon = AnomalyMonitor()
        for k in range(10):
            mon.straggler.note("tele/s1", 0.01 + 0.001 * k, ts=float(k))
        eng = _engine(
            max_workers=2,
            fault_plan=FaultPlan(seed=7, straggler_rate=1.0,
                                 straggler_delay_s=0.4,
                                 targets=frozenset({"s1"})),
            telemetry_interval_s=0.05, anomaly=mon)
        try:
            wf = _chain_wf("tele", n=2)
            h = eng.gateway.submit_nowait(wf, tenant="t0", block=True)
            run = h.result()
            assert run.succeeded()
            evs = h.events_so_far()
            checker = TraceChecker.check(evs, wf=wf)
            alerts = [e for e in evs if e.type is EventType.ALERT]
            assert len(alerts) == 1 == len(checker.alerts)
            assert alerts[0].status == "straggler"
            assert alerts[0].step == "s1"
            assert "z=" in alerts[0].error
            assert mon.counts() == {"straggler": 1}
            # the alert counter landed in the gateway-bound registry
            assert eng.gateway.registry.get_value(
                "alerts_total", detector="straggler") == 1
        finally:
            eng.close()

    def test_readmission_storm_alert_with_hysteresis(self):
        mon = AnomalyMonitor()
        eng = _engine(
            max_workers=2,
            fault_plan=FaultPlan(seed=5, permanent_rate=1.0,
                                 targets=frozenset({"s0"}),
                                 max_failures_per_site=3),
            readmission=ReadmissionPolicy(base_backoff_s=0.005,
                                          max_backoff_s=0.02),
            anomaly=mon)
        try:
            wf = _chain_wf("storm", n=1)
            h = eng.gateway.submit_nowait(wf, tenant="t1", block=True)
            run = h.result()
            assert run.succeeded()
            evs = h.events_so_far()
            TraceChecker.check(evs, wf=wf)
            req = [e for e in evs if e.type is EventType.WORKFLOW_REQUEUED]
            storm = [e for e in evs if e.type is EventType.ALERT]
            assert len(req) == 3
            assert len(storm) == 1          # hysteresis: once per episode
            assert storm[0].status == "readmission_storm"
        finally:
            eng.close()

    def test_clean_corpus_zero_false_positives(self):
        mon = AnomalyMonitor()
        slos = SLOMonitor([SLO(tenant=f"t{i}") for i in range(3)])
        eng = _engine(max_workers=4, telemetry_interval_s=0.02,
                      anomaly=mon, slo=slos)
        try:
            rng = random.Random(3)
            handles = []
            for i in range(24):
                wf = WorkflowIR(f"clean-{i}")
                n = rng.randint(2, 5)
                for j in range(n):
                    wf.add_job(Job(name=f"s{j}",
                                   fn=lambda: time.sleep(0.001),
                                   cacheable=False))
                for j in range(1, n):
                    for k in range(j):
                        if rng.random() < 0.4:
                            wf.add_edge(f"s{k}", f"s{j}")
                handles.append(eng.gateway.submit_nowait(
                    wf, tenant=f"t{i % 3}", block=True))
            runs = [h.result() for h in handles]
            assert all(r.succeeded() for r in runs)
            for h in handles:
                assert not any(e.type is EventType.ALERT
                               for e in h.events_so_far())
            assert len(mon.alerts) == 0
            assert len(slos.alerts) == 0
        finally:
            eng.close()


class TestTelemetryAPI:
    def test_couler_telemetry_samples_the_gateway(self):
        eng = _engine(max_workers=2)
        try:
            tsdb, mon, slo_mon = couler.telemetry(
                eng, interval_s=0.02, slos=[SLO(tenant="default")])
            assert isinstance(mon, AnomalyMonitor)
            assert isinstance(slo_mon, SLOMonitor)
            run = eng.submit(_chain_wf("tapi", n=3))
            assert run.succeeded()
            deadline = time.time() + 5.0
            while tsdb.samples_taken < 2 and time.time() < deadline:
                time.sleep(0.02)
            assert tsdb.samples_taken >= 2
            assert tsdb.latest("gateway_workflows_submitted_total") >= 1.0
            # slo monitor saw the finished run
            assert slo_mon.status()["default"]["runs_seen"] == 1
        finally:
            eng.close()

    def test_telemetry_requires_a_gateway(self):
        eng = MultiClusterEngine(clusters=[
            Cluster("a", cpu=8, mem_bytes=1 << 40)])
        with pytest.raises(TypeError, match="attach_telemetry"):
            couler.telemetry(eng)

    def test_cluster_attach_telemetry_samples_per_batch(self):
        eng = MultiClusterEngine(clusters=[
            Cluster("a", cpu=8, mem_bytes=1 << 40)])
        tsdb = TimeSeriesDB()
        eng.attach_telemetry(tsdb)
        wf = WorkflowIR("mc")
        wf.add_job(Job(name="j0", est_time_s=1.0, resources=Resources(cpu=2)))
        runs = eng.submit_many([(wf, "u0", 0)])
        assert all(r.succeeded() for r in runs.values())
        assert tsdb.samples_taken == 1
        assert tsdb.latest("cluster_workflows_total") is not None \
            or len(tsdb.names()) > 0

    def test_telemetry_jsonl_persistence(self, tmp_path):
        path = tmp_path / "tel.jsonl"
        eng = _engine(max_workers=2, telemetry_interval_s=0.02,
                      telemetry_path=str(path))
        try:
            run = eng.submit(_chain_wf("tpersist", n=2))
            assert run.succeeded()
            deadline = time.time() + 5.0
            while eng.gateway.tsdb.samples_taken < 2 \
                    and time.time() < deadline:
                time.sleep(0.02)
        finally:
            eng.close()
        back = TimeSeriesDB.load_jsonl(str(path))
        assert back.samples_taken >= 2
        assert back.latest("gateway_workflows_submitted_total") >= 1.0


class TestStepProfiling:
    def test_plain_fn_profile_recorded(self):
        eng = _engine(max_workers=2, profile_steps=True)
        try:
            run = eng.submit(_chain_wf("prof", n=2))
            assert run.succeeded()
            prof = run.steps["s0"].profile
            assert prof is not None and "execute_s" in prof
            snap = eng.gateway.registry.snapshot()
            assert snap["step_execute_s"]["count"] >= 2
        finally:
            eng.close()

    def test_jit_fn_splits_compile_and_execute(self):
        fn = jax.jit(lambda: jnp.asarray(2.0) * 3.0)
        eng = _engine(max_workers=2, profile_steps=True)
        try:
            wf = WorkflowIR("profjit")
            wf.add_job(Job(name="s0", fn=fn, cacheable=False))
            run = eng.submit(wf)
            assert run.succeeded()
            prof = run.steps["s0"].profile
            assert prof is not None
            assert prof["compile_s"] > 0 and prof["execute_s"] > 0
            snap = eng.gateway.registry.snapshot()
            assert snap["step_compile_s"]["count"] >= 1
        finally:
            eng.close()
