"""Observability fabric (repro.core.obs): thread-safe metrics registry,
span derivation from the gateway event stream, critical-path makespan
attribution, and the Chrome-trace / JSONL exports.

Pins the PR's contracts: no lost counter updates under the step pool's
concurrency (the old ``stats[k] += 1`` dicts raced), dict-compatible
``StatsView`` facades over every legacy ``stats`` surface, ``run.report()``
breakdowns whose segments partition the makespan exactly and reconcile
with measured wall-clock on a streaming pipeline, and a live-context
rotation warning from ``CoulerPolicy``'s scoring-memo LRU.
"""
import concurrent.futures as cf
import json
import logging
import time

import pytest

from repro.core import couler
from repro.core.cache.policies import CoulerPolicy
from repro.core.cache.store import TieredCacheStore
from repro.core.engines.cluster import MultiClusterEngine
from repro.core.engines.local import LocalEngine
from repro.core.gateway import AdmissionQueue, AdmittedItem
from repro.core.obs import (MetricsRegistry, ObsCollector, StatsView,
                            build_report, chrome_trace, load_jsonl,
                            validate_chrome_trace)
from repro.core.obs.metrics import Counter, Gauge


def _engine(**kw):
    kw.setdefault("enable_speculation", False)
    kw.setdefault("promote_interval_s", 0.0)
    kw.setdefault("check_events", True)
    return LocalEngine(**kw)


def _chain(name, sleep=0.0):
    with couler.workflow(name) as ir:
        a = couler.run_step(lambda: (time.sleep(sleep), 2)[1], step_name="a")
        b = couler.run_step(lambda x: (time.sleep(sleep), x * 3)[1], a,
                            step_name="b")
        couler.run_step(lambda x: x + 1, b, step_name="c")
    return ir


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_identity_and_label_series():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", tenant="a")
    assert reg.counter("x_total", tenant="a") is c1
    c2 = reg.counter("x_total", tenant="b")
    assert c2 is not c1
    c1.inc(3)
    c2.inc()
    snap = reg.snapshot()
    assert snap["x_total{tenant=a}"] == 3
    assert snap["x_total{tenant=b}"] == 1
    assert reg.get_value("x_total", tenant="a") == 3
    assert reg.get_value("never_created") == 0
    with pytest.raises(TypeError):
        reg.gauge("x_total", tenant="a")     # name/type collision


def test_histogram_buckets_and_quantile():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    v = h.value
    assert v["count"] == 5 and v["sum"] == pytest.approx(5.605)
    assert v["buckets"] == {"0.01": 1, "0.1": 3, "1.0": 4, "+Inf": 5}
    assert h.quantile(0.5) == 0.1
    assert h.quantile(1.0) == 1.0            # +Inf reports largest finite


def test_gauge_fn_sampled_at_snapshot():
    reg = MetricsRegistry()
    box = {"v": 1}
    reg.gauge_fn("box_depth", lambda: box["v"])
    assert reg.snapshot()["box_depth"] == 1
    box["v"] = 7
    assert reg.snapshot()["box_depth"] == 7


def test_stats_view_is_dict_compatible():
    reg = MetricsRegistry()
    c = reg.counter("n_total")
    c.inc(2)
    view = StatsView({"n": c, "derived": lambda: 10})
    assert view["n"] == 2 and view["derived"] == 10
    assert view == {"n": 2, "derived": 10}
    assert dict(view.items()) == {"n": 2, "derived": 10}
    assert set(view) == {"n", "derived"} and len(view) == 2
    assert view.get("missing", 5) == 5 and "n" in view
    view["n"] = 9                            # legacy hard-set path
    assert c.value == 9
    with pytest.raises(TypeError):
        view["derived"] = 1                  # derived fields are read-only


# ---------------------------------------------------------------------------
# satellite 1: no lost updates under the step pool's concurrency
# ---------------------------------------------------------------------------

def test_counter_hammer_no_lost_updates():
    reg = MetricsRegistry()
    c = reg.counter("hammer_total")
    g = reg.gauge("hammer_peak")
    n_threads, per = 8, 5000

    def work(_):
        for i in range(per):
            c.inc()
            g.set_max(i)

    with cf.ThreadPoolExecutor(n_threads) as ex:
        list(ex.map(work, range(n_threads)))
    assert c.value == n_threads * per        # the racing dict lost ~% here
    assert g.value == per - 1


def test_gateway_stats_consistent_under_concurrent_submission():
    eng = _engine(max_workers=8)
    try:
        n = 24
        wfs = [_chain(f"conc{i}") for i in range(n)]
        with cf.ThreadPoolExecutor(8) as ex:
            runs = list(ex.map(
                lambda wf: eng.submit(wf, optimize=False), wfs))
        assert all(r.succeeded() for r in runs)
        gw = eng.gateway
        assert gw.stats["submitted"] == n
        assert gw.stats["completed"] == n
        assert gw.stats["failed"] == 0
        assert gw.registry.get_value("gateway_inflight_steps") == 0
        assert gw.stats["peak_inflight_steps"] >= 1
    finally:
        eng.close()


def test_admission_per_tenant_shed_series():
    q = AdmissionQueue(max_depth_per_tenant=2, max_total=100)
    wf = _chain("shed")
    for _ in range(2):
        q.offer(AdmittedItem(wf=wf, tenant="t0"))
    from repro.core.gateway.admission import QueueFull
    with pytest.raises(QueueFull):
        q.offer(AdmittedItem(wf=wf, tenant="t0"), block=False)
    q.offer(AdmittedItem(wf=wf, tenant="t1"))
    assert q.stats["offered"] == 3 and q.stats["shed"] == 1
    assert q.registry.get_value("admission_shed_total", tenant="t0") == 1
    assert q.registry.get_value("admission_offered_total", tenant="t1") == 1
    assert q.registry.get_value("admission_depth", tenant="t0") == 2
    q.drain()
    assert q.stats["popped"] == 3
    assert q.registry.get_value("admission_depth", tenant="t0") == 0


def test_cache_store_stats_via_registry():
    store = TieredCacheStore()
    store.offer("a", b"x" * 64, 1.0, "p")
    assert store.get("a") is not None
    assert store.get("zz") is None
    assert store.stats["admitted"] == 1
    assert store.stats["hits"] == 1 and store.stats["misses"] == 1
    assert store.hit_ratio() == 0.5
    snap = store.registry.snapshot()
    assert snap["cache_hits_total{store=store}"] == 1
    assert "cache_used_bytes{store=store}" in snap


# ---------------------------------------------------------------------------
# satellite 2: live scoring-context rotation warning
# ---------------------------------------------------------------------------

def test_policy_live_ctx_rotation_warns_and_counts(caplog):
    pol = CoulerPolicy()
    reg = MetricsRegistry()
    pol.bind_metrics(reg)
    wfs = [_chain(f"rot{i}") for i in range(pol._MAX_CONTEXTS + 1)]
    with caplog.at_level(logging.WARNING, "repro.core.cache.policies"):
        for wf in wfs:                        # all live: 17th evicts the 1st
            pol._ctx_for(wf)
    assert pol.ctx_rotations_live == 1
    assert reg.get_value("cache_ctx_rotated_live_total") == 1
    assert any("rotated out scoring context" in r.message
               for r in caplog.records)
    assert reg.snapshot()["cache_scoring_ctxs"] == pol._MAX_CONTEXTS
    # dead workflows rotate silently
    caplog.clear()
    pol2 = CoulerPolicy()
    pol2.bind_metrics(reg)
    for i in range(pol2._MAX_CONTEXTS + 4):
        pol2._ctx_for(_chain(f"dead{i}"))     # nothing else holds a ref
    assert pol2.ctx_rotations_live == 0


# ---------------------------------------------------------------------------
# span derivation + attribution
# ---------------------------------------------------------------------------

def test_span_tree_and_report_basics():
    eng = _engine()
    try:
        c = couler.observe(eng)
        run = eng.submit(_chain("spans", sleep=0.01), optimize=False)
        assert run.succeeded()
        tree = c.tree(run.run_id)
        assert tree is not None and tree.status == "Succeeded"
        assert {s.step for s in tree.steps} == {"a", "b", "c"}
        assert c.open_run_ids == []           # no leaked builders
        for sp in tree.steps:
            assert sp.end is not None and sp.end >= sp.start
            assert sp.segments and all(seg.dur >= 0 for seg in sp.segments)
        # b depends on a -> it waited for a to finish
        b = next(s for s in tree.steps if s.step == "b")
        assert b.segments[0].kind == "queue-wait"
        rep = run.report()
        assert rep.attributed_s == pytest.approx(rep.makespan_s, abs=1e-9)
        assert rep.critical_path == ["a", "b", "c"]
        assert rep.totals.get("compute", 0) > 0
        assert "compute" in rep.render()
    finally:
        eng.close()


def test_report_requires_observe():
    eng = _engine()
    try:
        run = eng.submit(_chain("unobserved"), optimize=False)
        with pytest.raises(RuntimeError, match="couler.observe"):
            run.report()
    finally:
        eng.close()


def test_streaming_pipeline_report_reconciles_with_wall_clock():
    # the acceptance pipeline: 8 stages (p + m1..m7), chunked streaming;
    # the attributed makespan must reconcile with measured wall-clock ±5%
    def gen():
        for i in range(6):
            time.sleep(0.01)
            yield i
    with couler.workflow("stream8") as ir:
        cur = couler.run_stream(gen, step_name="p", cacheable=False)
        for k in range(1, 8):
            cur = couler.map_stream(
                lambda ch, _k=k: (time.sleep(0.004), ch + _k)[1], cur,
                step_name=f"m{k}", cacheable=False)
    eng = _engine(max_inflight_steps=8)
    try:
        c = couler.observe(eng)
        t0 = time.time()
        run = eng.submit(ir, optimize=False)
        wall = time.time() - t0
        assert run.succeeded()
        rep = run.report()
        assert len(rep.critical_path) >= 1
        assert rep.reconciles(wall), \
            f"attributed {rep.attributed_s:.4f}s vs wall {wall:.4f}s"
        tree = c.tree(run.run_id)
        assert sum(s.chunks for s in tree.steps) >= 8 * 6
        # channel accounting folded into the producer spans
        p = next(s for s in tree.steps if s.step == "p")
        assert p.annotations.get("stream_chunks") == 6
    finally:
        eng.close()


def test_jsonl_round_trip_and_chrome_export():
    eng = _engine()
    try:
        c = couler.observe(eng)
        eng.submit(_chain("exp1"), optimize=False)
        eng.submit(_chain("exp2"), optimize=False)
        text = c.export_jsonl()
        trees = load_jsonl(text)
        assert {t.workflow for t in trees} == {"exp1", "exp2"}
        orig = {t.run_id: t for t in c.trees()}
        for t in trees:
            assert t.makespan_s == pytest.approx(orig[t.run_id].makespan_s)
            assert [s.step for s in t.steps] == \
                   [s.step for s in orig[t.run_id].steps]
            assert build_report(t).attributed_s == \
                pytest.approx(build_report(orig[t.run_id]).attributed_s)
        trace = c.export_chrome()
        assert validate_chrome_trace(trace) == []
        json.dumps(trace)                    # loadable = serializable
        names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
        assert any(n.startswith("a:") for n in names)
        assert "compute" in names
        pids = {e["pid"] for e in trace["traceEvents"]}
        assert len(pids) == 2                # one process per run
    finally:
        eng.close()


def test_chrome_validator_flags_malformed():
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "n", "ts": 0}]}) != []
    assert validate_chrome_trace({"traceEvents": [
        {"ph": "Q", "pid": 1, "tid": 0, "name": "n"}]}) != []


def test_collector_lru_bounds_finished_runs():
    c = ObsCollector(max_runs=3)
    eng = _engine()
    try:
        eng.gateway.attach_collector(c)
        runs = [eng.submit(_chain(f"lru{i}"), optimize=False)
                for i in range(5)]
        assert all(r.succeeded() for r in runs)
        assert len(c.trees()) == 3
        assert c.tree(runs[0].run_id) is None       # rotated out
        assert c.tree(runs[-1].run_id) is not None
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# cluster engine: registry-backed metrics + coarse span ingestion
# ---------------------------------------------------------------------------

def test_cluster_metrics_view_and_observe():
    eng = MultiClusterEngine()
    c = couler.observe(eng)
    run = eng.submit(_chain("clus"), user="u0")
    assert run.status == "Succeeded"
    m = eng.metrics
    assert m["scheduled_jobs"] == 3 and m["completed_workflows"] == 1
    busy = m["cluster_busy_s"]
    assert isinstance(busy, dict) and sum(busy.values()) > 0
    assert m == {**dict(m.items())}          # view equals its dict snapshot


def test_cluster_submit_admitted_ingests_spans():
    from repro.core.gateway.run import AsyncWorkflowRun
    eng = MultiClusterEngine()
    c = couler.observe(eng)
    q = AdmissionQueue()
    wf = _chain("adm")
    h = AsyncWorkflowRun(wf.name, tenant="t0")
    q.offer(AdmittedItem(wf=wf, tenant="t0", handle=h))
    runs = eng.submit_admitted(q)
    run = runs[wf.name]
    assert run.status == "Succeeded"
    rep = run.report()                        # weakref back to the collector
    assert rep.status == "Succeeded"
    assert c.tree(run.run_id).tenant == "t0"
