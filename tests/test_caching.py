import math

import numpy as np
import pytest

from repro.core.caching import (CacheStore, CachedArtifact, CoulerPolicy,
                                FIFOPolicy, LRUPolicy, NoCache, CacheAll,
                                importance, reconstruction_cost, reuse_value,
                                sizeof)
from repro.core.ir import Job, WorkflowIR


def chain_wf(n=6):
    wf = WorkflowIR("c")
    prev = None
    for i in range(n):
        wf.add_job(Job(name=f"j{i}", est_time_s=1.0 + i))
        if prev:
            wf.add_edge(prev, f"j{i}")
        prev = f"j{i}"
    return wf


def fan_wf(fanout=4):
    """root -> mid -> {leaf_i}: mid's artifact has high reuse value."""
    wf = WorkflowIR("f")
    wf.add_job(Job(name="root", est_time_s=5))
    wf.add_job(Job(name="mid", est_time_s=3))
    wf.add_edge("root", "mid")
    for i in range(fanout):
        wf.add_job(Job(name=f"leaf{i}", est_time_s=1))
        wf.add_edge("mid", f"leaf{i}")
    return wf


def test_eq3_truncates_at_cached():
    wf = chain_wf()
    full = reconstruction_cost(wf, "j5", cached_producers=set())
    truncated = reconstruction_cost(wf, "j5", cached_producers={"j3"})
    assert truncated < full


def test_eq4_reuse_grows_with_fanout():
    assert reuse_value(fan_wf(6), "mid") > reuse_value(fan_wf(2), "mid")
    # sink artifact has no successors -> zero reuse value
    assert reuse_value(chain_wf(), "j5") == 0.0


def test_eq6_monotonicity():
    base = importance(10, 2, 0.5)
    assert importance(100, 2, 0.5) > base          # higher rebuild cost
    assert importance(10, 4, 0.5) > base           # higher reuse
    assert importance(10, 2, 0.1) < base           # cheaper-to-store wins
    assert importance(0, 0, 1e9) == pytest.approx(-0.0, abs=1e-6)


def test_store_capacity_and_eviction():
    store = CacheStore(capacity_bytes=300, policy=FIFOPolicy())
    for i in range(5):
        store.offer(f"a{i}", b"x" * 100, 1.0, producer=f"j{i}")
    assert store.used_bytes <= 300
    assert len(store.items) == 3
    assert "a0" not in store.items and "a4" in store.items   # FIFO evicts oldest


def test_lru_evicts_least_recent():
    store = CacheStore(capacity_bytes=300, policy=LRUPolicy())
    for i in range(3):
        store.offer(f"a{i}", b"x" * 100, 1.0, producer=f"j{i}")
    store.get("a0")                         # refresh a0
    store.offer("a3", b"x" * 100, 1.0, producer="j3")
    assert "a0" in store.items and "a1" not in store.items


def test_none_and_all_policies():
    none = CacheStore(capacity_bytes=1000, policy=NoCache())
    assert not none.offer("a", b"xx", 1.0, producer="j")
    alls = CacheStore(capacity_bytes=1000, policy=CacheAll())
    assert alls.offer("a", b"xx", 1.0, producer="j")


def test_couler_policy_prefers_high_value_artifacts():
    """Algorithm 2: the fan-out artifact (high F) should displace a
    leaf artifact (no successors) when space runs out."""
    wf = fan_wf(5)
    store = CacheStore(capacity_bytes=150, policy=CoulerPolicy())
    store.attach_workflow(wf)
    assert store.offer("leaf0:out", b"x" * 100, 0.5, producer="leaf0")
    # mid has 5 successors -> much higher importance; should evict leaf0
    assert store.offer("mid:out", b"y" * 100, 3.0, producer="mid")
    assert "mid:out" in store.items
    assert "leaf0:out" not in store.items


def test_couler_policy_rejects_low_value_when_full():
    wf = fan_wf(5)
    store = CacheStore(capacity_bytes=150, policy=CoulerPolicy())
    store.attach_workflow(wf)
    assert store.offer("mid:out", b"y" * 100, 3.0, producer="mid")
    assert not store.offer("leaf1:out", b"x" * 100, 0.5, producer="leaf1")
    assert "mid:out" in store.items


def test_oversized_artifact_rejected():
    store = CacheStore(capacity_bytes=10, policy=CacheAll())
    assert not store.offer("big", b"x" * 100, 1.0, producer="j")


def test_hit_ratio_accounting():
    store = CacheStore(capacity_bytes=1000, policy=CacheAll())
    store.offer("a", 1, 1.0, producer="j")
    assert store.get("a") is not None
    assert store.get("b") is None
    assert store.hit_ratio() == 0.5


def test_sizeof_numpy():
    assert sizeof(np.zeros((10, 10), np.float32)) == 400
