"""Multi-device integration tests (subprocess: 8 fake host devices so the
in-process tests keep seeing 1 device, per task spec)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


# ~1 min wall on 8 fake host devices — back in tier-1 since the
# out_shardings pin and the axis_size compat shim fixed the suite
@pytest.mark.timeout(900)
def test_distributed_suite():
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "distributed_worker.py")],
        capture_output=True, text=True, timeout=850)
    sys.stdout.write(r.stdout)
    sys.stderr.write(r.stderr[-4000:])
    assert r.returncode == 0
    assert "ALL DISTRIBUTED OK" in r.stdout
