from repro.core.llm import TemplateLLM, SurrogateLLM, TIERS
from repro.core.nl2wf import (decompose, execute_generated, extract_entities,
                              nl_to_workflow)

DESC = ("I need to design a workflow to select the optimal image "
        "classification model. Load the dataset named imagenet-sub, "
        "preprocess it, train the ResNet, ViT and DenseNet models "
        "respectively, evaluate accuracy on validation data, then select "
        "the best model and generate a report.")


def test_decompose_finds_pipeline_spine():
    kinds = [s.kind for s in decompose(DESC)]
    assert kinds.index("load") < kinds.index("preprocess")
    assert kinds.index("preprocess") < kinds.index("train_multi")
    assert kinds.index("train_multi") < kinds.index("evaluate")
    assert kinds.index("evaluate") < kinds.index("select")


def test_entity_extraction():
    e = extract_entities(DESC.lower())
    assert "resnet" in e["models"] and "densenet" in e["models"]
    assert e["dataset"] == "'imagenet-sub'"
    assert e["metric"] == "'accuracy'"


def test_generation_builds_valid_workflow():
    """pass@5 semantics: generation has a seeded error model, so assert a
    strong majority of seeds yield the full structure at t=0."""
    good = 0
    for seed in range(5):
        res = nl_to_workflow(DESC, llm=TemplateLLM("gpt-4"), temperature=0.0,
                             seed=seed)
        if res.error is not None or res.workflow is None:
            continue
        names = set(res.workflow.jobs)
        if ("load-data" in names and "preprocess" in names
                and any(n.startswith("train-") for n in names)
                and "select-best" in names):
            res.workflow.validate()
            good += 1
    assert good >= 3, good


def test_self_calibration_rounds_recorded():
    res = nl_to_workflow(DESC, llm=TemplateLLM("gpt-3.5"), temperature=0.8,
                         seed=1, baseline_score=0.9)
    assert all(r >= 1 for r in res.rounds)
    assert len(res.scores) == len(res.subtask_codes)


def test_user_feedback_loop():
    seen = {}

    def feedback(desc, code):
        seen["code"] = code
        return desc + " Also checkpoint save the model weights."
    res = nl_to_workflow(DESC, llm=TemplateLLM("gpt-4"), temperature=0.0,
                         feedback=feedback, seed=5)
    assert "code" in seen
    assert res.error is None
    assert any("checkpoint" in n for n in res.workflow.jobs)


def test_reference_free_baseline_is_worse():
    """Without Code-Lake retrieval (paper's raw-GPT baseline) the same NL
    should fail more often across seeds."""
    ours_ok = base_ok = 0
    for seed in range(12):
        r1 = nl_to_workflow(DESC, llm=TemplateLLM("gpt-4"), seed=seed,
                            temperature=0.6)
        r2 = nl_to_workflow(DESC, llm=TemplateLLM("gpt-4",
                                                  use_references=False),
                            seed=seed, temperature=0.6, max_rounds=1)
        def good(r):
            return (r.error is None and r.workflow is not None
                    and any(n.startswith("train-") for n in r.workflow.jobs))
        ours_ok += good(r1)
        base_ok += good(r2)
    assert ours_ok > base_ok


def test_execute_generated_rejects_cycles():
    code = "x = couler.run_step(steps.load_data, step_name='a')\n"
    wf = execute_generated(code)
    assert "a" in wf.jobs


def test_surrogate_llm_prefers_sane_lr():
    llm = SurrogateLLM()
    dc = {"n_examples": 1e5}
    mc = {"n_params": 1e8}
    good = llm.predict_training_log(dc, mc, {"learning_rate": 3e-3,
                                             "batch_size": 32})
    bad = llm.predict_training_log(dc, mc, {"learning_rate": 3.0,
                                            "batch_size": 32})
    assert good["final_loss"] < bad["final_loss"]
    assert "step" in good["log"]


def test_token_accounting_and_cost():
    llm = TemplateLLM("gpt-4")
    nl_to_workflow(DESC, llm=llm, seed=0)
    assert llm.tokens_used > 100
    assert llm.cost_usd() > 0
    assert TIERS["gpt-4"].cost_per_1k_tokens > TIERS["gpt-3.5"].cost_per_1k_tokens
