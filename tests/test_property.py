"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st           # noqa: E402
from hypothesis import given, settings       # noqa: E402

from repro.core.autosplit import Budget, split_workflow, validate_split
from repro.core.caching import (CacheStore, CoulerPolicy, FIFOPolicy,
                                LRUPolicy, importance)
from repro.core.ir import Job, WorkflowIR


# ---------------------------------------------------------------------------
# random DAG strategy: edges only point forward -> always acyclic
# ---------------------------------------------------------------------------

@st.composite
def dags(draw, max_nodes=40):
    n = draw(st.integers(min_value=1, max_value=max_nodes))
    wf = WorkflowIR("rand")
    for i in range(n):
        wf.add_job(Job(name=f"j{i}",
                       est_time_s=draw(st.floats(0.1, 10.0)),
                       resources=__import__(
                           "repro.core.ir", fromlist=["Resources"]
                       ).Resources(cpu=draw(st.floats(0.5, 8.0)))))
    for j in range(1, n):
        for i in range(j):
            if draw(st.booleans()) and draw(st.integers(0, 3)) == 0:
                wf.add_edge(f"j{i}", f"j{j}")
    return wf


@given(dags(), st.integers(min_value=2, max_value=20))
@settings(max_examples=40, deadline=None)
def test_split_partitions_any_dag(wf, steps):
    b = Budget(steps=steps, spec_bytes=10**9, pods=10**9)
    subs = split_workflow(wf, b)
    validate_split(wf, subs, b)


@given(dags())
@settings(max_examples=30, deadline=None)
def test_ir_json_roundtrip(wf):
    wf2 = WorkflowIR.from_json(wf.to_json())
    assert set(wf2.jobs) == set(wf.jobs)
    assert wf2.edges == wf.edges
    assert wf2.topo_order() == wf.topo_order()


@given(dags())
@settings(max_examples=30, deadline=None)
def test_critical_path_bounds(wf):
    total, path = wf.critical_path()
    times = [wf.jobs[n].est_time_s for n in wf.jobs]
    assert total <= sum(times) + 1e-9
    assert total >= max(times) - 1e-9
    # path must follow edges
    for a, b in zip(path, path[1:]):
        assert (a, b) in wf.edges


# ---------------------------------------------------------------------------
# cache store invariants under arbitrary offer/get sequences
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 30),          # artifact id
                          st.integers(1, 400),         # size
                          st.floats(0.0, 10.0)),       # compute time
                min_size=1, max_size=80),
       st.sampled_from(["fifo", "lru", "couler"]))
@settings(max_examples=60, deadline=None)
def test_cache_never_exceeds_capacity(ops, policy_name):
    policy = {"fifo": FIFOPolicy, "lru": LRUPolicy,
              "couler": CoulerPolicy}[policy_name]()
    store = CacheStore(capacity_bytes=1000, policy=policy)
    for aid, size, t in ops:
        store.offer(f"a{aid}", b"x" * size, t, producer=f"j{aid}")
        assert store.used_bytes <= store.capacity_bytes
        assert store.used_bytes == sum(a.bytes for a in store.items.values())
    s = store.stats
    assert s["admitted"] - s["evictions"] == len(store.items)


@given(st.floats(0, 1e6), st.floats(0, 100), st.floats(0, 1.0),
       st.floats(0.1, 5.0), st.floats(0.1, 5.0))
@settings(max_examples=100, deadline=None)
def test_importance_monotone(l, f, v, alpha, beta):
    base = importance(l, f, v, alpha, beta)
    assert importance(l * 2 + 1, f, v, alpha, beta) >= base
    assert importance(l, f + 1, v, alpha, beta) >= base
    assert importance(l, f, v + 1, alpha, beta) >= base
    assert np.isfinite(base)


# ---------------------------------------------------------------------------
# int8 compression error bound (single-participant path runs in-process)
# ---------------------------------------------------------------------------

@given(st.integers(1, 500), st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale_mag):
    import jax.numpy as jnp
    from repro.training.compression import _dequantize, _quantize
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.normal(size=(n,)) * scale_mag, jnp.float32)
    s = jnp.max(jnp.abs(g)) + 1e-12
    q = _quantize(g, s)
    back = _dequantize(q.astype(jnp.int32), s, 1)
    # max error is half a quantization step
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) / 127.0 * 0.51 + 1e-6
