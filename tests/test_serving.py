import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, reduced
from repro.models import transformer as T
from repro.serving.engine import ServingEngine


@pytest.mark.parametrize("aid", ["stablelm-1.6b", "mamba2-370m",
                                 "zamba2-1.2b"])
def test_generate_batched(aid):
    cfg = reduced(get_arch(aid).model).replace(param_dtype="float32",
                                               compute_dtype="float32")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_len=32)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 100)
    res = eng.generate(prompts, gen_len=8)
    assert len(res.tokens) == 2 and len(res.tokens[0]) == 8
    assert res.tokens_per_s > 0


def test_temperature_sampling_differs():
    cfg = reduced(get_arch("stablelm-1.6b").model).replace(
        param_dtype="float32", compute_dtype="float32")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_len=32)
    prompts = jnp.ones((1, 4), jnp.int32)
    a = eng.generate(prompts, gen_len=10, temperature=1.5, seed=1)
    b = eng.generate(prompts, gen_len=10, temperature=1.5, seed=2)
    assert a.tokens != b.tokens          # different seeds -> different samples
    g = eng.generate(prompts, gen_len=10, temperature=0.0)
    g2 = eng.generate(prompts, gen_len=10, temperature=0.0)
    assert g.tokens == g2.tokens         # greedy is deterministic
