"""Streaming artifact pipelines: chunk ordering, backpressure, chunk-granular
caching (partial hit + tail recompute), producer retry rewind, cancel
mid-stream resumability, and the speculation in-flight-bound regression."""
import asyncio
import threading
import time

import pytest

from repro.core import couler
from repro.core.engines.base import StepStatus, TransientError
from repro.core.engines.local import LocalEngine
from repro.core.gateway.channels import (ArtifactChannel, StreamRewound,
                                         StreamStalled)
from repro.core.gateway.events import EventType


def _engine(**kw):
    kw.setdefault("enable_speculation", False)
    kw.setdefault("promote_interval_s", 0.0)
    # sanitizer mode: the TraceChecker validates every event inline
    kw.setdefault("check_events", True)
    return LocalEngine(**kw)


def _pipeline(name, n_chunks=10, stages=3, cacheable=False, sleep=0.0,
              gen=None):
    """Linear run_stream -> map_stream^stages pipeline; returns (ir, expected
    final chunk list)."""
    if gen is None:
        def gen():
            for i in range(n_chunks):
                if sleep:
                    time.sleep(sleep)
                yield i
    with couler.workflow(name) as ir:
        cur = couler.run_stream(gen, step_name="p", cacheable=cacheable)
        for k in range(1, stages + 1):
            fn = (lambda c, _k=k: (time.sleep(sleep), c * 2 + _k)[1]
                  if sleep else c * 2 + _k)
            cur = couler.map_stream(fn, cur, step_name=f"m{k}",
                                    cacheable=cacheable)
    expected = list(range(n_chunks))
    for k in range(1, stages + 1):
        expected = [c * 2 + k for c in expected]
    return ir, expected


# ---------------------------------------------------------------------------
# chunk ordering / equivalence / fallback
# ---------------------------------------------------------------------------

def test_chunk_order_and_materialized_equality():
    ir, expected = _pipeline("order", n_chunks=12, stages=3)
    eng = _engine()
    try:
        run = eng.submit(ir, optimize=False)
        assert run.status == "Succeeded"
        assert run.artifacts["m3:out"] == expected
        for n in ("p", "m1", "m2", "m3"):
            assert run.steps[n].status is StepStatus.SUCCEEDED
            assert run.steps[n].chunks_emitted == 12
    finally:
        eng.close()


def test_non_streaming_consumer_sees_materialized_whole():
    with couler.workflow("fallback") as ir:
        src = couler.run_stream(lambda: iter(range(6)), step_name="p",
                                cacheable=False)
        couler.run_step(lambda xs: sum(xs), src, step_name="tot",
                        cacheable=False)
    eng = _engine()
    try:
        run = eng.submit(ir, optimize=False)
        assert run.status == "Succeeded"
        assert run.artifacts["p:out"] == list(range(6))
        assert run.artifacts["tot:out"] == 15
    finally:
        eng.close()


def test_streaming_event_invariants_and_overlap():
    """Structural event-ordering invariants are delegated to the shared
    ``TraceChecker`` (the executable spec); this test keeps only the
    stream-specific expectations — complete chunk coverage per stage and
    actual producer/consumer overlap."""
    from repro.core.analysis import TraceChecker

    ir, expected = _pipeline("events", n_chunks=8, stages=2, sleep=0.005)

    async def main():
        eng = _engine()
        try:
            h = await couler.run_async(submitter=eng, workflow_ir=ir,
                                       optimize=False)
            return [ev async for ev in h.events()], await h
        finally:
            eng.close()

    evs, run = asyncio.run(main())
    assert run.artifacts["m2:out"] == expected
    checker = TraceChecker.check(evs, wf=ir)
    for step in ("p", "m1", "m2"):
        assert checker.chunks[step] == 7      # all 8 chunks, last index 7
        idx = [e.chunk for e in evs if e.step == step
               and e.type is EventType.STEP_CHUNK]
        assert idx == list(range(8))
    by_seq = {e.step: {"started": None, "terminal": None} for e in evs
              if e.step}
    for e in evs:
        if e.type is EventType.STEP_STARTED:
            by_seq[e.step]["started"] = e.seq
        elif e.type is EventType.STEP_SUCCEEDED:
            by_seq[e.step]["terminal"] = e.seq
    # overlap: each consumer started before its producer finished
    assert by_seq["m1"]["started"] < by_seq["p"]["terminal"]
    assert by_seq["m2"]["started"] < by_seq["m1"]["terminal"]


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_backpressure_bounds_producer_lead():
    """A fast producer feeding a slow consumer is throttled to the channel
    capacity: the generator can never run more than buffer+1 chunks ahead
    of what the consumer has taken."""
    emitted, consumed = [], []
    lead = {"max": 0}

    def fastgen():
        for i in range(40):
            lead["max"] = max(lead["max"], len(emitted) - len(consumed))
            emitted.append(i)
            yield i

    def slow(c):
        time.sleep(0.003)
        consumed.append(c)
        return c

    with couler.workflow("bp") as ir:
        src = couler.run_stream(fastgen, step_name="p", cacheable=False,
                                buffer_chunks=3)
        couler.map_stream(slow, src, step_name="m", cacheable=False)
    eng = _engine()
    try:
        run = eng.submit(ir, optimize=False)
        assert run.status == "Succeeded"
        assert run.artifacts["m:out"] == list(range(40))
        # put(i) blocks until lead < 3, so at yield time the producer is at
        # most capacity+1 ahead of the slowest reader
        assert lead["max"] <= 4, lead["max"]
    finally:
        eng.close()


def test_channel_stall_raises_instead_of_hanging():
    ch = ArtifactChannel("a:out", producer="p", capacity=1,
                         stall_timeout_s=0.1)
    ch.expect_consumer("never-attaches")
    ch.put(0)
    with pytest.raises(StreamStalled):
        ch.put(1)


def test_channel_rewind_signals_readers():
    ch = ArtifactChannel("a:out", producer="p", capacity=8)
    r = ch.reader("c")
    ch.put("x")
    assert next(r) == "x"
    ch.rewind()
    with pytest.raises(StreamRewound):
        next(r)
    r.close()
    r2 = ch.reader("c")
    ch.put("y")
    ch.close(1)
    assert list(r2) == ["y"]
    assert ch.stats["rewinds"] == 1


# ---------------------------------------------------------------------------
# chunk-granular caching
# ---------------------------------------------------------------------------

def test_full_chunk_cache_hit_marks_step_cached():
    calls = {"n": 0}

    def gen():
        calls["n"] += 1
        yield from range(5)

    def build():
        with couler.workflow("cachewf") as ir:
            src = couler.run_stream(gen, step_name="p")
            couler.map_stream(lambda c: c + 1, src, step_name="m")
        return ir

    eng = _engine()
    try:
        r1 = eng.submit(build(), optimize=False)
        assert r1.status == "Succeeded" and calls["n"] == 1
        r2 = eng.submit(build(), optimize=False)
        assert calls["n"] == 1                    # generator not re-invoked
        assert r2.steps["p"].status is StepStatus.CACHED
        assert r2.steps["m"].status is StepStatus.CACHED
        assert r2.steps["p"].chunks_replayed == 5
        assert r2.artifacts["m:out"] == [1, 2, 3, 4, 5]
    finally:
        eng.close()


def test_partial_chunk_hit_replays_prefix_and_recomputes_tail():
    calls = {"n": 0}

    def gen():
        calls["n"] += 1
        yield from range(5)

    def build():
        with couler.workflow("partial") as ir:
            src = couler.run_stream(gen, step_name="p")
            couler.map_stream(lambda c: c * 10, src, step_name="m")
        return ir

    eng = _engine()
    try:
        r1 = eng.submit(build(), optimize=False)
        assert r1.status == "Succeeded"
        # evict the producer's tail chunks (keep the manifest + prefix)
        store = eng.cache
        victims = [n for n in store.items
                   if "#c" in n and int(n.split("#c")[1]) >= 3]
        assert victims
        for name in victims:
            t = store.find_tier(name)
            t.remove(name, "evicted")
        r2 = eng.submit(build(), optimize=False)
        assert r2.status == "Succeeded"
        assert r2.artifacts["m:out"] == [0, 10, 20, 30, 40]
        assert calls["n"] == 2                    # tail needed the generator
        p2 = r2.steps["p"]
        assert p2.status is StepStatus.SUCCEEDED
        assert p2.chunks_replayed == 3            # cached prefix
        assert p2.chunks_emitted == 2             # recomputed tail
    finally:
        eng.close()


def test_uncacheable_upstream_disables_consumer_chunk_cache():
    """A consumer of an uncacheable stream cannot identify its input, so it
    must not cache its own chunks (a stale hit would be wrong)."""
    def build(base):
        with couler.workflow("nokey") as ir:
            src = couler.run_stream(lambda: iter([base, base + 1]),
                                    step_name="p", cacheable=False)
            couler.map_stream(lambda c: c * 2, src, step_name="m")
        return ir

    eng = _engine()
    try:
        r1 = eng.submit(build(10), optimize=False)
        assert r1.artifacts["m:out"] == [20, 22]
        r2 = eng.submit(build(50), optimize=False)
        assert r2.artifacts["m:out"] == [100, 102]   # not a stale [20, 22]
        assert r2.steps["m"].status is StepStatus.SUCCEEDED
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# retry rewind / cancel
# ---------------------------------------------------------------------------

def test_producer_transient_failure_rewinds_channel():
    state = {"attempts": 0}
    consumed_one = threading.Event()

    def flaky():
        state["attempts"] += 1
        for i in range(5):
            if state["attempts"] == 1 and i == 2:
                # wait until the consumer has read a chunk, so the rewind
                # deterministically interrupts an in-flight reader
                consumed_one.wait(2.0)
                raise TransientError("ConnectionReset mid-stream")
            yield i

    def sq(c):
        consumed_one.set()
        return c * c

    with couler.workflow("rewind") as ir:
        src = couler.run_stream(flaky, step_name="p", cacheable=False,
                                retry_limit=3)
        couler.map_stream(sq, src, step_name="m", cacheable=False)
    eng = _engine()
    try:
        run = eng.submit(ir, optimize=False)
        assert run.status == "Succeeded"
        assert run.artifacts["m:out"] == [0, 1, 4, 9, 16]
        assert state["attempts"] == 2
        assert run.steps["p"].attempts == 2
        # the consumer restarted on the rewind without burning retry budget
        assert run.steps["m"].attempts >= 2
        assert run.steps["m"].chunks_emitted == 5
    finally:
        eng.close()


def test_permanent_producer_failure_fails_consumer_too():
    def broken():
        yield 0
        raise ValueError("hard failure")

    with couler.workflow("hardfail") as ir:
        src = couler.run_stream(broken, step_name="p", cacheable=False,
                                retry_limit=1)
        couler.map_stream(lambda c: c, src, step_name="m", cacheable=False)
    eng = _engine()
    try:
        run = eng.submit(ir, optimize=False)
        assert run.status == "Failed"
        assert run.steps["p"].status is StepStatus.FAILED
        assert run.steps["m"].status is StepStatus.FAILED
        assert "StreamBroken" in run.steps["m"].error
    finally:
        eng.close()


def test_cancel_mid_stream_leaves_run_resumable():
    gate = threading.Event()

    def slowgen():
        for i in range(20):
            if i == 3:
                gate.set()
            time.sleep(0.005)
            yield i

    def build():
        with couler.workflow("cancelwf") as ir:
            src = couler.run_stream(slowgen, step_name="p", cacheable=False)
            couler.map_stream(lambda c: c + 100, src, step_name="m",
                              cacheable=False)
        return ir

    eng = _engine()
    try:
        ir = build()

        async def main():
            h = await couler.run_async(submitter=eng, workflow_ir=ir,
                                       optimize=False)
            await asyncio.get_running_loop().run_in_executor(None, gate.wait)
            assert h.cancel()
            return await h

        run = asyncio.run(main())
        assert run.status == "Cancelled"
        # mid-stream steps reverted to Pending: the run is resumable
        assert all(r.status is StepStatus.PENDING
                   for r in run.steps.values())
        resumed = eng.resume(run)
        assert resumed.status == "Succeeded"
        assert resumed.artifacts["m:out"] == [i + 100 for i in range(20)]
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# satellite regressions: speculation bound + concurrent scoring contexts
# ---------------------------------------------------------------------------

def test_speculation_respects_max_inflight_steps():
    """Straggler backups draw from the gateway's in-flight-step semaphore:
    with the bound saturated no backup launches; with slack the backup
    launches, is counted, and the bound still holds."""
    def straggle(tag):
        time.sleep(0.3)
        return tag

    def build(name):
        with couler.workflow(name) as ir:
            couler.run_step(straggle, name, step_name="s", cacheable=False,
                            est_time_s=0.02)
        return ir

    # saturated: two straggler steps occupy both slots -> no backups
    eng = LocalEngine(max_workers=4, max_inflight_steps=2,
                      straggler_factor=1.0, promote_interval_s=0.0)
    try:
        async def both():
            h1 = await couler.run_async(submitter=eng,
                                        workflow_ir=build("w1"),
                                        optimize=False)
            h2 = await couler.run_async(submitter=eng,
                                        workflow_ir=build("w2"),
                                        optimize=False)
            return await h1, await h2

        r1, r2 = asyncio.run(both())
        assert r1.status == r2.status == "Succeeded"
        assert eng.gateway.stats["peak_inflight_steps"] <= 2
        assert not r1.steps["s"].speculative
        assert not r2.steps["s"].speculative
    finally:
        eng.close()

    # slack: the backup launches and counts against the bound
    eng2 = LocalEngine(max_workers=4, max_inflight_steps=4,
                       straggler_factor=1.0, promote_interval_s=0.0)
    try:
        r = eng2.submit(build("w3"), optimize=False)
        assert r.steps["s"].speculative
        gw = eng2.gateway
        assert gw.stats["peak_inflight_steps"] == 2   # step + its backup
        deadline = time.time() + 2.0
        while gw._inflight_steps and time.time() < deadline:
            time.sleep(0.01)
        assert gw._inflight_steps == 0                # slot released
    finally:
        eng2.close()


def test_concurrent_workflows_keep_independent_scoring_contexts():
    """Artifacts offered with workflow= score against their own DAG even
    when another workflow was attached afterwards, and re-attaching
    registered workflows no longer bumps the store epoch (the thrash)."""
    from repro.core.cache.policies import CoulerPolicy
    from repro.core.cache.store import CacheStore

    def fan(name, width):
        with couler.workflow(name) as ir:
            mid = couler.run_step(lambda: 1, step_name="mid")
            for i in range(width):
                couler.run_step(lambda x: x, mid, step_name=f"c{i}")
        return ir

    w_wide, w_narrow = fan("wide", 6), fan("narrow", 1)
    store = CacheStore(capacity_bytes=1 << 20, policy=CoulerPolicy())
    store.attach_workflow(w_wide)
    store.attach_workflow(w_narrow)
    store.offer("a-wide", b"x" * 64, compute_time_s=1.0, producer="mid",
                workflow=w_wide)
    store.offer("a-narrow", b"x" * 64, compute_time_s=1.0, producer="mid",
                workflow=w_narrow)
    s_wide = store.policy.score(store.items["a-wide"], store)
    s_narrow = store.policy.score(store.items["a-narrow"], store)
    # same producer name, different DAGs: the wide fan-out has far more
    # Eq. 4 reuse value, which per-context scoring must preserve
    assert s_wide > s_narrow

    # equality with a dedicated single-workflow store (no cross-talk)
    solo = CacheStore(capacity_bytes=1 << 20, policy=CoulerPolicy())
    solo.attach_workflow(w_wide)
    solo.offer("a-wide", b"x" * 64, compute_time_s=1.0, producer="mid",
               workflow=w_wide)
    assert store.policy.score(store.items["a-wide"], store) == \
        pytest.approx(solo.policy.score(solo.items["a-wide"], solo))

    # the thrash is gone: alternating attach of registered workflows is free
    epoch = store._epoch
    for _ in range(5):
        store.attach_workflow(w_wide)
        store.attach_workflow(w_narrow)
    assert store._epoch == epoch
