"""HLO cost-parser unit tests, including the while-trip-count handling the
stock ``cost_analysis()`` gets wrong (it counts scan bodies once)."""
import textwrap

from repro.roofline.analysis import (_parse_computations, _trip_count,
                                     analyze_hlo, model_flops)

HLO = textwrap.dedent("""\
    HloModule test, num_partitions=8

    %body (param: (s32[], f32[4,16])) -> (s32[], f32[4,16]) {
      %param = (s32[], f32[4,16]{1,0}) parameter(0)
      %gte0 = s32[] get-tuple-element(%param), index=0
      %gte1 = f32[4,16]{1,0} get-tuple-element(%param), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot = f32[4,16]{1,0} dot(%gte1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[4,16]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%sum
      %one = s32[] constant(1)
      %next = s32[] add(%gte0, %one)
      ROOT %tup = (s32[], f32[4,16]{1,0}) tuple(%next, %ar)
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %add = f32[] add(%a, %b)
    }

    %cond (param.1: (s32[], f32[4,16])) -> pred[] {
      %param.1 = (s32[], f32[4,16]{1,0}) parameter(0)
      %it = s32[] get-tuple-element(%param.1), index=0
      %n = s32[] constant(12)
      ROOT %lt = pred[] compare(%it, %n), direction=LT
    }

    ENTRY %main (x: f32[4,16]) -> f32[4,16] {
      %x = f32[4,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[4,16]{1,0}) tuple(%zero, %x)
      %w2 = f32[16,8]{1,0} constant({...})
      %head = f32[4,8]{1,0} dot(%x, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ag = f32[4,64]{0,1} all-gather(%x), channel_id=2, replica_groups=[2,4]<=[8], dimensions={1}
      %loop = (s32[], f32[4,16]{1,0}) while(%init), condition=%cond, body=%body
      ROOT %out = f32[4,16]{1,0} get-tuple-element(%loop), index=1
    }
""")


def test_while_trip_count_multiplies_costs():
    t = analyze_hlo(HLO)
    # body dot: 2*4*16*16 = 2048 flops x 12 trips; head dot: 2*4*8*16 = 1024
    assert t.flops == 2048 * 12 + 1024
    # all-reduce in body: 4*16*4B=256B result, ring 2*(n-1)/n with n=4
    ar = 256 * 2 * 3 / 4 * 12
    ag = 4 * 64 * 4 * 3 / 4
    assert abs(t.coll_bytes - (ar + ag)) < 1e-6
    assert t.coll_by_kind["all-reduce"] == ar


def test_trip_count_ge_direction():
    comps, _ = _parse_computations(HLO)
    assert _trip_count(comps, "cond") == 12


def test_dominant_term_selection():
    t = analyze_hlo(HLO)
    assert t.dominant in ("compute", "memory", "collective")


def test_model_flops_kinds():
    from repro.configs import get_arch, get_shape
    cfg = get_arch("stablelm-1.6b").model
    n = cfg.param_counts()["active"]
    tr = model_flops(cfg, get_shape("train_4k"), n)
    pf = model_flops(cfg, get_shape("prefill_32k"), n)
    dc = model_flops(cfg, get_shape("decode_32k"), n)
    assert tr == 6.0 * n * 4096 * 256
    assert pf == 2.0 * n * 32768 * 32
    assert dc == 2.0 * n * 128
