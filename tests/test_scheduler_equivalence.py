"""Equivalence tests for the hot-path scheduler/cache refactor.

The event-driven schedulers must be *behaviorally identical* to the
pre-refactor reference implementations — same completion sets, same
simulated metrics, same admission decisions — on seeded random DAGs. The
reference multi-cluster scheduler below is a faithful copy of the old
O(events·V·E) full-rescan algorithm (predecessors via edge scans,
``launch_ready`` over every job of every active workflow per event).
"""
import heapq
import itertools
import random
import threading
import time

import pytest

from repro.core.caching import CacheStore, CoulerPolicy
from repro.core.engines.base import StepRecord, StepStatus, WorkflowRun
from repro.core.engines.cluster import Cluster, MultiClusterEngine, UserQuota
from repro.core.engines.local import LocalEngine
from repro.core.ir import Job, Resources, WorkflowIR


# ---------------------------------------------------------------------------
# seeded random DAGs
# ---------------------------------------------------------------------------

def random_dag(rng: random.Random, name: str, n_min=3, n_max=14,
               p_edge=0.3, gpu_frac=0.15) -> WorkflowIR:
    wf = WorkflowIR(name)
    n = rng.randint(n_min, n_max)
    for i in range(n):
        gpu = 1.0 if rng.random() < gpu_frac else 0.0
        wf.add_job(Job(name=f"j{i}",
                       est_time_s=round(rng.uniform(1, 50), 3),
                       resources=Resources(cpu=rng.choice([1, 2, 4, 8]),
                                           gpu=gpu)))
    for j in range(1, n):
        for i in range(j):
            if rng.random() < p_edge:
                wf.add_edge(f"j{i}", f"j{j}")
    return wf


# ---------------------------------------------------------------------------
# reference (pre-refactor) multi-cluster scheduler
# ---------------------------------------------------------------------------

def reference_submit_many(clusters, workflows):
    """Verbatim port of the old full-rescan submit_many. Returns
    (runs, metrics)."""
    seq = itertools.count()
    quotas = {}
    metrics = {"scheduled_jobs": 0, "completed_workflows": 0,
               "failed_admission": 0, "makespan_s": 0.0,
               "cluster_busy_s": {c.name: 0.0 for c in clusters}}

    def quota(user):
        if user not in quotas:
            quotas[user] = UserQuota()
        return quotas[user]

    def pick_cluster(job):
        cands = [c for c in clusters if c.fits(job)]
        if job.resources.gpu > 0:
            cands = [c for c in cands if c.gpu > 0]
        if not cands:
            return None
        return min(cands, key=lambda c: c.load())

    queue = []
    for wf, user, prio in workflows:
        wf.validate()
        heapq.heappush(queue, ((-prio, next(seq)), wf, user))
    runs, active, events = {}, [], []
    now = 0.0

    while queue:
        _, wf, user = heapq.heappop(queue)
        st = {"wf": wf, "user": user,
              "indeg": {n: len([s for (s, d) in wf.edges if d == n])
                        for n in wf.jobs},
              "remaining": len(wf.jobs), "run": WorkflowRun(workflow=wf)}
        for n in wf.jobs:
            st["run"].steps[n] = StepRecord()
        active.append(st)
        runs[wf.name] = st["run"]

    def launch_ready():
        for st in active:
            wf = st["wf"]
            for n, k in list(st["indeg"].items()):
                if k != 0 or st["run"].steps[n].status != StepStatus.PENDING:
                    continue
                job = wf.jobs[n]
                q = quota(st["user"])
                if not q.fits(job):
                    continue
                c = pick_cluster(job)
                if c is None:
                    metrics["failed_admission"] += 1
                    continue
                r = job.resources
                c.used_cpu += r.cpu
                c.used_mem += r.mem_bytes
                c.used_gpu += r.gpu
                q.used_cpu += r.cpu
                q.used_mem += r.mem_bytes
                q.used_gpu += r.gpu
                st["run"].steps[n].status = StepStatus.RUNNING
                st["run"].steps[n].start = now
                metrics["scheduled_jobs"] += 1
                heapq.heappush(events, (now + job.est_time_s, next(seq),
                                        c, st["user"], id(st), st, n))

    launch_ready()
    while events:
        now, _, c, user, _, st, n = heapq.heappop(events)
        job = st["wf"].jobs[n]
        r = job.resources
        c.used_cpu -= r.cpu
        c.used_mem -= r.mem_bytes
        c.used_gpu -= r.gpu
        q = quota(user)
        q.used_cpu -= r.cpu
        q.used_mem -= r.mem_bytes
        q.used_gpu -= r.gpu
        metrics["cluster_busy_s"][c.name] += job.est_time_s * r.cpu
        st["run"].steps[n].status = StepStatus.SUCCEEDED
        st["run"].steps[n].end = now
        st["remaining"] -= 1
        for s2 in [d for (s, d) in st["wf"].edges if s == n]:
            st["indeg"][s2] -= 1
        if st["remaining"] == 0:
            st["run"].status = "Succeeded"
            st["run"].wall_time_s = now
            metrics["completed_workflows"] += 1
        launch_ready()
    metrics["makespan_s"] = now
    return runs, metrics


def _clusters(tight=False):
    if tight:
        return [Cluster("gpu", cpu=12, mem_bytes=1 << 40, gpu=2),
                Cluster("cpu-a", cpu=16, mem_bytes=1 << 40),
                Cluster("cpu-b", cpu=10, mem_bytes=1 << 40)]
    return [Cluster("gpu", cpu=256, mem_bytes=1 << 50, gpu=32),
            Cluster("cpu-a", cpu=1024, mem_bytes=1 << 50),
            Cluster("cpu-b", cpu=1024, mem_bytes=1 << 50)]


@pytest.mark.parametrize("seed,tight", [(0, False), (1, False), (2, True),
                                        (3, True), (4, True)])
def test_submit_many_matches_reference(seed, tight):
    """Makespan, scheduled_jobs, busy time, per-step times, and completion
    sets must be identical to the pre-refactor full-rescan scheduler —
    including under tight cluster capacity and user quotas (the blocked
    retry paths) and GPU-only routing."""
    rng = random.Random(seed)
    batch1 = [(random_dag(rng, f"wf-{i}"), f"u{i % 3}", rng.randint(0, 2))
              for i in range(12)]
    rng = random.Random(seed)        # identical DAGs for the reference
    batch2 = [(random_dag(rng, f"wf-{i}"), f"u{i % 3}", rng.randint(0, 2))
              for i in range(12)]

    eng = MultiClusterEngine(clusters=_clusters(tight))
    runs = eng.submit_many(batch1)
    ref_runs, ref_metrics = reference_submit_many(_clusters(tight), batch2)

    assert eng.metrics["makespan_s"] == ref_metrics["makespan_s"]
    assert eng.metrics["scheduled_jobs"] == ref_metrics["scheduled_jobs"]
    assert eng.metrics["completed_workflows"] == \
        ref_metrics["completed_workflows"]
    assert eng.metrics["failed_admission"] == ref_metrics["failed_admission"]
    assert eng.metrics["cluster_busy_s"] == ref_metrics["cluster_busy_s"]
    assert set(runs) == set(ref_runs)
    for name, run in runs.items():
        ref = ref_runs[name]
        assert run.status == ref.status, name
        # identical completion sets AND identical per-step schedule times
        for n, rec in run.steps.items():
            rref = ref.steps[n]
            assert rec.status == rref.status, (name, n)
            assert rec.start == rref.start, (name, n)
            assert rec.end == rref.end, (name, n)


def test_submit_many_quota_starvation_matches_reference():
    """A job larger than its user's entire quota never launches; everything
    else must still complete exactly as in the reference."""
    wf = WorkflowIR("starve")
    wf.add_job(Job(name="huge", est_time_s=5.0,
                   resources=Resources(cpu=1000.0)))
    wf.add_job(Job(name="ok", est_time_s=2.0, resources=Resources(cpu=2.0)))
    wf2 = WorkflowIR("starve")
    wf2.add_job(Job(name="huge", est_time_s=5.0,
                    resources=Resources(cpu=1000.0)))
    wf2.add_job(Job(name="ok", est_time_s=2.0, resources=Resources(cpu=2.0)))

    eng = MultiClusterEngine(clusters=[
        Cluster("big", cpu=4096, mem_bytes=1 << 50)])
    run = eng.submit_many([(wf, "u0", 0)])["starve"]
    ref_runs, ref_metrics = reference_submit_many(
        [Cluster("big", cpu=4096, mem_bytes=1 << 50)], [(wf2, "u0", 0)])
    ref = ref_runs["starve"]
    assert run.steps["huge"].status == ref.steps["huge"].status \
        == StepStatus.PENDING
    assert run.steps["ok"].status == ref.steps["ok"].status \
        == StepStatus.SUCCEEDED
    assert eng.metrics["makespan_s"] == ref_metrics["makespan_s"]
    assert eng.metrics["scheduled_jobs"] == ref_metrics["scheduled_jobs"]


# ---------------------------------------------------------------------------
# local engine: completion sets + per-step ordering constraints
# ---------------------------------------------------------------------------

def test_local_engine_respects_dag_order_on_random_dags():
    """Push-based scheduling must run every job exactly once and never
    start a job before all its predecessors finished."""
    for seed in range(4):
        rng = random.Random(100 + seed)
        wf = WorkflowIR(f"loc-{seed}")
        n = rng.randint(5, 18)
        spans = {}
        lock = threading.Lock()

        def mk(name):
            def fn(*a):
                t0 = time.monotonic()
                time.sleep(rng.uniform(0.001, 0.004))
                with lock:
                    spans[name] = (t0, time.monotonic())
                return name
            return fn

        for i in range(n):
            wf.add_job(Job(name=f"j{i}", fn=mk(f"j{i}"), cacheable=False,
                           outputs=[f"j{i}:out"]))
        for j in range(1, n):
            for i in range(j):
                if rng.random() < 0.35:
                    wf.add_edge(f"j{i}", f"j{j}")

        eng = LocalEngine(max_workers=4, enable_speculation=False)
        run = eng.submit(wf, optimize=False)
        assert run.succeeded()
        assert set(spans) == set(wf.jobs)                 # each ran once
        statuses = {n_: r.status for n_, r in run.steps.items()}
        assert all(s == StepStatus.SUCCEEDED for s in statuses.values())
        for (u, v) in wf.edges:                           # ordering constraint
            assert spans[u][1] <= spans[v][0], (u, v)


def test_local_engine_failure_stops_descendants():
    wf = WorkflowIR("fail")
    ran = []
    wf.add_job(Job(name="a", fn=lambda: ran.append("a") or 1,
                   cacheable=False, outputs=["a:out"]))
    wf.add_job(Job(name="b", fn=lambda: (_ for _ in ()).throw(
        ValueError("boom")), cacheable=False, retry_limit=0))
    wf.add_job(Job(name="c", fn=lambda: ran.append("c") or 3,
                   cacheable=False))
    wf.add_edge("a", "b")
    wf.add_edge("b", "c")
    run = LocalEngine(enable_speculation=False).submit(wf, optimize=False)
    assert not run.succeeded()
    assert run.steps["b"].status == StepStatus.FAILED
    assert run.steps["c"].status == StepStatus.PENDING    # never launched
    assert "c" not in ran


# ---------------------------------------------------------------------------
# cache scoring memo invalidation
# ---------------------------------------------------------------------------

def _fan(name, fanout):
    wf = WorkflowIR(name)
    wf.add_job(Job(name="root", est_time_s=5))
    wf.add_job(Job(name="mid", est_time_s=3))
    wf.add_edge("root", "mid")
    for i in range(fanout):
        wf.add_job(Job(name=f"leaf{i}", est_time_s=1))
        wf.add_edge("mid", f"leaf{i}")
    return wf


def test_memo_invalidated_across_attach_workflow():
    """The Eq.3/4 memo must not leak scores across differently-structured
    workflows attached to the same store."""
    pol = CoulerPolicy()
    store = CacheStore(capacity_bytes=1000, policy=pol)
    store.offer("mid:out", b"x" * 10, 1.0, producer="mid")
    art = store.items["mid:out"]

    store.attach_workflow(_fan("w1", 6))
    high = pol.score(art, store)
    store.attach_workflow(_fan("w2", 1))   # same names, much lower fan-out
    low = pol.score(art, store)
    assert low < high
    # re-attaching the high-fanout structure recovers the high score
    store.attach_workflow(_fan("w3", 6))
    assert pol.score(art, store) == high


def test_memo_invalidated_by_structure_and_weights_mutation():
    pol = CoulerPolicy()
    store = CacheStore(capacity_bytes=1000, policy=pol)
    store.offer("mid:out", b"x" * 10, 1.0, producer="mid")
    art = store.items["mid:out"]
    wf = _fan("w", 2)
    store.attach_workflow(wf)
    s0 = pol.score(art, store)

    # structural mutation (add a consumer) must be visible immediately
    wf.add_job(Job(name="extra", est_time_s=1))
    wf.add_edge("mid", "extra")
    s1 = pol.score(art, store)
    assert s1 > s0

    # est_time_s refinement + note_weights_changed must drop Eq.3 memos
    wf.jobs["root"].est_time_s *= 100
    wf.note_weights_changed()
    s2 = pol.score(art, store)
    assert s2 > s1
