import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.caching import CacheStore, CacheAll
from repro.training.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"mu": {"w": jnp.ones((8, 8)), "b": jnp.zeros((8,))}},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(7, st)
    assert mgr.latest_step() == 7
    back = mgr.restore(like=st)
    np.testing.assert_allclose(back["params"]["w"], st["params"]["w"])
    assert int(back["step"]) == 7


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_async_save_overlaps(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    t = mgr.async_save(3, st)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_restore_registers_cache_artifact(tmp_path):
    cache = CacheStore(capacity_bytes=1 << 20, policy=CacheAll())
    mgr = CheckpointManager(str(tmp_path), cache=cache)
    mgr.save(5, _state())
    assert any(k.startswith("ckpt:") for k in cache.items)


def test_restart_continues_training(tmp_path):
    """Fault-tolerance path: train, checkpoint, 'crash', restore, continue."""
    from repro.configs import get_arch, reduced
    from repro.training import train as TR
    from repro.data.pipeline import synthetic_batches

    spec = get_arch("stablelm-1.6b")
    cfg = reduced(spec.model).replace(param_dtype="float32",
                                      compute_dtype="float32")
    tcfg = spec.train.__class__(optimizer="adamw", learning_rate=1e-3,
                                remat="none")
    state = TR.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(TR.make_train_step(cfg, tcfg))
    batches = list(synthetic_batches(4, 16, cfg.vocab_size, n=6))
    for b in batches[:3]:
        state, m = step(state, b)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(int(state["step"]), state)

    # simulate crash: fresh process state, restore, continue
    restored = mgr.restore(like=jax.tree.map(np.asarray, state))
    assert int(restored["step"]) == 3
    state2 = jax.tree.map(jnp.asarray, restored)
    for b in batches[3:]:
        state2, m = step(state2, b)
    assert int(state2["step"]) == 6
    assert jnp.isfinite(m["loss"])
