from repro.core.autotune import (DataCard, ModelCard, default_search_space,
                                 train_real_model, tune)


def test_tune_picks_reasonable_lr():
    r = tune(DataCard("d", n_examples=100_000),
             ModelCard("m", n_params=1e8))
    lr = r.best["learning_rate"]
    assert 1e-4 <= lr <= 1e-2
    assert len(r.predicted_logs) == len(default_search_space())


def test_tune_scales_lr_with_model_size():
    small = tune(DataCard("d"), ModelCard("m", n_params=1e6)).best
    big = tune(DataCard("d"), ModelCard("m", n_params=1e10)).best
    assert small["learning_rate"] >= big["learning_rate"]


def test_real_model_training_improves():
    out = train_real_model({"learning_rate": 3e-3, "batch_size": 16},
                           steps=40)
    assert out["losses"][0] > out["final_loss"]


def test_real_model_bad_lr_is_worse():
    good = train_real_model({"learning_rate": 3e-3, "batch_size": 16},
                            steps=30)
    bad = train_real_model({"learning_rate": 3.0, "batch_size": 16},
                           steps=30)
    assert good["final_loss"] < bad["final_loss"]
