import threading
import time

import pytest

from repro.core import couler
from repro.core.caching import CacheStore, CoulerPolicy
from repro.core.engines.airflow import to_airflow_dag
from repro.core.engines.argo import ArgoSubmitter, to_argo_yaml
from repro.core.engines.base import StepStatus, TransientError
from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.engines.local import LocalEngine
from repro.core.ir import Job, Resources, WorkflowIR


def test_retry_on_transient_error():
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise TransientError("TooManyRequestsErr: api-server busy")
        return "ok"

    with couler.workflow("flaky") as ir:
        couler.run_step(flaky, step_name="s", retry_limit=5)
    run = LocalEngine(retry_backoff_s=0.001).submit(ir)
    assert run.succeeded()
    assert run.steps["s"].attempts == 3


def test_permanent_error_fails_workflow():
    def boom():
        raise ValueError("not transient")

    with couler.workflow("boom") as ir:
        couler.run_step(boom, step_name="s", retry_limit=5)
    run = LocalEngine().submit(ir)
    assert not run.succeeded()
    assert run.steps["s"].attempts == 1         # no retry on permanent


def test_resume_from_failure_skips_done_steps():
    """App B.B: restart skips Succeeded/Cached; reruns the failed step."""
    state = {"fail": True, "a_runs": 0}

    def a():
        state["a_runs"] += 1
        return "A"

    def b(x):
        if state["fail"]:
            raise ValueError("crash")
        return x + "B"

    with couler.workflow("resume") as ir:
        oa = couler.run_step(a, step_name="a", cacheable=False)
        couler.run_step(b, oa, step_name="b", cacheable=False)
    eng = LocalEngine()
    run = eng.submit(ir)
    assert not run.succeeded()
    assert run.steps["a"].status == StepStatus.SUCCEEDED
    state["fail"] = False
    run2 = eng.resume(run)
    assert run2.succeeded()
    assert state["a_runs"] == 1                  # a NOT re-executed
    assert run2.artifacts["b:out"] == "AB"


def test_cache_skips_recompute_across_runs():
    calls = {"n": 0}

    def expensive():
        calls["n"] += 1
        return 42

    cache = CacheStore(capacity_bytes=1 << 20, policy=CoulerPolicy())
    eng = LocalEngine(cache=cache)

    def build():
        with couler.workflow("cached") as ir:
            couler.run_step(expensive, step_name="big")
        return ir

    r1 = eng.submit(build())
    r2 = eng.submit(build())
    assert calls["n"] == 1
    assert r2.steps["big"].status == StepStatus.CACHED
    assert r2.artifacts["big:out"] == 42


def test_straggler_speculation():
    slow_once = {"first": True}

    def maybe_slow():
        if slow_once["first"]:
            slow_once["first"] = False
            time.sleep(1.0)                     # straggler
            return "slow"
        return "fast"

    with couler.workflow("strag") as ir:
        couler.run_step(maybe_slow, step_name="s", est_time_s=0.02,
                        cacheable=False)
    eng = LocalEngine(straggler_factor=2.0)
    t0 = time.time()
    run = eng.submit(ir)
    assert run.succeeded()
    assert run.artifacts["s:out"] == "fast"     # speculative copy won
    assert run.steps["s"].speculative
    assert time.time() - t0 < 1.0


def test_parallelism_actually_parallel():
    barrier = threading.Barrier(4, timeout=5)

    def wait():
        barrier.wait()
        return 1

    with couler.workflow("par") as ir:
        couler.concurrent([
            lambda i=i: couler.run_step(wait, step_name=f"p{i}",
                                        cacheable=False)
            for i in range(4)])
    run = LocalEngine(max_workers=4, enable_speculation=False).submit(ir)
    assert run.succeeded()


def test_argo_yaml_generation_and_budget():
    with couler.workflow("y") as ir:
        a = couler.run_container(image="img:1", command=["run"], step_name="a")
        couler.run_container(image="img:2", command=["run"], step_name="b",
                             fn=None)
        couler.when(couler.equal(a, "x"),
                    lambda: couler.run_container(image="img:3", step_name="c"))
    y = to_argo_yaml(ir)
    assert "apiVersion: argoproj.io/v1alpha1" in y
    assert "dependencies: [a]" in y
    assert "when:" in y
    run = ArgoSubmitter().submit(ir)
    assert run.status == "Generated"
    assert len(run.artifacts["argo:manifests"]) == 1


def test_airflow_generation():
    with couler.workflow("af") as ir:
        a = couler.run_step(lambda: 1, step_name="a")
        couler.run_step(lambda x: x, a, step_name="b")
    src = to_airflow_dag(ir)
    assert "PythonOperator" in src and "t_a >> t_b" in src
    compile(src, "<dag>", "exec")               # syntactically valid python


def test_multicluster_scheduling_and_quota():
    wf = WorkflowIR("mc")
    for i in range(8):
        wf.add_job(Job(name=f"j{i}", est_time_s=1.0,
                       resources=Resources(cpu=4)))
    eng = MultiClusterEngine(clusters=[
        Cluster("a", cpu=8, mem_bytes=1 << 40),
        Cluster("b", cpu=8, mem_bytes=1 << 40),
    ])
    run = eng.submit(wf)
    assert run.succeeded()
    # 8 jobs x 4 cpu on 16 cpus -> 2 waves of 4 -> makespan 2s
    assert eng.metrics["makespan_s"] == pytest.approx(2.0)
    busy = eng.metrics["cluster_busy_s"]
    assert busy["a"] > 0 and busy["b"] > 0      # load balanced


def test_gpu_jobs_require_gpu_cluster():
    wf = WorkflowIR("gpu")
    wf.add_job(Job(name="g", est_time_s=1.0,
                   resources=Resources(cpu=1, gpu=1)))
    eng = MultiClusterEngine(clusters=[
        Cluster("cpu-only", cpu=64, mem_bytes=1 << 40, gpu=0),
        Cluster("gpu", cpu=64, mem_bytes=1 << 40, gpu=8),
    ])
    run = eng.submit(wf)
    assert run.succeeded()
    assert eng.metrics["cluster_busy_s"]["gpu"] > 0
    assert eng.metrics["cluster_busy_s"]["cpu-only"] == 0
