import pytest

from repro.core.ir import Condition, Job, Resources, WorkflowIR


def make_chain(n=5):
    wf = WorkflowIR("chain")
    prev = None
    for i in range(n):
        wf.add_job(Job(name=f"j{i}", est_time_s=float(i + 1)))
        if prev:
            wf.add_edge(prev, f"j{i}")
        prev = f"j{i}"
    return wf


def test_topo_and_validate():
    wf = make_chain()
    assert wf.topo_order() == [f"j{i}" for i in range(5)]
    wf.validate()


def test_cycle_detection():
    wf = make_chain(3)
    wf.add_edge("j2", "j0")
    with pytest.raises(ValueError):
        wf.topo_order()


def test_critical_path():
    wf = WorkflowIR("d")
    for n, t in [("a", 1), ("b", 5), ("c", 1), ("d", 1)]:
        wf.add_job(Job(name=n, est_time_s=t))
    wf.add_edge("a", "b")
    wf.add_edge("a", "c")
    wf.add_edge("b", "d")
    wf.add_edge("c", "d")
    total, path = wf.critical_path()
    assert total == 7 and path == ["a", "b", "d"]


def test_adjacency_and_degrees():
    wf = make_chain(3)
    A = wf.adjacency()
    assert A.sum() == 2
    d = wf.degrees()
    assert list(d) == [1, 2, 1]


def test_json_roundtrip():
    wf = make_chain(4)
    wf.jobs["j1"].condition = Condition("equal", "j0:out", "x")
    wf.jobs["j2"].resources = Resources(cpu=4, mem_bytes=123)
    wf2 = WorkflowIR.from_json(wf.to_json())
    assert set(wf2.jobs) == set(wf.jobs)
    assert wf2.edges == wf.edges
    assert wf2.jobs["j1"].condition.kind == "equal"
    assert wf2.jobs["j2"].resources.cpu == 4
    assert wf2.fingerprint() == WorkflowIR.from_json(wf2.to_json()).fingerprint()


def test_budget_components():
    wf = make_chain(10)
    b = wf.budget()
    assert b["steps"] == 10
    assert b["spec_bytes"] > 0
    assert b["pods"] >= 10


def test_self_edge_rejected():
    wf = make_chain(2)
    with pytest.raises(ValueError):
        wf.add_edge("j0", "j0")
