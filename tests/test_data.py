import numpy as np

from repro.core.caching import CacheStore, CacheAll
from repro.data.pipeline import (CachedShardReader, ShardedCorpus,
                                 synthetic_batches)


def test_synthetic_batches_deterministic():
    a = list(synthetic_batches(2, 8, 64, seed=1, n=3))
    b = list(synthetic_batches(2, 8, 64, seed=1, n=3))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    assert a[0]["tokens"].shape == (2, 8)
    # targets are tokens shifted by one
    np.testing.assert_array_equal(a[0]["tokens"][:, 1:], a[0]["targets"][:, :-1])


def test_corpus_materialize_and_read(tmp_path):
    c = ShardedCorpus(str(tmp_path), n_shards=3, tokens_per_shard=128,
                      vocab=64, seed=0)
    paths = c.materialize()
    assert len(paths) == 3 and all(p.exists() for p in paths)
    arr = c.read_shard(0)
    assert arr.shape == (128,) and arr.dtype == np.int32
    assert arr.max() < 64


def test_cached_reader_hits_second_epoch(tmp_path):
    c = ShardedCorpus(str(tmp_path), n_shards=4, tokens_per_shard=256,
                      vocab=64, read_delay_s=0.01)
    c.materialize()
    r = CachedShardReader(c, cache=CacheStore(capacity_bytes=1 << 20,
                                              policy=CacheAll()))
    list(r.epoch())
    assert r.cache.stats["hits"] == 0
    list(r.epoch())
    assert r.cache.stats["hits"] == 4
    # cached reads are much faster than the simulated remote reads
    cold = r.read_times[:4]
    warm = r.read_times[4:]
    assert np.mean(warm) < np.mean(cold)


def test_batches_shapes(tmp_path):
    c = ShardedCorpus(str(tmp_path), n_shards=2, tokens_per_shard=512,
                      vocab=32)
    c.materialize()
    r = CachedShardReader(c)
    bs = list(r.batches(batch=4, seq=16))
    assert len(bs) >= 5
    assert bs[0]["tokens"].shape == (4, 16)
