"""Per-arch smoke tests: REDUCED same-family configs, one forward/train step
on CPU, asserting output shapes + no NaNs (task spec requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.models import transformer as T
from repro.training import train as TR


def _reduced(aid):
    spec = get_arch(aid)
    cfg = reduced(spec.model).replace(param_dtype="float32",
                                      compute_dtype="float32")
    return cfg, spec.train


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
         "targets": jnp.ones((B, S), jnp.int32) * 5}
    if cfg.family == "encdec":
        b["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        b["patches"] = jnp.ones((B, cfg.num_patches, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_train_step_smoke(aid):
    cfg, tcfg = _reduced(aid)
    state = TR.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(TR.make_train_step(cfg, tcfg))
    state, m = step(state, _batch(cfg))
    assert jnp.isfinite(m["loss"])
    assert int(state["step"]) == 1
    # a second step must also be finite (optimizer state exercised)
    state, m2 = step(state, _batch(cfg))
    assert jnp.isfinite(m2["loss"])


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_forward_shapes(aid):
    cfg, tcfg = _reduced(aid)
    params = T.init_lm(jax.random.PRNGKey(1), cfg)
    B, S = 2, 32
    b = _batch(cfg, B, S)
    logits, aux = T.apply_lm(params, cfg, b["tokens"],
                             frames=b.get("frames"), patches=b.get("patches"))
    S_out = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_decode_step(aid):
    cfg, tcfg = _reduced(aid)
    params = T.init_lm(jax.random.PRNGKey(2), cfg)
    B = 2
    caches = T.init_caches(cfg, B, 16, jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    fn = jax.jit(lambda p, t, c, i: T.apply_lm_decode(p, cfg, t, c, i))
    logits, caches = fn(params, tok, caches, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    logits2, _ = fn(params, tok, caches, jnp.int32(1))
    assert not bool(jnp.any(jnp.isnan(logits2)))


def test_decode_matches_forward_dense():
    """Sequential decode must reproduce the full forward logits (GQA path)."""
    cfg, _ = _reduced("stablelm-1.6b")
    params = T.init_lm(jax.random.PRNGKey(3), cfg)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, 100)
    full_logits, _ = T.apply_lm(params, cfg, toks)
    caches = T.init_caches(cfg, B, S, jnp.float32)
    outs = []
    for i in range(S):
        lg, caches = T.apply_lm_decode(params, cfg, toks[:, i:i+1], caches,
                                       jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.allclose(full_logits, dec, atol=2e-2, rtol=2e-2), (
        float(jnp.max(jnp.abs(full_logits - dec))))


def test_decode_matches_forward_ssm():
    """Recurrent SSM decode must match the chunked full-sequence forward."""
    cfg, _ = _reduced("mamba2-370m")
    params = T.init_lm(jax.random.PRNGKey(5), cfg)
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0, 100)
    full_logits, _ = T.apply_lm(params, cfg, toks)
    caches = T.init_caches(cfg, B, S, jnp.float32)
    outs = []
    for i in range(S):
        lg, caches = T.apply_lm_decode(params, cfg, toks[:, i:i+1], caches,
                                       jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.allclose(full_logits, dec, atol=2e-2, rtol=2e-2), (
        float(jnp.max(jnp.abs(full_logits - dec))))


def test_param_counts_sane():
    for aid in ARCH_IDS:
        cfg = get_arch(aid).model
        c = cfg.param_counts()
        assert c["total"] >= c["active"] > 0
    assert get_arch("deepseek-v3-671b").model.param_counts()["total"] > 5e11
    assert get_arch("mamba2-370m").model.param_counts()["total"] < 6e8


def _decode_matches_forward(aid, S=8, atol=2e-2):
    cfg, _ = _reduced(aid)
    params = T.init_lm(jax.random.PRNGKey(7), cfg)
    B = 1
    toks = jax.random.randint(jax.random.PRNGKey(8), (B, S), 0, 100)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model)) * 0.1
    full_logits, _ = T.apply_lm(params, cfg, toks, **kwargs)
    caches = T.init_caches(cfg, B, S, jnp.float32)
    if cfg.family == "encdec":
        # populate cross-attention caches from the encoder output
        from repro.models import layers as L, attention as A
        he = kwargs["frames"]
        Se = he.shape[1]
        epos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32)[None], (B, Se))
        def ebody(hh, lp):
            from repro.models.transformer import _dense_body
            return _dense_body(cfg, lp, hh, epos, prefix_len=jnp.int32(Se)), None
        he, _ = jax.lax.scan(ebody, he, params["enc_layers"])
        he = L.apply_rmsnorm(params["enc_norm"], he, cfg.norm_eps)
        hd, H, KH = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
        def fill(cc, lp):
            k = (he @ lp["cross_attn"]["wk"]).reshape(B, Se, KH, hd)
            v = (he @ lp["cross_attn"]["wv"]).reshape(B, Se, KH, hd)
            return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}
        caches["cross"] = jax.vmap(
            lambda lp: fill(None, lp))(params["dec_layers"])
    outs = []
    for i in range(S):
        lg, caches = T.apply_lm_decode(params, cfg, toks[:, i:i + 1], caches,
                                       jnp.int32(i))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(full_logits - dec)))
    assert err < atol, err


def test_decode_matches_forward_mla():
    """Absorbed-matmul MLA decode == full (non-absorbed) forward."""
    _decode_matches_forward("deepseek-v3-671b")


def test_decode_matches_forward_moe():
    _decode_matches_forward("olmoe-1b-7b")


def test_decode_matches_forward_hybrid():
    _decode_matches_forward("zamba2-1.2b")


def test_decode_matches_forward_gqa_kv_lt_heads():
    _decode_matches_forward("mistral-nemo-12b")


def test_decode_matches_forward_encdec():
    _decode_matches_forward("whisper-large-v3")


def test_paper_workload_bonus_archs():
    """§VI workload models (nanoGPT, ViT) train on CPU (bonus configs)."""
    from repro.configs.paper_workload import BONUS_ARCHS
    from repro.configs import reduced
    for aid, spec in BONUS_ARCHS.items():
        cfg = reduced(spec.model).replace(param_dtype="float32",
                                          compute_dtype="float32")
        state = TR.init_train_state(cfg, spec.train, jax.random.PRNGKey(0))
        step = jax.jit(TR.make_train_step(cfg, spec.train))
        state, m = step(state, _batch(cfg))
        assert jnp.isfinite(m["loss"]), aid
