import pytest

from repro.core.autosplit import (Budget, cross_edges, schedule_parts,
                                  split_workflow, validate_split)
from repro.core.ir import Job, WorkflowIR


def chain(n):
    wf = WorkflowIR("chain")
    prev = None
    for i in range(n):
        wf.add_job(Job(name=f"j{i}"))
        if prev:
            wf.add_edge(prev, f"j{i}")
        prev = f"j{i}"
    return wf


def wide(n):
    wf = WorkflowIR("wide")
    wf.add_job(Job(name="root"))
    for i in range(n):
        wf.add_job(Job(name=f"w{i}"))
        wf.add_edge("root", f"w{i}")
    return wf


def test_small_workflow_not_split():
    wf = chain(10)
    subs = split_workflow(wf, Budget(steps=200))
    assert len(subs) == 1


def test_chain_split_respects_budget():
    wf = chain(500)
    b = Budget(steps=100)
    subs = split_workflow(wf, b)
    assert len(subs) == 5
    validate_split(wf, subs, b)


def test_wide_split_parallel_waves():
    wf = wide(300)
    b = Budget(steps=100)
    subs = split_workflow(wf, b)
    validate_split(wf, subs, b)
    waves = schedule_parts(wf, subs)
    # after the root's part completes, the rest can run in parallel
    assert len(waves) <= len(subs)


def test_spec_bytes_budget():
    wf = chain(100)
    b = Budget(spec_bytes=2000, steps=10_000)
    subs = split_workflow(wf, b)
    assert len(subs) > 1
    validate_split(wf, subs, b)


def test_cross_edges_flow_forward():
    wf = chain(300)
    subs = split_workflow(wf, Budget(steps=64))
    for s, d, a, b in cross_edges(wf, subs):
        assert a < b, "cross edge must flow to a later part"
