import pytest

from repro.core.engines.local import LocalEngine
from repro.core.sqlflow import (PredictStatement, TrainStatement, parse,
                                run_sql, to_workflow)

TRAIN_SQL = """
SELECT * FROM iris.train
TO TRAIN DNNClassifier
WITH model.n_classes = 3, model.hidden_units = [10]
COLUMN sepal_len, sepal_width, petal_length
LABEL class
INTO sqlflow_models.my_dnn_model;
"""

PREDICT_SQL = """
SELECT * FROM iris.test
TO PREDICT iris.predict.class
USING sqlflow_models.my_dnn_model;
"""


def test_parse_train():
    s = parse(TRAIN_SQL)
    assert isinstance(s, TrainStatement)
    assert s.table == "iris.train"
    assert s.estimator == "DNNClassifier"
    assert s.attrs["model.n_classes"] == 3
    assert s.attrs["model.hidden_units"] == [10]
    assert s.columns == ["sepal_len", "sepal_width", "petal_length"]
    assert s.label == "class"
    assert s.into == "sqlflow_models.my_dnn_model"


def test_parse_predict():
    s = parse(PREDICT_SQL)
    assert isinstance(s, PredictStatement)
    assert s.model == "sqlflow_models.my_dnn_model"
    assert s.output == "iris.predict.class"


def test_parse_rejects_garbage():
    with pytest.raises(ValueError):
        parse("DROP TABLE users;")


def test_train_statement_builds_and_runs():
    ir = to_workflow(TRAIN_SQL)
    assert list(ir.topo_order()) == ["select", "train", "save-model"]
    run = LocalEngine().submit(ir)
    assert run.succeeded()
    saved = run.artifacts["save-model:out"]
    assert saved["saved_as"] == "sqlflow_models.my_dnn_model"
    assert saved["weights"].shape == (3, 3)


def test_train_then_predict_pipeline():
    run1 = run_sql(TRAIN_SQL)
    model = run1.artifacts["save-model:out"]
    run2 = run_sql(PREDICT_SQL,
                   model_registry={model["saved_as"]: model})
    assert run2.succeeded()
    out = run2.artifacts["predict:out"]
    assert out["output"] == "iris.predict.class"
    assert len(out["preds"]) == 64
