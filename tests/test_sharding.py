"""Pure-logic sharding tests (no multi-device runtime needed — mesh stubs).
Real-mesh behaviour is covered by tests/test_distributed.py subprocesses."""
from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.sharding.ctx import logical_to_spec
from repro.sharding.rules import (DEFAULT_RULES, FSDP_RULES,
                                  batch_logical_axes, cache_logical_axes,
                                  param_logical_axes, rules_for)


class FakeMesh(SimpleNamespace):
    pass


MESH = FakeMesh(shape={"data": 16, "model": 16})
MESH3 = FakeMesh(shape={"pod": 2, "data": 16, "model": 16})


def spec(axes, shape, mesh=MESH, rules=DEFAULT_RULES):
    return logical_to_spec(axes, shape, mesh, rules)


def test_basic_resolution():
    assert spec(("vocab", "embed"), (51200, 2048)) == P("model")
    assert spec(("embed", "mlp"), (2048, 5632)) == P(None, "model")
    assert spec(("batch", None), (256, 4096)) == P("data")


def test_divisibility_fallback_drops_axis():
    # 20 heads % 16 -> dropped
    assert spec(("batch", "kv_heads", "kv_seq", None),
                (32, 20, 32768, 64)) == P("data", None, "model")
    # divisible heads win before kv_seq
    assert spec(("batch", "kv_heads", "kv_seq", None),
                (32, 32, 32768, 64)) == P("data", "model")
    # batch smaller than the data axis -> batch unsharded too
    assert spec(("batch", "kv_heads", "kv_seq", None),
                (8, 20, 32768, 64)) == P(None, None, "model")


def test_no_double_axis_use():
    s = spec(("vocab", "mlp"), (512, 512))
    # both want 'model' but an axis is used at most once
    assert s == P("model") or s == P("model", None)


def test_multipod_batch_axes():
    assert spec(("batch", None), (256, 4096), mesh=MESH3) == P(("pod", "data"))
    # batch=1 -> nothing shards
    assert spec(("batch", None), (1, 4096), mesh=MESH3) == P()


def test_fsdp_rules_shard_embed_dim():
    assert logical_to_spec(("embed", "mlp"), (7168, 2048), MESH,
                           FSDP_RULES) == P("data", "model")
    assert rules_for("deepseek-v3-671b") is FSDP_RULES
    assert rules_for("stablelm-1.6b") is DEFAULT_RULES


class _K:
    def __init__(self, k):
        self.key = k


def _axes(path, shape):
    return param_logical_axes(tuple(_K(p) for p in path), shape)


def test_param_path_mapping():
    assert _axes(("embed", "table"), (51200, 2048)) == ("vocab", "embed")
    assert _axes(("layers", "attn", "wq"), (24, 2048, 2048)) == \
        (None, "embed", "heads")
    assert _axes(("layers", "moe", "experts", "gate"),
                 (16, 64, 2048, 1024)) == (None, "expert", "embed", "mlp")
    assert _axes(("layers", "ssm", "in_x"), (48, 1024, 2048)) == \
        (None, "embed", "ssm_inner")
    assert _axes(("groups", "0", "ssm", "conv_x"), (6, 6, 4, 4224)) == \
        (None, None, None, "ssm_inner")
    assert _axes(("final_norm", "scale"), (2048,)) == (None,)
    assert _axes(("lm_head", "w"), (2048, 51200)) == ("embed", "vocab")


def test_cache_path_mapping():
    def c(path, shape):
        return cache_logical_axes(tuple(_K(p) for p in path), shape)
    assert c(("layers", "k"), (24, 8, 32, 1024, 128)) == \
        (None, "batch", "kv_heads", "kv_seq", None)
    assert c(("layers", "c_kv"), (58, 8, 32768, 512)) == \
        (None, "batch", "kv_seq", None)
    assert c(("layers", "state"), (48, 8, 32, 64, 128)) == \
        (None, "batch", "ssm_heads", None, None)


def test_head_aware_fallback():
    """kv_heads=8 vs TP=16 -> wk/wv switch to contraction sharding."""
    from repro.sharding.rules import _head_aware
    cfg = get_arch("mistral-nemo-12b").model
    fn = _head_aware(param_logical_axes, cfg, MESH)
    assert fn(tuple(_K(p) for p in ("layers", "attn", "wk")),
              (40, 5120, 1024)) == (None, "tp", None)
    # q heads divide -> unchanged
    assert fn(tuple(_K(p) for p in ("layers", "attn", "wq")),
              (40, 5120, 4096)) == (None, "embed", "heads")


def test_batch_mapping():
    def b(path, shape):
        return batch_logical_axes(tuple(_K(p) for p in path), shape)
    assert b(("tokens",), (256, 4096)) == ("batch", None)
    assert b(("patches",), (256, 256, 2048)) == ("batch", None, None)
