"""Static analyzer (repro.core.analysis) tests: property-based no-false-
positive checks over random valid DAGs, mutation operators that must each
trip the right CLR diagnostic, the submission-time lint gate, and the
TraceChecker executable event spec (one violation case per invariant)."""
import random
import time

import pytest

from repro.core import couler
from repro.core.analysis import (CODES, Severity, TraceChecker,
                                 TraceViolation, WorkflowLintError, lint,
                                 lint_gate, nondeterminism_findings)
from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.engines.local import LocalEngine
from repro.core.gateway.events import EventType, WorkflowEvent
from repro.core.ir import Condition, Job, Resources, WorkflowIR


def _ok_fn(*args):
    return 0


def _noisy_fn():
    return random.random()


def _seeded_fn():
    rng = random.Random(0)
    return rng.normalvariate(0, 1)


def _clocky_fn():
    return time.time()


# ---------------------------------------------------------------------------
# property: valid random DAGs produce zero errors
# ---------------------------------------------------------------------------

def _random_dag(rng: random.Random, i: int) -> WorkflowIR:
    wf = WorkflowIR(f"rand-{i}")
    n = rng.randint(1, 10)
    for j in range(n):
        wf.add_job(Job(name=f"s{j}", fn=_ok_fn, outputs=[f"s{j}:out"]))
    for j in range(1, n):
        for k in range(j):
            if rng.random() < 0.35:
                wf.add_edge(f"s{k}", f"s{j}")
                if rng.random() < 0.5:
                    wf.jobs[f"s{j}"].inputs.append(f"s{k}:out")
    return wf


def test_random_valid_dags_have_zero_errors():
    rng = random.Random(7)
    big = [Cluster("big", cpu=1024, mem_bytes=1 << 42, gpu=8)]
    for i in range(40):
        wf = _random_dag(rng, i)
        res = lint(wf, clusters=big, max_inflight_steps=64)
        assert not res.errors, (wf.name, [str(d) for d in res.errors])


def test_api_built_workflow_is_clean():
    with couler.workflow("clean") as ir:
        a = couler.run_step(_ok_fn, step_name="a")
        b = couler.run_step(_ok_fn, a, step_name="b")
        couler.when(couler.equal(b, 0),
                    lambda: couler.run_step(_ok_fn, step_name="c"))
    res = lint(ir)
    assert res.ok() and not res.diagnostics


def test_self_referential_loop_condition_is_legal():
    # exec_while conditioning on the body's own output (coinflip shape)
    with couler.workflow("loop") as ir:
        r = couler.run_step(_ok_fn, step_name="flip")
        couler.exec_while(couler.equal(r, "tails"), lambda: r)
    assert lint(ir).ok()


# ---------------------------------------------------------------------------
# mutation operators: each must be caught with the right code
# ---------------------------------------------------------------------------

def _chain(*names: str) -> WorkflowIR:
    wf = WorkflowIR("chain")
    for n in names:
        wf.add_job(Job(name=n, fn=_ok_fn, outputs=[f"{n}:out"]))
    for a, b in zip(names, names[1:]):
        wf.add_edge(a, b)
    return wf


def test_mutation_back_edge_is_clr001():
    wf = _chain("a", "b", "c")
    wf.add_edge("c", "a")
    res = lint(wf)
    assert "CLR001" in res.codes() and not res.ok()
    [d] = res.errors
    assert "->" in d.message            # offending path is named


def test_mutation_dropped_producer_is_clr003_and_clr008():
    wf = _chain("p", "c")
    wf.jobs["c"].inputs.append("p:out")
    wf.jobs["c"].condition = Condition("equal", "p:out", 1)
    assert lint(wf).ok()
    sub = wf.subgraph(["c"], name="mutant")   # producer dropped
    res = lint(sub)
    assert {"CLR003", "CLR008"} <= res.codes()
    assert all(d.severity is Severity.ERROR
               for d in res.diagnostics if d.code in ("CLR003", "CLR008"))


def test_mutation_unseeded_rng_is_clr007_warning():
    wf = _chain("a", "noisy")
    wf.jobs["noisy"].fn = _noisy_fn
    res = lint(wf)
    assert "CLR007" in res.codes()
    assert res.ok()                     # warning, not error
    [d] = res.warnings
    assert "random.random" in d.message
    # cacheable=False opts out: caching is the only hazard
    wf.jobs["noisy"].cacheable = False
    assert "CLR007" not in lint(wf).codes()


def test_mutation_over_requested_resources_is_clr005():
    wf = _chain("a", "big")
    wf.jobs["big"].resources = Resources(cpu=512, gpu=16)
    assert lint(wf).ok()                # no capacity context, no verdict
    res = lint(wf, clusters=[Cluster("small", cpu=64,
                                     mem_bytes=1 << 40, gpu=8)])
    assert "CLR005" in res.codes() and not res.ok()
    # a cluster that fits it silences the diagnostic
    res = lint(wf, clusters=[Cluster("huge", cpu=1024,
                                     mem_bytes=1 << 40, gpu=32)])
    assert res.ok()


def test_orphan_step_is_clr002_warning():
    wf = _chain("a", "b")
    wf.add_job(Job(name="island", fn=_ok_fn))
    res = lint(wf)
    assert "CLR002" in res.codes() and res.ok()


def test_nondeterminism_findings_direct():
    assert any("random.random" in f for f in nondeterminism_findings(_noisy_fn))
    assert any("time.time" in f for f in nondeterminism_findings(_clocky_fn))
    assert nondeterminism_findings(_seeded_fn) == ()
    assert nondeterminism_findings(len) == ()   # no source: conservative


# ---------------------------------------------------------------------------
# streaming shape diagnostics
# ---------------------------------------------------------------------------

def _fanin_workflow() -> WorkflowIR:
    with couler.workflow("fanin") as ir:
        s1 = couler.run_stream(lambda: iter(range(3)), step_name="p1",
                               cacheable=False)
        s2 = couler.run_stream(lambda: iter(range(3)), step_name="p2",
                               cacheable=False)
        couler.map_stream(lambda c, other: c + len(other), s1, s2,
                          step_name="join", cacheable=False)
    return ir


def test_chunkwise_fanin_is_clr004():
    res = lint(_fanin_workflow())
    assert "CLR004" in res.codes() and not res.ok()
    [d] = res.errors
    assert "p2:out" in d.message        # the materialized extra input


def test_fanin_rejected_at_submit_unless_opted_out():
    eng = LocalEngine(max_workers=4, enable_speculation=False,
                      promote_interval_s=0.0)
    try:
        with pytest.raises(WorkflowLintError) as ei:
            eng.submit(_fanin_workflow())
        assert "CLR004" in ei.value.result.codes()
        run = eng.submit(_fanin_workflow(), lint="off")
        assert run.status == "Succeeded", run.status
    finally:
        eng.close()


def test_streaming_depth_over_inflight_bound_is_clr006():
    with couler.workflow("deep") as ir:
        cur = couler.run_stream(lambda: iter(range(3)), step_name="p",
                                cacheable=False)
        for k in range(3):
            cur = couler.map_stream(lambda c: c, cur, step_name=f"m{k}",
                                    cacheable=False)
    assert lint(ir, max_inflight_steps=8).ok()
    res = lint(ir, max_inflight_steps=2)
    assert "CLR006" in res.codes() and not res.ok()
    eng = LocalEngine(max_workers=4, max_inflight_steps=2,
                      enable_speculation=False, promote_interval_s=0.0)
    try:
        with pytest.raises(WorkflowLintError) as ei:
            eng.submit(ir)
        assert "CLR006" in ei.value.result.codes()
    finally:
        eng.close()


def test_map_stream_over_materialized_source_is_clr009_info():
    wf = WorkflowIR("mat")
    wf.add_job(Job(name="p", fn=_ok_fn, outputs=["p:out"]))
    wf.add_job(Job(name="m", fn=_ok_fn, inputs=["p:out"], stream_input=True,
                   stream_arg="p:out"))
    wf.add_edge("p", "m")
    res = lint(wf)
    assert "CLR009" in res.codes() and res.ok()


# ---------------------------------------------------------------------------
# eager condition validation at construction time (satellite b)
# ---------------------------------------------------------------------------

def test_when_with_missing_producer_raises_eagerly():
    with couler.workflow("eager"):
        ghost = couler.StepOutput("ghost", "ghost:out")
        with pytest.raises(ValueError, match="CLR003"):
            couler.when(couler.equal(ghost, True),
                        lambda: couler.run_step(_ok_fn, step_name="then"))


def test_when_on_none_raises_eagerly():
    # the NL2WF failure shape: conditioning on a plain value (e.g. an
    # unassigned template variable) instead of a StepOutput
    with couler.workflow("eager-none"):
        with pytest.raises(ValueError, match="CLR003"):
            couler.when(couler.equal(None, True),
                        lambda: couler.run_step(_ok_fn, step_name="deploy"))


def test_exec_while_with_missing_producer_raises_eagerly():
    with couler.workflow("eager-loop"):
        ghost = couler.StepOutput("ghost", "ghost:out")
        with pytest.raises(ValueError, match="CLR003"):
            couler.exec_while(couler.equal(ghost, 1),
                              lambda: couler.run_step(_ok_fn,
                                                      step_name="body"))


def test_add_job_validates_condition_producer():
    wf = WorkflowIR("direct")
    bad = Job(name="c", fn=_ok_fn,
              condition=Condition("equal", "missing:out", 1))
    with pytest.raises(ValueError, match="CLR003"):
        wf.add_job(bad)


# ---------------------------------------------------------------------------
# lint gate modes + engine wiring
# ---------------------------------------------------------------------------

def test_lint_gate_modes():
    cyc = _chain("a", "b")
    cyc.add_edge("b", "a")
    with pytest.raises(WorkflowLintError) as ei:
        lint_gate(cyc)
    assert ei.value.result.errors and "lint=" in str(ei.value)
    assert lint_gate(cyc, mode="warn") is not None    # no raise
    assert lint_gate(cyc, mode="off") is None
    with pytest.raises(ValueError):
        lint_gate(cyc, mode="loud")


def test_lint_gate_records_warnings_in_workflow_configs():
    wf = _chain("a", "noisy")
    wf.jobs["noisy"].fn = _noisy_fn
    res = lint_gate(wf)                 # warnings never raise
    assert res is not None and res.ok()
    recorded = wf.configs["lint_warnings"]
    assert any(d["code"] == "CLR007" for d in recorded)


def test_engine_submit_records_warnings():
    wf = _chain("a", "noisy")
    wf.jobs["noisy"].fn = _noisy_fn
    eng = LocalEngine(max_workers=2, enable_speculation=False,
                      promote_interval_s=0.0)
    try:
        run = eng.submit(wf)
        assert run.status == "Succeeded"
        assert any(d["code"] == "CLR007"
                   for d in wf.configs["lint_warnings"])
    finally:
        eng.close()


def test_cluster_engine_rejects_unschedulable_workflow():
    wf = _chain("a", "big")
    wf.jobs["big"].resources = Resources(cpu=1 << 20)
    eng = MultiClusterEngine()
    with pytest.raises(WorkflowLintError) as ei:
        eng.submit_many([(wf, "alice", 1)])
    assert "CLR005" in ei.value.result.codes()


def test_couler_lint_api():
    with couler.workflow("api") as ir:
        couler.run_step(_ok_fn, step_name="only")
        res = couler.lint()
    assert res.ok() and res.workflow == "api"
    assert couler.lint(ir).ok()


def test_repo_corpus_has_no_lint_errors():
    """Zero false positives across the whole workflow corpus (example
    DAG shapes, benchmark workloads, SQLFlow translations, NL2WF
    generations) — the same gate scripts/lint_workflows.py runs in CI."""
    import importlib.util
    from pathlib import Path
    path = (Path(__file__).resolve().parent.parent / "scripts"
            / "lint_workflows.py")
    spec = importlib.util.spec_from_file_location("lint_workflows", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    n_wf, n_err, _n_warn = mod.run_gate(verbose=False)
    assert n_wf >= 10 and n_err == 0, (n_wf, n_err)


def test_codes_table_is_consistent():
    assert set(CODES) == {f"CLR00{i}" for i in range(1, 10)}
    for code, (sev, _meaning) in CODES.items():
        assert isinstance(sev, Severity)


# ---------------------------------------------------------------------------
# TraceChecker: the executable event spec, one violation per invariant
# ---------------------------------------------------------------------------

def _ev(t: EventType, step: str = "", status: str = "", chunk: int = -1,
        seq: int = -1) -> WorkflowEvent:
    return WorkflowEvent(type=t, workflow="w", run_id="r", step=step,
                         status=status, chunk=chunk, seq=seq)


_ADM = _ev(EventType.WORKFLOW_ADMITTED)
_DONE_OK = _ev(EventType.WORKFLOW_DONE, status="Succeeded")


def _stream_wf() -> WorkflowIR:
    wf = WorkflowIR("sw")
    wf.add_job(Job(name="p", fn=_ok_fn, outputs=["p:out"],
                   stream_output=True, cacheable=False))
    wf.add_job(Job(name="m", fn=_ok_fn, inputs=["p:out"], stream_input=True,
                   stream_arg="p:out", cacheable=False))
    wf.add_edge("p", "m")
    return wf


def test_trace_valid_stream_passes():
    evs = [_ADM,
           _ev(EventType.STEP_STARTED, "p"),
           _ev(EventType.STEP_STREAMING, "p"),
           _ev(EventType.STEP_CHUNK, "p", chunk=0),
           _ev(EventType.STEP_STARTED, "m"),
           _ev(EventType.STEP_CHUNK, "p", chunk=1),
           _ev(EventType.STEP_SUCCEEDED, "p"),
           _ev(EventType.STEP_SUCCEEDED, "m"),
           _DONE_OK]
    chk = TraceChecker.check(evs, wf=_stream_wf())
    assert chk.chunks["p"] == 1 and chk.n_events == len(evs)


def _expect(evs, invariant, wf=None):
    with pytest.raises(TraceViolation) as ei:
        TraceChecker.check(evs, wf=wf)
    assert ei.value.invariant == invariant, str(ei.value)


def test_trace_inv1_admitted_first():
    _expect([_ev(EventType.STEP_STARTED, "a"), _ADM, _DONE_OK], 1)


def test_trace_inv2_nothing_after_terminal():
    _expect([_ADM, _DONE_OK, _ev(EventType.STEP_STARTED, "a")], 2)


def test_trace_inv2_bad_terminal_status():
    _expect([_ADM, _ev(EventType.WORKFLOW_DONE, status="Exploded")], 2)


def test_trace_inv2_missing_terminal():
    _expect([_ADM, _ev(EventType.STEP_STARTED, "a"),
             _ev(EventType.STEP_SUCCEEDED, "a")], 2)


def test_trace_inv3_succeeded_run_must_complete_steps():
    evs = [_ADM, _ev(EventType.STEP_STARTED, "a"), _DONE_OK]
    _expect(evs, 3)
    # cancel scoping: a Cancelled run may leave started steps dangling
    evs = [_ADM, _ev(EventType.STEP_STARTED, "a"),
           _ev(EventType.WORKFLOW_DONE, status="Cancelled")]
    TraceChecker.check(evs)


def test_trace_inv3_terminal_before_start_and_duplicates():
    _expect([_ADM, _ev(EventType.STEP_SUCCEEDED, "a"), _DONE_OK], 3)
    _expect([_ADM, _ev(EventType.STEP_STARTED, "a"),
             _ev(EventType.STEP_STARTED, "a")], 3)


def test_trace_inv4_chunk_needs_streaming_announcement():
    _expect([_ADM, _ev(EventType.STEP_STARTED, "p"),
             _ev(EventType.STEP_CHUNK, "p", chunk=0)], 4)
    _expect([_ADM, _ev(EventType.STEP_STREAMING, "p")], 4)


def test_trace_inv5_chunk_indices_monotone_or_rewind():
    _expect([_ADM, _ev(EventType.STEP_STARTED, "p"),
             _ev(EventType.STEP_STREAMING, "p"),
             _ev(EventType.STEP_CHUNK, "p", chunk=0),
             _ev(EventType.STEP_CHUNK, "p", chunk=2)], 5)
    # a rewind restart at 0 is legal (retry re-announces first)
    evs = [_ADM, _ev(EventType.STEP_STARTED, "p"),
           _ev(EventType.STEP_STREAMING, "p"),
           _ev(EventType.STEP_CHUNK, "p", chunk=0),
           _ev(EventType.STEP_CHUNK, "p", chunk=1),
           _ev(EventType.STEP_STREAMING, "p"),
           _ev(EventType.STEP_CHUNK, "p", chunk=0),
           _ev(EventType.STEP_CHUNK, "p", chunk=1),
           _ev(EventType.STEP_CHUNK, "p", chunk=2),
           _ev(EventType.STEP_SUCCEEDED, "p"),
           _ev(EventType.WORKFLOW_DONE, status="Succeeded")]
    assert TraceChecker.check(evs).chunks["p"] == 2


def test_trace_inv6_consumer_waits_for_streaming():
    evs = [_ADM, _ev(EventType.STEP_STARTED, "p"),
           _ev(EventType.STEP_STARTED, "m")]
    _expect(evs, 6, wf=_stream_wf())
    # without topology the checker cannot (and must not) guess
    TraceChecker.check(evs + [_ev(EventType.WORKFLOW_DONE,
                                  status="Cancelled")])


def test_trace_seq_contiguity():
    _expect([_ev(EventType.WORKFLOW_ADMITTED, seq=1)], 1)
    _expect([_ev(EventType.WORKFLOW_ADMITTED, seq=0),
             _ev(EventType.WORKFLOW_DONE, status="Succeeded", seq=2)], 2)
