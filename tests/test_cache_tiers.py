"""Tiered cache subsystem tests (repro.core.cache).

Covers the ISSUE-4 acceptance points: demotion cascade under MEM pressure,
Eq. 6-driven promotion after repeated gets, single-tier facade equivalence
with the pre-tier ``CacheStore`` on a seeded trace (reference
implementation vendored below, like test_scheduler_equivalence does for
the scheduler), cross-cluster ``SharedRemoteTier`` hit accounting,
locality-aware ``MultiClusterEngine`` placement, and the documented Eq. 4
literal-vs-deviation behaviors.
"""
import heapq
import random
import time

import pytest

from repro.core.cache import (CacheStore, CacheTier, CoulerPolicy,
                              FIFOPolicy, LRUPolicy, SharedRemoteTier,
                              TierSpec, TieredCacheStore, mem_spec,
                              remote_spec, reuse_value, ssd_spec)
from repro.core.cache.policies import CacheAll
from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.ir import Job, WorkflowIR


def fan_wf(fanout=4):
    wf = WorkflowIR("f")
    wf.add_job(Job(name="root", est_time_s=5))
    wf.add_job(Job(name="mid", est_time_s=3))
    wf.add_edge("root", "mid")
    for i in range(fanout):
        wf.add_job(Job(name=f"leaf{i}", est_time_s=1))
        wf.add_edge("mid", f"leaf{i}")
    return wf


def chain_wf(n=4):
    wf = WorkflowIR("c")
    prev = None
    for i in range(n):
        wf.add_job(Job(name=f"j{i}", est_time_s=1.0 + i))
        if prev:
            wf.add_edge(prev, f"j{i}")
        prev = f"j{i}"
    return wf


def three_tiers(mem=300, ssd=600, remote=900):
    return [CacheTier(TierSpec("MEM", mem, 8e9, 2e-6)),
            CacheTier(TierSpec("SSD", ssd, 1.2e9, 2.5e-4)),
            CacheTier(TierSpec("REMOTE", remote, 1.2e8, 2e-2))]


# ---------------------------------------------------------------------------
# demotion cascade
# ---------------------------------------------------------------------------

def test_demotion_cascade_under_mem_pressure():
    """MEM overflow demotes FIFO-oldest downward tier by tier; artifacts
    only fall off the cache entirely at the REMOTE tier."""
    store = TieredCacheStore(tiers=three_tiers(), policy=FIFOPolicy())
    for i in range(20):
        assert store.offer(f"a{i}", b"x" * 100, 1.0, producer=f"j{i}")
    # capacities 300/600/900 bytes -> 3 + 6 + 9 = 18 items survive
    assert len(store.items) == 18
    assert store.used_bytes == 1800
    # newest in MEM, oldest still cached in REMOTE
    assert set(store.tiers[0].items) == {"a17", "a18", "a19"}
    assert "a2" in store.tiers[2].items
    # only the 2 oldest fell off the cache, and only off REMOTE
    assert store.stats["evictions"] == 2
    assert not store.contains("a0") and not store.contains("a1")
    assert store.tiers[2].stats["evictions"] == 2
    assert store.tiers[0].stats["evictions"] == 0
    assert store.tiers[1].stats["evictions"] == 0
    # every MEM demotion arrived in SSD, every SSD demotion in REMOTE
    assert store.tiers[0].stats["demotions_out"] == \
        store.tiers[1].stats["demotions_in"]
    assert store.tiers[1].stats["demotions_out"] == \
        store.tiers[2].stats["demotions_in"]
    assert store.stats["demotions"] > 0
    store.check_invariants()


def test_artifact_too_big_for_mem_lands_lower():
    store = TieredCacheStore(tiers=three_tiers(), policy=FIFOPolicy())
    assert store.offer("big", b"x" * 500, 1.0, producer="p")
    assert "big" in store.tiers[1].items          # skipped 300-byte MEM
    assert store.offer("huge", b"x" * 700, 1.0, producer="p2")
    assert "huge" in store.tiers[2].items
    assert not store.offer("absurd", b"x" * 5000, 1.0, producer="p3")
    assert store.stats["rejected"] == 1
    store.check_invariants()


# ---------------------------------------------------------------------------
# Eq. 6 promotion
# ---------------------------------------------------------------------------

def test_eq6_promotion_after_repeated_gets():
    """Observed hits fold into Eq. 4's reuse events: an artifact demoted
    out of MEM climbs back after enough gets, displacing the incumbent."""
    wf = fan_wf(5)
    store = TieredCacheStore(tiers=three_tiers(mem=150, ssd=400, remote=900),
                             policy=CoulerPolicy())
    store.attach_workflow(wf)
    assert store.offer("leaf0:out", b"x" * 100, 0.5, producer="leaf0")
    assert "leaf0:out" in store.tiers[0].items
    # mid (5 successors -> high F) displaces leaf0 down to SSD
    assert store.offer("mid:out", b"y" * 100, 3.0, producer="mid")
    assert "mid:out" in store.tiers[0].items
    assert "leaf0:out" in store.tiers[1].items
    # leaf0 gets hot: each hit is one of Eq. 4's r reuse events
    for _ in range(15):
        assert store.get("leaf0:out") is not None
    moved = store.promote()
    assert moved["promoted"] >= 1
    assert "leaf0:out" in store.tiers[0].items    # climbed back to MEM
    assert "mid:out" in store.tiers[1].items      # incumbent sank
    store.check_invariants()


def test_promote_does_not_pin_orphaned_artifacts():
    """An artifact whose producer vanished from the attached workflow must
    not out-rank live Eq. 6 scores in the promotion re-pack (its eviction
    fallback is an epoch timestamp, which would pin it into MEM forever)."""
    wf = fan_wf(5)
    store = TieredCacheStore(tiers=three_tiers(mem=150, ssd=400, remote=900),
                             policy=CoulerPolicy())
    store.attach_workflow(wf)
    assert store.offer("ghost:out", b"x" * 100, 1.0, producer="ghost")
    assert "ghost:out" in store.tiers[0].items
    # the orphan's timestamp fallback wins the admission contest, so the
    # genuinely valuable artifact lands in SSD...
    assert store.offer("mid:out", b"y" * 100, 3.0, producer="mid")
    assert "mid:out" in store.tiers[1].items
    # ...but the promotion pass ranks orphans below everything
    store.promote()
    assert "mid:out" in store.tiers[0].items
    assert "ghost:out" in store.tiers[1].items
    store.check_invariants()


def test_promotion_noop_when_ranking_matches_layout():
    store = TieredCacheStore(tiers=three_tiers(), policy=FIFOPolicy())
    for i in range(3):
        store.offer(f"a{i}", b"x" * 100, 1.0, producer=f"j{i}")
    before = dict(store.tiers[0].items)
    moved = store.promote()
    assert moved == {"promoted": 0, "demoted": 0, "copied_up": 0}
    assert store.tiers[0].items == before


# ---------------------------------------------------------------------------
# single-tier facade == legacy CacheStore (reference vendored verbatim)
# ---------------------------------------------------------------------------

class LegacyCacheStore:
    """Pre-tier CacheStore (PR 3 state), vendored as the behavioral
    reference for the facade."""

    def __init__(self, capacity_bytes=1 << 30, policy=None):
        import threading
        from repro.core.cache.scoring import CachedArtifact  # noqa: F401
        self.capacity_bytes = capacity_bytes
        self.policy = policy or CoulerPolicy()
        self.items = {}
        self.used_bytes = 0
        self.workflow = None
        self._insertions = 0
        self._lock = threading.RLock()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "admitted": 0, "rejected": 0, "refreshed": 0,
                      "score_time_s": 0.0}
        self._epoch = 0
        self._heap = []
        self._heap_epoch = -1
        self._wf_versions = None

    def attach_workflow(self, wf):
        if wf is not self.workflow:
            self.workflow = wf
            self.policy.invalidate(wf)
            self._epoch += 1

    def get(self, name):
        art = self.items.get(name)
        if art is None:
            self.stats["misses"] += 1
            return None
        art.last_used = time.time()
        art.uses += 1
        self.stats["hits"] += 1
        self._epoch += 1
        return art

    def offer(self, name, value, compute_time_s, producer, nbytes=None):
        from repro.core.cache.scoring import CachedArtifact, sizeof
        b = nbytes if nbytes is not None else sizeof(value)
        art = CachedArtifact(name=name, value=value, bytes=b,
                             compute_time_s=compute_time_s,
                             producer=producer, insertion=self._insertions)
        self._insertions += 1
        if not self.policy.admit(art):
            self.stats["rejected"] += 1
            return False
        if b > self.capacity_bytes:
            self.stats["rejected"] += 1
            return False
        if self.used_bytes + b <= self.capacity_bytes:
            self._insert(art)
            return True
        self._sync_workflow_versions()
        new_score = self.policy.score(art, self)
        while self.used_bytes + b > self.capacity_bytes:
            if not self.items:
                break
            k_min, s_min = self._min_scored()
            if s_min >= new_score:
                self.stats["rejected"] += 1
                return False
            self._evict(k_min)
        self._insert(art)
        return True

    def _sync_workflow_versions(self):
        wf = self.workflow
        v = (None if wf is None
             else (wf.structure_version, wf.weights_version))
        if v != self._wf_versions:
            self._wf_versions = v
            self._epoch += 1

    def _min_scored(self):
        if self._heap_epoch != self._epoch:
            arts = list(self.items.values())
            scores = self.policy.score_many(arts, self)
            self._heap = [(s, a.insertion, a.name)
                          for s, a in zip(scores, arts)]
            heapq.heapify(self._heap)
            self._heap_epoch = self._epoch
        s, _, name = self._heap[0]
        return name, s

    def _insert(self, art):
        old = self.items.pop(art.name, None)
        if old is not None:
            self.used_bytes -= old.bytes
            self.stats["refreshed"] += 1
        else:
            self.stats["admitted"] += 1
        self.items[art.name] = art
        self.used_bytes += art.bytes
        self._epoch += 1

    def _evict(self, name):
        art = self.items.pop(name)
        self.used_bytes -= art.bytes
        self.stats["evictions"] += 1
        self._epoch += 1


LEGACY_KEYS = ("hits", "misses", "evictions", "admitted", "rejected",
               "refreshed")


@pytest.mark.parametrize("policy_cls", [FIFOPolicy, LRUPolicy, CacheAll,
                                        CoulerPolicy])
def test_single_tier_facade_matches_legacy(policy_cls):
    """Same seeded offer/get trace -> identical admission/eviction
    decisions, stats, contents and byte usage as the pre-tier store."""
    rng = random.Random(7)
    ops = []
    keys = [f"k{i}" for i in range(12)]
    producers = ["root", "mid"] + [f"leaf{i}" for i in range(4)] + ["ghost"]
    for _ in range(300):
        if rng.random() < 0.6:
            ops.append(("offer", rng.choice(keys),
                        rng.choice([40, 90, 150, 260]),
                        rng.choice(producers)))
        else:
            ops.append(("get", rng.choice(keys)))

    def drive(store):
        store.attach_workflow(fan_wf(4))
        decisions = []
        for op in ops:
            if op[0] == "offer":
                _, k, b, p = op
                decisions.append(store.offer(k, None, 1.0, producer=p,
                                             nbytes=b))
            else:
                decisions.append(store.get(op[1]) is not None)
        return decisions

    new = CacheStore(capacity_bytes=500, policy=policy_cls())
    old = LegacyCacheStore(capacity_bytes=500, policy=policy_cls())
    d_new = drive(new)
    d_old = drive(old)
    assert d_new == d_old
    assert {k: new.stats[k] for k in LEGACY_KEYS} == \
        {k: old.stats[k] for k in LEGACY_KEYS}
    assert sorted(new.items) == sorted(old.items)
    assert new.used_bytes == old.used_bytes
    new.check_invariants()


# ---------------------------------------------------------------------------
# cross-cluster shared REMOTE tier
# ---------------------------------------------------------------------------

def test_shared_remote_cross_cluster_hit_accounting():
    shared = SharedRemoteTier(remote_spec(1000))
    a = TieredCacheStore(
        tiers=[CacheTier(mem_spec(200)), shared],
        policy=FIFOPolicy(), name="cluster-a")
    b = TieredCacheStore(
        tiers=[CacheTier(mem_spec(200)), shared],
        policy=FIFOPolicy(), name="cluster-b")
    for i in range(3):                       # x0 demotes into shared REMOTE
        assert a.offer(f"x{i}", None, 1.0, producer=f"p{i}", nbytes=100)
    assert "x0" in shared.items
    # a cluster that never fetched x0 through the shared tier must not
    # replicate it into its private tiers (copy-up is gated on LOCAL use,
    # not the cross-cluster art.uses counter)
    assert b.promote()["copied_up"] == 0
    # cluster-b sees cluster-a's demoted artifact through the shared tier
    hit = b.get("x0")
    assert hit is not None
    assert b.stats["hits"] == 1 and b.stats["misses"] == 0
    assert a.get("x0") is not None
    assert shared.hits_by_client == {"cluster-b": 1, "cluster-a": 1}
    # promotion COPIES out of the shared tier: b gets a private replica,
    # the remote copy survives for other clusters
    moved = b.promote()
    assert moved["copied_up"] == 1
    assert "x0" in b.tiers[0].items and "x0" in shared.items
    a.check_invariants()
    b.check_invariants()


def test_cluster_engine_placement_follows_artifact_locality():
    """With per-cluster stores attached, a consumer lands on the cluster
    already holding its input artifact (fetch beats cross-cluster pull)."""
    def mk_store(name):
        return TieredCacheStore(tiers=[CacheTier(mem_spec(8 << 20))],
                                policy=LRUPolicy(), name=name)
    caches = {"ca": mk_store("ca"), "cb": mk_store("cb")}
    eng = MultiClusterEngine(
        clusters=[Cluster("ca", cpu=64, mem_bytes=1 << 40),
                  Cluster("cb", cpu=64, mem_bytes=1 << 40)],
        caches=caches)
    wf = WorkflowIR("loc")
    wf.add_job(Job(name="a", est_time_s=5.0))
    wf.add_job(Job(name="b", est_time_s=1.0))
    wf.add_edge("a", "b")
    run = eng.submit(wf)
    assert run.succeeded()
    # a ran on ca (first fitting, both idle) and left its artifact there;
    # b must follow it: one hit on ca's store, none on cb's
    assert caches["ca"].stats["hits"] == 1
    assert caches["cb"].stats["hits"] == 0
    assert eng.metrics["fetch_wait_s"] > 0.0
    # makespan = a + b + the MEM fetch of a's 1 MiB artifact (~0.13 ms),
    # far below the 28 ms cross-cluster pull it avoided
    assert 6.0 < eng.metrics["makespan_s"] < 6.01


# ---------------------------------------------------------------------------
# Eq. 4 literal vs documented deviation
# ---------------------------------------------------------------------------

def test_reuse_value_literal_vs_deviation():
    """Pins both behaviors of the documented Eq. 4 choice: the literal
    equation zeroes DIRECT successors (zeta_ui = -A_ui), the default
    |zeta| deviation makes them count most."""
    fan = fan_wf(4)
    assert reuse_value(fan, "mid") == pytest.approx(8.0)          # 4*(1+1)
    assert reuse_value(fan, "mid", literal_eq4=True) == pytest.approx(0.0)
    chain = chain_wf(4)
    # from j0: j1 at kappa=1 (zeta=-1), j2 at 2, j3 at 3 (zeta=0)
    assert reuse_value(chain, "j0") == pytest.approx(2 + 1 / 2 + 1 / 3)
    assert reuse_value(chain, "j0", literal_eq4=True) == \
        pytest.approx(0 + 1 / 2 + 1 / 3)
    # flag flows through the policy: literal scores mid's artifact lower
    lit = CoulerPolicy(literal_eq4=True)
    dev = CoulerPolicy()
    store = CacheStore(capacity_bytes=1000, policy=dev)
    store.attach_workflow(fan)
    store.offer("mid:out", None, 3.0, producer="mid", nbytes=10)
    art = store.items["mid:out"]
    assert lit.score(art, store) < dev.score(art, store)


# ---------------------------------------------------------------------------
# fuzz: ledger invariants under arbitrary traffic
# ---------------------------------------------------------------------------

def test_shared_tier_concurrent_stores_keep_invariants():
    """Two stores hammer one SharedRemoteTier from separate threads; the
    atomic put_if_fits path must keep the shared tier within capacity and
    the byte ledgers balanced."""
    import threading
    shared = SharedRemoteTier(remote_spec(1500))
    stores = [TieredCacheStore(tiers=[CacheTier(mem_spec(300)), shared],
                               policy=FIFOPolicy(), name=f"s{i}")
              for i in range(2)]
    errors = []

    def work(store, seed):
        rng = random.Random(seed)
        try:
            for _ in range(400):
                r = rng.random()
                if r < 0.6:
                    store.offer(f"k{rng.randrange(12)}", None, 1.0,
                                producer="p",
                                nbytes=rng.choice([60, 120, 280]))
                elif r < 0.9:
                    store.get(f"k{rng.randrange(12)}")
                else:
                    store.promote()
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=work, args=(s, i))
               for i, s in enumerate(stores)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    shared.check_ledger()                    # capacity + ledger balanced
    for s in stores:
        s.check_invariants()


@pytest.mark.parametrize("policy_cls,seed", [(FIFOPolicy, 0), (LRUPolicy, 1),
                                             (CoulerPolicy, 2)])
def test_invariants_under_random_traffic(policy_cls, seed):
    rng = random.Random(seed)
    shared = SharedRemoteTier(remote_spec(2000))
    store = TieredCacheStore(
        tiers=[CacheTier(mem_spec(400)), CacheTier(ssd_spec(800)), shared],
        policy=policy_cls(), name="fuzz", auto_promote_every=7)
    store.attach_workflow(fan_wf(4))
    keys = [f"k{i}" for i in range(20)]
    producers = ["root", "mid", "leaf0", "leaf1", "other"]
    for i in range(500):
        r = rng.random()
        if r < 0.55:
            store.offer(rng.choice(keys), None, rng.uniform(0.1, 3.0),
                        producer=rng.choice(producers),
                        nbytes=rng.choice([30, 80, 140, 390, 900]))
        elif r < 0.9:
            store.get(rng.choice(keys))
        else:
            store.promote()
        if i % 50 == 0:
            store.check_invariants()
    store.check_invariants()
    assert store.used_bytes <= 400 + 800 + 2000
