"""Chaos-hardened workflows (repro.core.faults).

Pins the fault-tolerance contract: deterministic seeded fault injection
(identical replay), capped+jittered retry backoff with STEP_RETRY /
WORKER_LOST events, frontier checkpoint-resume on a *fresh* engine,
checkpoint-wired steps resuming mid-step after a worker-loss kill,
simulated cluster preemption with job re-placement, straggler-aware
re-admission (backoff + priority aging), and the TraceChecker invariants
(7, 8) that make all of it auditable.
"""
import tempfile

import pytest

from repro.core import couler
from repro.core.analysis import TraceChecker, TraceViolation
from repro.core.caching import CacheStore
from repro.core.engines.base import StepStatus, TransientError
from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.engines.local import LocalEngine
from repro.core.faults import (ChaosInjector, FaultPlan, ReadmissionPolicy,
                               RetryPolicy, capped_jittered_delay)
from repro.core.gateway import AdmissionQueue, AdmittedItem, EventType
from repro.core.gateway.events import WorkflowEvent
from repro.core.gateway.run import AsyncWorkflowRun
from repro.core.ir import Job, Resources, WorkflowIR


def build_chain(name="flt"):
    with couler.workflow(name) as ir:
        a = couler.run_step(lambda: 2, step_name="a")
        b = couler.run_step(lambda x: x * 3, a, step_name="b")
        couler.run_step(lambda x: x + 1, b, step_name="c")
    return ir


def _engine(**kw):
    kw.setdefault("cache", CacheStore())
    kw.setdefault("enable_speculation", False)
    kw.setdefault("check_events", True)         # inline sanitizer
    kw.setdefault("retry_backoff_s", 0.001)
    kw.setdefault("retry_backoff_max_s", 0.01)
    return LocalEngine(**kw)


# ---------------------------------------------------------------------------
# FaultPlan / ChaosInjector determinism
# ---------------------------------------------------------------------------

def test_fault_plan_rejects_oversubscribed_rates():
    with pytest.raises(ValueError, match="sum"):
        FaultPlan(crash_rate=0.6, permanent_rate=0.3, worker_loss_rate=0.3)


def _fault_sequence(plan, n=40):
    inj = ChaosInjector(plan)
    seq = []
    for i in range(n):
        f, at = inj.begin_attempt("wf", f"s{i % 5}")
        seq.append((type(f).__name__ if f else None, at))
    return seq, inj


def test_injector_replay_is_deterministic():
    plan = FaultPlan(seed=11, crash_rate=0.3, worker_loss_rate=0.2,
                     max_failures_per_site=100)
    s1, i1 = _fault_sequence(plan)
    s2, i2 = _fault_sequence(plan)
    assert s1 == s2
    assert i1.stats == i2.stats
    assert i1.stats["crash"] > 0 and i1.stats["worker_lost"] > 0
    s3, _ = _fault_sequence(FaultPlan(seed=12, crash_rate=0.3,
                                      worker_loss_rate=0.2,
                                      max_failures_per_site=100))
    assert s3 != s1                              # seed actually matters


def test_injector_cap_and_targets():
    plan = FaultPlan(seed=0, crash_rate=1.0, max_failures_per_site=2,
                     targets=frozenset(["hit", "wf/qualified"]))
    inj = ChaosInjector(plan)
    hits = [inj.begin_attempt("wf", "hit")[0] for _ in range(5)]
    assert sum(f is not None for f in hits) == 2     # hard cap converges
    assert all(inj.begin_attempt("wf", "miss")[0] is None for _ in range(5))
    # qualified "workflow/step" targets match too
    assert inj.begin_attempt("wf", "qualified")[0] is not None
    assert inj.injected_at("wf", "hit") == 2


def test_end_to_end_injection_replays_identically():
    plan = FaultPlan(seed=3, crash_rate=0.5, max_failures_per_site=2)
    attempts = []
    for _ in range(2):
        run = _engine(fault_plan=plan).submit(build_chain())
        assert run.succeeded()
        attempts.append({k: r.attempts for k, r in run.steps.items()})
    assert attempts[0] == attempts[1]
    assert sum(attempts[0].values()) > 3             # something was injected


# ---------------------------------------------------------------------------
# retry backoff: cap + jitter, STEP_RETRY / WORKER_LOST events
# ---------------------------------------------------------------------------

def test_backoff_is_capped_and_jittered():
    pol = RetryPolicy(base_s=0.1, cap_s=1.5, jitter=True)
    delays = [pol.delay_s(a) for a in range(1, 12)]
    assert all(0 < d <= 1.5 for d in delays)         # never exceeds the cap
    # no jitter -> pure capped exponential, deterministic
    flat = RetryPolicy(base_s=0.1, cap_s=1.5, jitter=False)
    assert [flat.delay_s(a) for a in (1, 2, 3, 6, 10)] == \
           [0.1, 0.2, 0.4, 1.5, 1.5]
    assert capped_jittered_delay(50, 0.1, 2.0, jitter=False) == 2.0


def test_step_retry_events_on_every_retry():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientError(f"flake {calls['n']}")
        return x + 1

    wf = WorkflowIR("retry-ev")
    wf.add_job(Job(name="s", fn=flaky, args=(1,), cacheable=False,
                   outputs=["s:out"], retry_limit=3))
    eng = _engine()
    handle = eng.gateway.submit_nowait(wf, block=True)
    run = handle.result()
    assert run.succeeded() and run.steps["s"].attempts == 3
    retries = [e for e in handle.events_so_far()
               if e.type is EventType.STEP_RETRY]
    assert [e.attempt for e in retries] == [2, 3]    # one per retry, ascending
    TraceChecker.check(handle.events_so_far(), wf=wf)


def test_worker_loss_emits_event_and_recovers():
    plan = FaultPlan(seed=2, worker_loss_rate=1.0, max_failures_per_site=1)
    eng = _engine(fault_plan=plan)
    wf = build_chain("wl")
    handle = eng.gateway.submit_nowait(wf, block=True)
    run = handle.result()
    assert run.succeeded()
    evs = handle.events_so_far()
    lost = [e for e in evs if e.type is EventType.WORKER_LOST]
    assert lost and all(e.attempt >= 1 for e in lost)
    # every loss is absorbed: a STEP_RETRY for the same step follows
    types = [(e.type, e.step) for e in evs]
    for e in lost:
        assert types.index((EventType.STEP_RETRY, e.step)) > \
               types.index((EventType.WORKER_LOST, e.step))
    TraceChecker.check(evs, wf=wf)


def test_permanent_crash_is_not_absorbed():
    plan = FaultPlan(seed=0, permanent_rate=1.0, max_failures_per_site=1)
    run = _engine(fault_plan=plan).submit(build_chain("perm"))
    assert run.status == "Failed"
    failed = [r for r in run.steps.values() if r.status == StepStatus.FAILED]
    assert len(failed) == 1 and failed[0].attempts == 1   # no retry burned
    assert "injected permanent crash" in failed[0].error


# ---------------------------------------------------------------------------
# frontier checkpoint-resume
# ---------------------------------------------------------------------------

def test_frontier_restore_on_fresh_engine():
    cache = CacheStore()
    plan = FaultPlan(seed=0, permanent_rate=1.0, max_failures_per_site=1,
                     targets=frozenset(["fr/c"]))
    eng_a = _engine(cache=cache, fault_plan=plan, frontier=True)
    run_a = eng_a.submit(build_chain("fr"))
    assert run_a.status == "Failed"
    assert run_a.steps["a"].status == StepStatus.SUCCEEDED
    assert run_a.steps["c"].status == StepStatus.FAILED

    # a brand-new engine/gateway (fresh process stand-in) sharing only the
    # cache reconstructs the completion frontier and finishes the run
    eng_b = _engine(cache=cache, frontier=True)
    run_b = eng_b.resume_from_frontier(build_chain("fr"))
    assert run_b.succeeded()
    assert run_b.steps["a"].status == StepStatus.CACHED
    assert run_b.steps["b"].status == StepStatus.CACHED
    assert run_b.steps["c"].status == StepStatus.SUCCEEDED
    assert run_b.artifacts["c:out"] == 7


def test_frontier_resume_without_prior_state_runs_everything():
    eng = _engine(frontier=True)
    run = eng.resume_from_frontier(build_chain("cold"))
    assert run.succeeded()
    assert all(r.status == StepStatus.SUCCEEDED for r in run.steps.values())


def test_checkpoint_wired_step_resumes_mid_step():
    iters = 6
    work_log = []

    def train(n, ckpt=None):
        start, total = 0, 0
        if ckpt.latest_step() is not None:
            state = ckpt.restore()
            start, total = int(state["i"]) + 1, int(state["acc"])
        for i in range(start, n):
            ckpt.tick(i)                      # interruption point
            work_log.append(i)
            total += i
            ckpt.save(i, {"i": i, "acc": total})
        return total

    with tempfile.TemporaryDirectory() as td:
        with couler.workflow("ck") as ir:
            couler.add_job(train, iters, checkpoint=td + "/ck",
                           step_name="train", retry_limit=8)
        plan = FaultPlan(seed=5, worker_loss_rate=1.0,
                         max_failures_per_site=2, mid_step_kill_window=4,
                         targets=frozenset(["ck/train"]))
        eng = _engine(fault_plan=plan)
        run = eng.submit(ir)
    assert run.succeeded()
    assert eng.injector.stats["mid_step_kill"] == 2
    assert run.artifacts["train:out"] == sum(range(iters))
    assert run.steps["train"].attempts == 3
    # the kills did NOT restart from scratch: total iteration executions
    # stay below attempts * iters (progress survived via the checkpoint)
    assert len(work_log) < run.steps["train"].attempts * iters


# ---------------------------------------------------------------------------
# simulated cluster preemption (MultiClusterEngine)
# ---------------------------------------------------------------------------

def _cluster_wf(i):
    wf = WorkflowIR(f"wf{i}")
    wf.add_job(Job(name="a", est_time_s=1.0, resources=Resources(cpu=4)))
    wf.add_job(Job(name="b", est_time_s=2.0, resources=Resources(cpu=4)))
    wf.add_edge("a", "b")
    return wf


def test_preempted_cluster_jobs_are_replaced():
    plan = FaultPlan(seed=7, preemption_rate_per_s=0.4,
                     preemption_dark_s=3.0)
    q = AdmissionQueue()
    handles = {}
    for i in range(6):
        wf = _cluster_wf(i)
        h = AsyncWorkflowRun(wf.name)
        handles[wf.name] = h
        q.offer(AdmittedItem(wf=wf, tenant="u0", handle=h))
    eng = MultiClusterEngine(clusters=[
        Cluster("a", cpu=8, mem_bytes=1 << 40),
        Cluster("b", cpu=8, mem_bytes=1 << 40)], fault_plan=plan)
    runs = eng.submit_admitted(q)
    assert all(r.succeeded() for r in runs.values())
    assert eng.metrics["preemptions"] > 0
    assert eng.metrics["preempted_jobs"] > 0
    preempted = [e for h in handles.values() for e in h.events_so_far()
                 if e.type is EventType.CLUSTER_PREEMPTED]
    assert len(preempted) == eng.metrics["preempted_jobs"]
    assert all(e.step and e.attempt >= 1 for e in preempted)
    for h in handles.values():                  # streams stay invariant-clean
        TraceChecker.check(h.events_so_far())
    # an evicted job's attempts are bumped in its run record
    bumped = [r for r in runs.values()
              if any(rec.attempts > 0 for rec in r.steps.values())]
    assert bumped


def test_cluster_scheduling_unchanged_without_plan():
    def batch():
        return [(_cluster_wf(i), "u0", 0) for i in range(4)]
    e1 = MultiClusterEngine(clusters=[Cluster("a", cpu=8,
                                              mem_bytes=1 << 40)])
    e2 = MultiClusterEngine(clusters=[Cluster("a", cpu=8,
                                              mem_bytes=1 << 40)],
                            fault_plan=None)
    r1, r2 = e1.submit_many(batch()), e2.submit_many(batch())
    assert e1.metrics["makespan_s"] == e2.metrics["makespan_s"]
    assert {k: r.wall_time_s for k, r in r1.items()} == \
           {k: r.wall_time_s for k, r in r2.items()}


# ---------------------------------------------------------------------------
# straggler-aware re-admission
# ---------------------------------------------------------------------------

def test_readmission_policy_units():
    pol = ReadmissionPolicy(base_backoff_s=0.1, max_backoff_s=1.0,
                            max_readmissions=3, aging_priority_step=2,
                            jitter=False)
    assert [pol.delay_s(n) for n in (1, 2, 3, 8)] == [0.1, 0.2, 0.4, 1.0]
    assert pol.should_readmit(0) and pol.should_readmit(2)
    assert not pol.should_readmit(3)
    assert pol.aged_priority(5) == 7
    jit = ReadmissionPolicy(base_backoff_s=0.1, max_backoff_s=1.0)
    assert all(0 < jit.delay_s(n) <= 1.0 for n in range(1, 20))


def test_failed_workflow_is_readmitted_and_recovers():
    # every attempt crashes until the per-site cap: the in-run retry
    # budget (retry_limit=3 -> 4 attempts) exhausts first, the workflow
    # fails, re-enters admission with backoff+aging, and succeeds once
    # the injector's cap converges
    plan = FaultPlan(seed=1, crash_rate=1.0, max_failures_per_site=5)
    eng = _engine(fault_plan=plan,
                  readmission=ReadmissionPolicy(base_backoff_s=0.005,
                                                max_backoff_s=0.05))
    wf = build_chain("readmit")
    handle = eng.gateway.submit_nowait(wf, block=True)
    run = handle.result()
    assert run.succeeded()
    assert eng.gateway.stats["readmitted"] >= 1
    evs = handle.events_so_far()
    requeues = [e for e in evs if e.type is EventType.WORKFLOW_REQUEUED]
    assert requeues
    assert [e.attempt for e in requeues] == \
           list(range(1, len(requeues) + 1))        # admission rounds count up
    assert all("steps failed" in e.error for e in requeues)
    # a STEP_FAILED precedes the first requeue; the terminal is Succeeded
    types = [e.type for e in evs]
    assert types.index(EventType.STEP_FAILED) < \
           types.index(EventType.WORKFLOW_REQUEUED)
    assert evs[-1].type is EventType.WORKFLOW_DONE
    assert evs[-1].status == "Succeeded"
    TraceChecker.check(evs, wf=wf)


def test_readmission_gives_up_after_cap():
    plan = FaultPlan(seed=1, permanent_rate=1.0, max_failures_per_site=100)
    eng = _engine(fault_plan=plan,
                  readmission=ReadmissionPolicy(base_backoff_s=0.001,
                                                max_backoff_s=0.01,
                                                max_readmissions=2))
    run = eng.submit(build_chain("doomed"))
    assert run.status == "Failed"
    assert eng.gateway.stats["readmitted"] == 2


def test_repeated_straggler_speculation_prioritized():
    # a site that straggled before gets its speculation budget shrunk, so
    # the backup copy launches sooner on later runs
    eng = LocalEngine(cache=CacheStore(), enable_speculation=True,
                      straggler_factor=2.0)
    eng._straggler_counts["wf/slow"] = 3
    job = Job(name="slow", fn=lambda: 1, est_time_s=1.0)
    budget_fresh = max(0.05, eng.straggler_factor * job.est_time_s / 1)
    budget_repeat = max(0.05, eng.straggler_factor * job.est_time_s
                        / (1 + eng._straggler_counts["wf/slow"]))
    assert budget_repeat < budget_fresh


# ---------------------------------------------------------------------------
# TraceChecker invariants 7 & 8
# ---------------------------------------------------------------------------

def _ev(type_, step="", status="", attempt=0, seq=0):
    return WorkflowEvent(type=type_, workflow="w", run_id="r", tenant="t",
                         step=step, status=status, attempt=attempt, seq=seq)


def _stream(*specs):
    return [_ev(*spec, seq=i) for i, spec in enumerate(specs)]


def test_trace_checker_catches_retry_violations():
    # retry before its STEP_STARTED
    bad = _stream((EventType.WORKFLOW_ADMITTED,),
                  (EventType.STEP_RETRY, "s", "", 2))
    with pytest.raises(TraceViolation, match="invariant 7"):
        TraceChecker.check(bad)
    # non-increasing attempt numbers
    bad = _stream((EventType.WORKFLOW_ADMITTED,),
                  (EventType.STEP_STARTED, "s"),
                  (EventType.STEP_RETRY, "s", "", 2),
                  (EventType.STEP_RETRY, "s", "", 2))
    with pytest.raises(TraceViolation, match="invariant 7"):
        TraceChecker.check(bad)
    # WORKER_LOST after the step's terminal event
    bad = _stream((EventType.WORKFLOW_ADMITTED,),
                  (EventType.STEP_STARTED, "s"),
                  (EventType.STEP_SUCCEEDED, "s"),
                  (EventType.WORKER_LOST, "s", "", 1))
    with pytest.raises(TraceViolation, match="invariant 7"):
        TraceChecker.check(bad)


def test_trace_checker_requeue_epoch():
    # a requeued run may legally re-announce STEP_STARTED...
    ok = _stream((EventType.WORKFLOW_ADMITTED,),
                 (EventType.STEP_STARTED, "s"),
                 (EventType.STEP_FAILED, "s"),
                 (EventType.WORKFLOW_REQUEUED, "", "", 1),
                 (EventType.STEP_STARTED, "s"),
                 (EventType.STEP_RETRY, "s", "", 2),
                 (EventType.STEP_SUCCEEDED, "s"),
                 (EventType.WORKFLOW_DONE, "", "Succeeded"))
    checker = TraceChecker.check(ok)
    assert checker.epoch == 1
    # ...but a REQUEUED before admission is invariant 8
    with pytest.raises(TraceViolation, match="invariant 8"):
        TraceChecker.check(_stream((EventType.WORKFLOW_REQUEUED, "", "", 1)))
    # duplicate STEP_STARTED *within* an epoch is still invariant 3
    bad = _stream((EventType.WORKFLOW_ADMITTED,),
                  (EventType.STEP_STARTED, "s"),
                  (EventType.STEP_STARTED, "s"))
    with pytest.raises(TraceViolation, match="invariant 3"):
        TraceChecker.check(bad)
