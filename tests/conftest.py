import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# must see 1 device (task spec). Multi-device tests run via subprocess
# (tests/test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
