"""Fault-tolerance benchmark: completion rate and makespan under chaos,
recovery machinery on vs off.

Two measured axes, same seeded ``FaultPlan`` everywhere:

* ``local`` — a batch of DAG workflows through ``LocalEngine`` with
  transient/permanent crashes and worker loss injected.
  ``recovery_off`` strips the safety nets (no retries survive the
  permanent crashes, no re-admission); ``recovery_on`` enables capped
  jittered retry backoff, frontier recording, and straggler-aware
  re-admission. The claim: recovery-on completes strictly more workflows.
* ``cluster`` — the ``MultiClusterEngine`` simulator under Poisson
  cluster preemption; recovery is structural there (evicted jobs re-enter
  placement), so the row reports the makespan inflation chaos costs
  relative to a preemption-free schedule.
"""
import asyncio
import random
import time
from typing import Any, Dict, List

from repro.core.analysis import TraceChecker
from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.engines.local import LocalEngine
from repro.core.faults import FaultPlan, ReadmissionPolicy
from repro.core.ir import Job, Resources, WorkflowIR


def _dag_batch(n_workflows: int, seed: int = 0) -> List[WorkflowIR]:
    rng = random.Random(seed)
    wfs = []
    for i in range(n_workflows):
        wf = WorkflowIR(f"bench-{i}")
        n = rng.randint(3, 6)
        for j in range(n):
            wf.add_job(Job(name=f"s{j}", fn=lambda i=i, j=j: i * 31 + j,
                           cacheable=False, outputs=[f"s{j}:out"],
                           retry_limit=3))
        for j in range(1, n):
            for k in range(j):
                if rng.random() < 0.4:
                    wf.add_edge(f"s{k}", f"s{j}")
        wfs.append(wf)
    return wfs


def _drive(eng: LocalEngine, wfs: List[WorkflowIR],
           timeout_s: float) -> List[Any]:
    async def one(wf):
        h = await eng.submit_async(wf, block=True)
        evs = [ev async for ev in h.events()]
        run = await h
        if run.status == "Succeeded":
            TraceChecker.check(evs, wf=wf)
        return run

    async def _all():
        return await asyncio.wait_for(
            asyncio.gather(*[one(w) for w in wfs], return_exceptions=True),
            timeout=timeout_s)

    return asyncio.run(_all())


def _local_row(config: str, n_workflows: int, plan: FaultPlan,
               timeout_s: float, **eng_kw) -> Dict[str, Any]:
    eng = LocalEngine(max_workers=6, enable_speculation=False,
                      promote_interval_s=0.0, check_events=True,
                      fault_plan=plan, **eng_kw)
    wfs = _dag_batch(n_workflows)
    t0 = time.time()
    results = _drive(eng, wfs, timeout_s)
    wall = time.time() - t0
    done = sum(1 for r in results
               if not isinstance(r, BaseException)
               and r.status == "Succeeded")
    inj = dict(eng.injector.stats) if eng.injector else {}
    readmitted = eng.gateway.stats.get("readmitted", 0)
    eng.close()
    return {
        "kind": "local", "config": config, "n_workflows": n_workflows,
        "completed": done,
        "completion_rate": round(done / n_workflows, 4),
        "makespan_s": round(wall, 4),
        "injected_faults": (inj.get("crash", 0)
                           + inj.get("crash_permanent", 0)
                           + inj.get("worker_lost", 0)),
        "readmissions": readmitted,
    }


def _cluster_row(n_workflows: int, plan) -> Dict[str, Any]:
    clusters = lambda: [Cluster("a", cpu=16, mem_bytes=1 << 40),  # noqa: E731
                        Cluster("b", cpu=16, mem_bytes=1 << 40)]
    rng = random.Random(1)
    def batch():
        wfs = []
        for i in range(n_workflows):
            wf = WorkflowIR(f"mc-{i}")
            prev = None
            for j in range(rng.randint(2, 4)):
                wf.add_job(Job(name=f"j{j}", est_time_s=1.0,
                               resources=Resources(cpu=4)))
                if prev:
                    wf.add_edge(prev, f"j{j}")
                prev = f"j{j}"
            wfs.append(wf)
        return [(w, "u0", 0) for w in wfs]
    eng = MultiClusterEngine(clusters=clusters(), fault_plan=plan)
    runs = eng.submit_many(batch())
    base = MultiClusterEngine(clusters=clusters())
    base.submit_many(batch())
    done = sum(1 for r in runs.values() if r.succeeded())
    return {
        "kind": "cluster",
        "config": "preemption" if plan else "fault_free",
        "n_workflows": n_workflows, "completed": done,
        "completion_rate": round(done / n_workflows, 4),
        "makespan_s": round(eng.metrics["makespan_s"], 4),
        "fault_free_makespan_s": round(base.metrics["makespan_s"], 4),
        "preemptions": eng.metrics["preemptions"],
        "preempted_jobs": eng.metrics["preempted_jobs"],
    }


def run(n_workflows: int = 24, timeout_s: float = 240.0) -> List[Dict]:
    plan = FaultPlan(seed=9, crash_rate=0.25, permanent_rate=0.1,
                     worker_loss_rate=0.1, max_failures_per_site=4)
    rows = [
        # recovery off: single attempt per step (retry budget zeroed via
        # an immediately-exhausted policy), no re-admission
        _local_row("recovery_off", n_workflows, plan, timeout_s,
                   retry_backoff_s=0.0, retry_backoff_max_s=0.0,
                   readmission=None),
        _local_row("recovery_on", n_workflows, plan, timeout_s,
                   retry_backoff_s=0.002, retry_backoff_max_s=0.02,
                   frontier=True,
                   readmission=ReadmissionPolicy(base_backoff_s=0.01,
                                                 max_backoff_s=0.1)),
        _cluster_row(n_workflows,
                     FaultPlan(seed=4, preemption_rate_per_s=0.3,
                               preemption_dark_s=2.0)),
    ]
    on = next(r for r in rows if r["config"] == "recovery_on")
    off = next(r for r in rows if r["config"] == "recovery_off")
    on["beats_recovery_off"] = on["completion_rate"] > off["completion_rate"]
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
