"""Gateway concurrency: serial sync-loop vs asyncio gateway wall-clock.

Serial baseline: N sequential ``LocalEngine.submit()`` calls — one caller
blocks per workflow, so wall time is the sum of all workflow latencies.
Gateway: the same N workflows admitted with ``submit_async`` from 8
tenants and awaited together — thousands of runs multiplex onto one shared
worker pool with bounded in-flight steps. The acceptance bar is a >=5x
speedup at n=500 with the in-flight bound enforced (reported per row).
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List, Sequence

from repro.core.engines.local import LocalEngine
from repro.core.ir import Job, WorkflowIR

STEP_SLEEP_S = 0.01
CHAIN_LEN = 3
MAX_WORKERS = 32
MAX_INFLIGHT_STEPS = 64


def _work(i: int, s: int) -> int:
    time.sleep(STEP_SLEEP_S)
    return i * CHAIN_LEN + s


def _chain_wf(tag: str, i: int) -> WorkflowIR:
    wf = WorkflowIR(f"gwb-{tag}-{i}")
    prev = None
    for s in range(CHAIN_LEN):
        name = f"s{s}"
        wf.add_job(Job(name=name, fn=_work, args=(i, s), cacheable=False,
                       outputs=[f"{name}:out"], est_time_s=STEP_SLEEP_S))
        if prev is not None:
            wf.add_edge(prev, name)
        prev = name
    return wf


def _serial(n: int) -> float:
    eng = LocalEngine(max_workers=MAX_WORKERS, enable_speculation=False,
                      promote_interval_s=0.0)
    t0 = time.time()
    for i in range(n):
        run = eng.submit(_chain_wf("ser", i), optimize=False)
        assert run.succeeded(), run.status
    wall = time.time() - t0
    eng.close()
    return wall


def _gateway(n: int) -> Dict:
    eng = LocalEngine(max_workers=MAX_WORKERS, enable_speculation=False,
                      max_inflight_steps=MAX_INFLIGHT_STEPS,
                      promote_interval_s=0.0)

    async def drive():
        handles = []
        for i in range(n):
            h = await eng.submit_async(_chain_wf("gw", i), optimize=False,
                                       tenant=f"t{i % 8}", block=True)
            handles.append(h)
        return await asyncio.gather(*handles)

    t0 = time.time()
    runs = asyncio.run(drive())
    wall = time.time() - t0
    ok = all(r.succeeded() for r in runs)
    peak = eng.gateway.stats["peak_inflight_steps"]
    eng.close()
    return {"wall_s": wall, "all_succeeded": ok,
            "peak_inflight_steps": peak,
            "bounded_inflight_ok": peak <= MAX_INFLIGHT_STEPS}


def run(sizes: Sequence[int] = (100, 500)) -> List[Dict]:
    rows: List[Dict] = []
    for n in sizes:
        serial_wall = _serial(n)
        gw = _gateway(n)
        rows.append({
            "n_workflows": n,
            "chain_len": CHAIN_LEN,
            "step_sleep_ms": STEP_SLEEP_S * 1e3,
            "serial_wall_s": round(serial_wall, 3),
            "gateway_wall_s": round(gw["wall_s"], 3),
            "speedup": round(serial_wall / max(gw["wall_s"], 1e-9), 1),
            "all_succeeded": gw["all_succeeded"],
            "peak_inflight_steps": gw["peak_inflight_steps"],
            "bounded_inflight_ok": gw["bounded_inflight_ok"],
        })
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
