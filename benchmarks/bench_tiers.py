"""Tiered-cache benchmark: hit ratio + SIMULATED makespan across tier
configurations on the paper's iterative-development sessions.

Replays ``benchmarks.workloads`` scenario DAGs in simulated time against a
``TieredCacheStore``: a step whose output key hits the cache costs the
holding tier's fetch time (latency + bytes/bandwidth), a miss costs the
step's est_time_s (recompute) and offers the artifact. Session makespan is
the DAG critical path over those effective durations — exactly the
fetch-vs-recompute trade the single-Alluxio-tier model (uniform hit
latency) cannot express.

Configs:
  mem_only          one MEM tier at the scenario budget (hot but tiny)
  unbounded_single  one REMOTE-speed tier, unlimited capacity — the old
                    CacheStore's Alluxio-tier assumption
  three_tier        MEM(budget) + SSD(4x) + REMOTE(16x), promotion pass
                    between sessions
  three_tier_shared two clusters alternating sessions, private MEM/SSD +
                    one SharedRemoteTier (cross-cluster reuse stats)

The acceptance check (benchmarks/run.py `cache_tiers` suite) asserts
three_tier achieves a strictly better simulated makespan than BOTH
baselines on the multimodal scenario.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from benchmarks.workloads import SCENARIOS, iterative_sessions
from repro.core.cache import (CacheTier, CoulerPolicy, SharedRemoteTier,
                              TierSpec, TieredCacheStore, mem_spec,
                              remote_spec, ssd_spec)
from repro.core.ir import WorkflowIR

# same contended budgets as bench_caching (55% of the large-artifact
# footprint at scale=1); artifact bytes scale ~ scale^2
CAPACITY = {"multimodal": 6 * 2**20, "image_seg": 2 * 2**20,
            "lm_finetune": 3 * 2**20}


def _key(wf: WorkflowIR, name: str) -> str:
    kw = sorted(wf.jobs[name].kwargs.items())
    return f"{wf.name}:{name}:{kw!r}"


def _replay_session(store: TieredCacheStore, wf: WorkflowIR) -> float:
    """One session in simulated time; returns the critical-path makespan
    under effective (fetch-or-recompute) durations."""
    store.attach_workflow(wf)
    dur: Dict[str, float] = {}
    for n in wf.topo_order():
        job = wf.jobs[n]
        k = _key(wf, n)
        before = store.stats["fetch_s"]
        if store.get(k) is not None:
            dur[n] = store.stats["fetch_s"] - before       # tier fetch time
        else:
            dur[n] = job.est_time_s                        # recompute
            store.offer(k, None, compute_time_s=job.est_time_s,
                        producer=n, nbytes=max(1, job.est_mem_bytes))
    finish: Dict[str, float] = {}
    for n in wf.topo_order():
        finish[n] = max((finish[p] for p in wf.predecessors(n)),
                        default=0.0) + dur[n]
    return max(finish.values(), default=0.0)


def _mk_store(config: str, budget: int, name: str = "c0",
              shared: Optional[SharedRemoteTier] = None) -> TieredCacheStore:
    if config == "mem_only":
        tiers = [CacheTier(mem_spec(budget))]
    elif config == "unbounded_single":
        tiers = [CacheTier(remote_spec(1 << 40))]
    elif shared is not None:
        # small private tiers so warm artifacts overflow into the shared
        # REMOTE tier where the sibling cluster can reuse them
        tiers = [CacheTier(mem_spec(budget)), CacheTier(ssd_spec(budget)),
                 shared]
    else:
        tiers = [CacheTier(mem_spec(budget)), CacheTier(ssd_spec(4 * budget)),
                 CacheTier(remote_spec(16 * budget))]
    return TieredCacheStore(tiers=tiers, policy=CoulerPolicy(), name=name)


def run_one(scenario: str, config: str, n_sessions: int = 4,
            scale: float = 1.0) -> Dict:
    budget = max(1 << 16, int(CAPACITY[scenario] * scale * scale))
    sessions = iterative_sessions(scenario, n_sessions=n_sessions,
                                  scale=scale)
    shared = None
    if config == "three_tier_shared":
        shared = SharedRemoteTier(remote_spec(16 * budget))
        stores = [_mk_store(config, budget, f"cluster-{i}", shared)
                  for i in range(2)]
    else:
        stores = [_mk_store(config, budget)]
    makespan = 0.0
    for s, wf in enumerate(sessions):
        store = stores[s % len(stores)]
        makespan += _replay_session(store, wf)
        if len(store.tiers) > 1:
            store.promote()                  # background promotion pass
    for store in stores:
        store.check_invariants()
    agg = lambda key: sum(st.stats[key] for st in stores)  # noqa: E731
    hits, misses = agg("hits"), agg("misses")
    row = {
        "scenario": scenario,
        "config": config,
        "mem_budget_mb": round(budget / 2**20, 3),
        "sim_makespan_s": round(makespan, 4),
        "hit_ratio": round(hits / max(hits + misses, 1), 4),
        "rejected": agg("rejected"),
        "evictions": agg("evictions"),
        "demotions": agg("demotions"),
        "promotions": agg("promotions"),
        "sim_fetch_s": round(agg("fetch_s"), 4),
        "tiers": [
            {"name": t.name, **{k: t.stats[k]
                                for k in ("hits", "admissions",
                                          "demotions_in", "demotions_out",
                                          "promotions_in", "promotions_out",
                                          "evictions")}}
            for st in stores for t in st.tiers
        ] if config != "mem_only" else None,
    }
    if shared is not None:
        row["shared_remote_hits_by_cluster"] = dict(shared.hits_by_client)
    return row


CONFIGS = ("mem_only", "unbounded_single", "three_tier", "three_tier_shared")


def run(scale: float = 1.0, n_sessions: int = 4) -> List[Dict]:
    rows = []
    for scenario in SCENARIOS:
        for config in CONFIGS:
            rows.append(run_one(scenario, config, n_sessions=n_sessions,
                                scale=scale))
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
