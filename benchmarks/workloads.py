"""Shared benchmark workloads: the paper's three RQ2 scenarios as workflow
DAGs whose steps are REAL (small) compute payloads with real artifact sizes,
so cache decisions face genuine time/space trade-offs.

  multimodal   37 pods / 19 "models"  (paper §VI.C)
  image_seg    15 pods /  8 "models"
  lm_finetune  21 pods / 11 "models"

Step payloads are numpy matmul/reduction workloads sized so a scenario runs
in seconds on CPU; `scale` shrinks them for tests.
"""
from __future__ import annotations

import numpy as np

from repro.core import couler
from repro.core.ir import WorkflowIR


def _load(shape, seed):
    def fn():
        rng = np.random.default_rng(seed)
        return rng.standard_normal(shape).astype(np.float32)
    return fn


def _transform(reps):
    def fn(x, **kw):
        y = x
        for _ in range(reps):
            y = np.tanh(y @ y.T[: y.shape[1], : y.shape[1]])
        return y.astype(np.float32)
    return fn


def _train(reps):
    def fn(x, **kw):
        w = np.ones((x.shape[1], 64), np.float32) * 0.01
        for _ in range(max(2, reps // 6)):
            h = np.maximum(x @ w, 0)
            w = w + 1e-3 * (x.T @ h)[:, :64] / x.shape[0]
        return w
    return fn


def _eval(x=None, *rest, **kw):
    return float(np.mean(np.abs(x))) if x is not None else 0.0


SCENARIOS = {
    # name: (n_branches, models_per_branch, dim)
    "multimodal": (6, 3, 448),      # ~37 pods, 19 trains
    "image_seg": (3, 2, 384),       # ~15 pods, 8 trains
    "lm_finetune": (4, 2, 416),     # ~21 pods, 11 trains
}


def build_scenario(name: str, scale: float = 1.0, seed: int = 0) -> WorkflowIR:
    """Branchy ML DAG: shared data load -> per-branch transform chains ->
    several train steps per branch -> eval -> select.

    Branches are HETEROGENEOUS (rebuild cost grows with branch id, and so
    does the downstream fan-out): feat-5 costs ~6x feat-0 to rebuild and is
    consumed by more trainers — exactly the (reconstruction cost x reuse
    value) signal Eq. 6 scores and size-oblivious FIFO/LRU cannot see."""
    branches, models, dim = SCENARIOS[name]
    dim = max(32, int(dim * scale))
    reps = max(1, int(8 * scale))

    with couler.workflow(f"{name}-wf") as ir:
        raw = couler.run_step(_load((dim, dim), seed), step_name="load-data",
                              est_time_s=0.05, est_mem_bytes=dim * dim * 4)
        prep = couler.run_step(_transform(reps * 3), raw,
                               step_name="preprocess",
                               est_time_s=0.3, est_mem_bytes=dim * dim * 4)
        evals = []
        for b in range(branches):
            b_reps = reps * (1 + 2 * b)               # cost heterogeneity
            b_models = 1 + (b * models) // max(branches - 1, 1)  # fan-out
            feat = couler.run_step(_transform(b_reps), prep,
                                   step_name=f"feat-{b}",
                                   est_time_s=0.05 * (1 + 2 * b),
                                   est_mem_bytes=dim * dim * 4)
            for m in range(b_models):
                t = couler.run_step(_train(reps), feat,
                                    step_name=f"train-{b}-{m}",
                                    est_time_s=0.05,
                                    est_mem_bytes=dim * 64 * 4)
                evals.append(couler.run_step(_eval, t,
                                             step_name=f"eval-{b}-{m}",
                                             est_time_s=0.01))
        couler.run_step(lambda *xs: max(xs), *evals, step_name="select")
    return ir


def iterative_sessions(name: str, n_sessions: int = 3, scale: float = 1.0):
    """The paper's iterative-development pattern: the same scenario is
    resubmitted repeatedly with small edits (a changed trailing stage), so
    early artifacts are repeatedly reusable. Returns list of WorkflowIRs."""
    out = []
    for s in range(n_sessions):
        ir = build_scenario(name, scale=scale, seed=0)
        # session s modifies one branch's training step (new kwargs)
        victim = f"train-0-0"
        if victim in ir.jobs and s > 0:
            ir.jobs[victim].kwargs = {"session": s}
        out.append(ir)
    return out
