"""Fig. 7 / App. D.A analog: caching strategies (No/ALL/FIFO/LRU/COULER)
across the three scenarios — wall time, storage, hit ratio — on REAL
iterative workflow sessions (resubmissions with small edits)."""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.workloads import SCENARIOS, iterative_sessions
from repro.core.caching import (CacheAll, CacheStore, CoulerPolicy,
                                FIFOPolicy, LRUPolicy, NoCache)
from repro.core.engines.local import LocalEngine

POLICIES = {
    "none": NoCache,
    "all": CacheAll,
    "fifo": FIFOPolicy,
    "lru": LRUPolicy,
    "couler": CoulerPolicy,
}


def run_one(scenario: str, policy_name: str, capacity_bytes: int,
            n_sessions: int = 4, scale: float = 1.0) -> Dict:
    if policy_name == "all":
        capacity_bytes = 1 << 40   # paper's ALL: unbounded storage cost
    cache = CacheStore(capacity_bytes=capacity_bytes,
                       policy=POLICIES[policy_name]())
    eng = LocalEngine(cache=cache, max_workers=8, enable_speculation=False)
    t0 = time.time()
    statuses = []
    for ir in iterative_sessions(scenario, n_sessions=n_sessions, scale=scale):
        run = eng.submit(ir)
        assert run.succeeded(), (scenario, policy_name, run.counts())
        statuses.append(run.counts())
    wall = time.time() - t0
    return {
        "scenario": scenario,
        "policy": policy_name,
        "capacity_mb": capacity_bytes / 2**20,
        "wall_s": round(wall, 3),
        "score_s": round(cache.stats["score_time_s"], 4),
        "hit_ratio": round(cache.hit_ratio(), 4),
        "peak_cache_mb": round(cache.used_bytes / 2**20, 3),
        "evictions": cache.stats["evictions"],
        "cached_steps": sum(s.get("Cached", 0) for s in statuses),
    }


# capacity ~55% of each scenario's large-artifact footprint so the cache
# is genuinely contended (the paper's Alluxio tier is always oversubscribed)
CAPACITY = {"multimodal": 6 * 2**20, "image_seg": 2 * 2**20,
            "lm_finetune": 3 * 2**20}


def run(scale: float = 1.0) -> List[Dict]:
    rows = []
    for scenario in SCENARIOS:
        for policy in POLICIES:
            rows.append(run_one(scenario, policy, CAPACITY[scenario],
                                scale=scale))
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
