"""Benchmark driver — one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; detailed JSON per suite is
written to out/bench/<suite>.json. ``--quick`` runs every suite at reduced
scale (CI smoke mode) and a consolidated ``BENCH_<date>.json`` — one
object with every suite's rows plus wall times — is always emitted.
"""
import argparse
import datetime
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

OUT = Path("out/bench")

SUITES = [
    # (name, import path, quick-mode kwargs, derived-metric extractor)
    ("caching_fig7", "benchmarks.bench_caching", {"scale": 0.4},
     lambda rows: "couler_beats_fifo_lru_in=" + str(sum(
         1 for s in {"multimodal", "image_seg", "lm_finetune"}
         if [r["wall_s"] for r in rows
             if r["scenario"] == s and r["policy"] == "couler"][0]
         < min(r["wall_s"] for r in rows
               if r["scenario"] == s and r["policy"] in ("fifo", "lru"))))),
    ("cache_sizes_appDB", "benchmarks.bench_cache_sizes", {"scale": 0.4},
     lambda rows: "hit_ratio_range=%.2f-%.2f" % (
         min(r["hit_ratio"] for r in rows),
         max(r["hit_ratio"] for r in rows))),
    ("cache_tiers", "benchmarks.bench_tiers", {"scale": 0.4},
     lambda rows: "three_tier_beats_both_baselines=" + str(
         [r["sim_makespan_s"] for r in rows
          if r["scenario"] == "multimodal" and r["config"] == "three_tier"][0]
         < min(r["sim_makespan_s"] for r in rows
               if r["scenario"] == "multimodal"
               and r["config"] in ("mem_only", "unbounded_single")))),
    ("nl2wf_tableII", "benchmarks.bench_nl2wf", {"n_seeds": 2},
     lambda rows: "gpt4_ours_pass@5=" + str(
         [r for r in rows if r.get("model") == "gpt-4+ours"][0]["pass@5"])),
    ("autotune_fig8", "benchmarks.bench_autotune", {"steps": 15},
     lambda rows: "ours_final_loss=" + str(
         [r for r in rows if r["config"] == "HP:Ours"][0]["final_loss"])),
    ("split_secIVB", "benchmarks.bench_split", {},
     lambda rows: "all_within_budget=" + str(
         all(r["within_crd_budget"] for r in rows))),
    ("throughput_rq1", "benchmarks.bench_throughput", {"n_workflows": 300},
     lambda rows: "workflows_per_s=" + str(rows[0]["workflows_per_s"])),
    ("observability_overhead", "benchmarks.bench_obs", {"n_workflows": 2000},
     lambda rows: "overhead_pct=%s_under_2pct=%s_telemetry_pct=%s_under_2pct=%s" % (
         rows[0]["overhead_pct"], rows[0]["overhead_under_2pct"],
         rows[2]["overhead_pct"], rows[2]["overhead_under_2pct"])),
    ("analysis_overhead", "benchmarks.bench_analysis", {"n_workflows": 2000},
     lambda rows: "lint_pct_of_submit=%s_under_2pct=%s_linear=%s" % (
         rows[0]["overhead_pct"], rows[0]["overhead_under_2pct"],
         rows[0]["linear_ok"])),
    ("gateway_concurrency", "benchmarks.bench_gateway",
     {"sizes": (100, 500)},
     lambda rows: "speedup_n%d=%sx_bounded=%s" % (
         rows[-1]["n_workflows"], rows[-1]["speedup"],
         all(r["bounded_inflight_ok"] and r["all_succeeded"]
             for r in rows))),
    ("streaming_pipeline", "benchmarks.bench_streaming",
     {"n_chunks": 32, "chunk_sleep_s": 0.008},
     lambda rows: "streamed_over_stage=%sx_meets_1p5x=%s" % (
         rows[0]["streamed_over_stage"],
         rows[0]["meets_1p5x_bar"] and rows[0]["artifacts_identical"]
         and rows[0]["bounded_inflight_ok"])),
    ("fault_tolerance", "benchmarks.bench_faults",
     {"n_workflows": 12, "timeout_s": 120.0},
     lambda rows: "recovery_on=%s_off=%s_beats=%s_preempt_ok=%s" % (
         [r["completion_rate"] for r in rows
          if r["config"] == "recovery_on"][0],
         [r["completion_rate"] for r in rows
          if r["config"] == "recovery_off"][0],
         [r for r in rows if r["config"] == "recovery_on"
          ][0]["beats_recovery_off"],
         [r["completion_rate"] for r in rows
          if r["kind"] == "cluster"][0] == 1.0)),
    ("learning_tableIV", "benchmarks.bench_learning", {},
     lambda rows: "couler_loc=" + str(
         [r for r in rows if r["interface"] == "couler"][0]["loc"])),
    ("roofline_dryrun", "benchmarks.roofline_report", {},
     lambda rows: "cells_ok=" + str(rows[0]["cells_ok"])),
]


def check_trajectory(threshold_pct: float = 25.0) -> int:
    """Regression watchdog over the latest consolidated BENCH file.

    Reads the most recent ``BENCH_<date>.json`` and fails (returns the
    number of offending suites) when any suite's recorded trajectory
    shows a wall-clock regression above ``threshold_pct`` vs the prior
    BENCH file it was compared against. With fewer than two BENCH files
    on disk there is no trajectory to judge — that is a skip (0), not a
    failure, so fresh clones stay green.
    """
    files = sorted(OUT.glob("BENCH_*.json"))
    if not files:
        print("# bench-check: no BENCH files — skip", file=sys.stderr)
        return 0
    latest = json.loads(files[-1].read_text())
    traj = latest.get("trajectory", {}).get("suites", {})
    if not traj:
        print(f"# bench-check: {files[-1].name} has no trajectory "
              "(first recorded run) — skip", file=sys.stderr)
        return 0
    baseline = latest.get("trajectory", {}).get("baseline", "?")
    bad = 0
    for name, t in sorted(traj.items()):
        if t["delta_pct"] > threshold_pct:
            bad += 1
            print(f"# bench-check REGRESSION {name}: {t['prev_wall_s']}s -> "
                  f"{t['wall_s']}s ({t['delta_pct']:+.1f}% > "
                  f"+{threshold_pct:.0f}%)", file=sys.stderr)
    print(f"# bench-check: {files[-1].name} vs {baseline}: "
          f"{len(traj)} suites, {bad} over +{threshold_pct:.0f}%",
          file=sys.stderr)
    return bad


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="run each suite at reduced scale (CI smoke mode)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="suite names to run (default: all)")
    ap.add_argument("--check", action="store_true",
                    help="judge the recorded bench trajectory instead of "
                         "running suites; exit nonzero on any >25%% "
                         "wall-clock regression")
    ap.add_argument("--check-threshold", type=float, default=25.0,
                    help="regression threshold in percent (default 25)")
    args = ap.parse_args(argv)

    if args.check:
        sys.exit(1 if check_trajectory(args.check_threshold) else 0)

    OUT.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    failures = []
    consolidated = {
        "date": datetime.date.today().isoformat(),
        "mode": "quick" if args.quick else "full",
        "suites": {},
    }
    for name, mod_path, quick_kwargs, derive in SUITES:
        if args.only and name not in args.only:
            continue
        t0 = time.time()
        try:
            mod = __import__(mod_path, fromlist=["run"])
            rows = mod.run(**(quick_kwargs if args.quick else {}))
            dur_us = (time.time() - t0) * 1e6
            (OUT / f"{name}.json").write_text(json.dumps(rows, indent=1))
            consolidated["suites"][name] = {
                "wall_s": round(dur_us / 1e6, 3),
                "derived": derive(rows),
                "rows": rows,
            }
            print(f"{name},{dur_us:.0f},{derive(rows)}")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            consolidated["suites"][name] = {"error": repr(e)}
            print(f"{name},0,ERROR:{type(e).__name__}")
    # bench trajectory: compare this run's per-suite wall clocks against
    # the most recent previous consolidated file, so drift across PRs is
    # observable instead of silently accumulating
    consolidated["total_wall_s"] = round(sum(
        s.get("wall_s", 0.0) for s in consolidated["suites"].values()), 3)
    bench_file = OUT / f"BENCH_{consolidated['date']}.json"
    prev = sorted(p for p in OUT.glob("BENCH_*.json") if p != bench_file)
    if prev:
        try:
            old = json.loads(prev[-1].read_text())
            traj = {}
            for name, suite in consolidated["suites"].items():
                before = old.get("suites", {}).get(name, {}).get("wall_s")
                now = suite.get("wall_s")
                if before and now:
                    traj[name] = {
                        "prev_wall_s": before, "wall_s": now,
                        "delta_pct": round(100.0 * (now - before) / before,
                                           1)}
            consolidated["trajectory"] = {"baseline": prev[-1].name,
                                          "suites": traj}
        except (ValueError, OSError):
            pass                       # a corrupt old file never blocks
    bench_file.write_text(json.dumps(consolidated, indent=1))
    print(f"# consolidated -> {bench_file}", file=sys.stderr)
    for name, t in consolidated.get("trajectory", {}).get("suites",
                                                          {}).items():
        print(f"# trajectory {name}: {t['prev_wall_s']}s -> {t['wall_s']}s "
              f"({t['delta_pct']:+.1f}%)", file=sys.stderr)
    if failures:
        for n, e in failures:
            print(f"# FAILED {n}: {e}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
