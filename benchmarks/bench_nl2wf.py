"""Table II + Table III analog: pass@k for NL -> unified-code generation.

A suite of NL descriptions each carries an executable GRADER over the built
IR. pass@k is measured over seeded samples at t in {0.2, 0.6, 0.8} for the
two simulated model tiers, with and without the paper's method (Code-Lake
retrieval + decomposition + self-calibration). Numbers are real
measurements of the surrogate error model (DESIGN.md §2.4) — the claim
reproduced is the ORDERING (ours > raw, gpt-4 > gpt-3.5), not the absolute
paper values.
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.core.llm import TemplateLLM
from repro.core.nl2wf import nl_to_workflow

# (description, grader(ir) -> bool)
SUITE: List = [
    ("Load the dataset named demo, preprocess it, train the ResNet and ViT "
     "models, evaluate accuracy, then select the best model.",
     lambda ir: ({"load-data", "preprocess", "select-best"} <= set(ir.jobs)
                 and sum(n.startswith("train-") for n in ir.jobs) >= 2
                 and sum(n.startswith("eval-") for n in ir.jobs) >= 2)),

    ("Load the click logs, preprocess them and train an xgboost model, then "
     "evaluate auc.",
     lambda ir: ({"load-data", "preprocess", "train"} <= set(ir.jobs)
                 and any(n.startswith("eval") for n in ir.jobs))),

    ("Fine-tune a GPT language model on the corpus after loading and "
     "tokenizing the text, then checkpoint save the weights.",
     lambda ir: ("finetune" in ir.jobs and "checkpoint" in ir.jobs
                 and ("finetune", "checkpoint") in ir.edges
                 or ("finetune" in ir.jobs and "checkpoint" in ir.jobs))),

    ("Load images, augment the training data with transformations, train a "
     "CNN model and evaluate accuracy.",
     lambda ir: ({"load-data", "augment"} <= set(ir.jobs)
                 and any(n.startswith("train") for n in ir.jobs))),

    ("Load the table, split the data into train and validation sets, train "
     "LSTM and transformer models and select the best by loss.",
     lambda ir: ({"load-data", "split-data", "select-best"} <= set(ir.jobs)
                 and sum(n.startswith("train-") for n in ir.jobs) >= 2)),

    ("Load features, preprocess them, tune hyperparameters over 4 "
     "configurations and train the best model.",
     lambda ir: ("load-data" in ir.jobs
                 and sum(n.startswith("hp-") for n in ir.jobs) >= 3)),

    ("Load the data and run xgboost and lightgbm training jobs concurrently "
     "in parallel, then select the best.",
     lambda ir: ({"train-a", "train-b"} <= set(ir.jobs))),

    ("Load sensor data, preprocess it, train a transformer model, evaluate "
     "f1, deploy the model if it passes the quality gate.",
     lambda ir: ("deploy" in ir.jobs
                 and ir.jobs["deploy"].condition is not None)),

    ("Load the corpus, preprocess and keep running the check step "
     "repeatedly until the condition is met, then generate a report.",
     lambda ir: ("check" in ir.jobs
                 and ir.jobs["check"].loop_condition is not None
                 and "report" in ir.jobs)),

    ("Load the dataset named ads, preprocess it, train DenseNet, evaluate "
     "accuracy and generate a summary report.",
     lambda ir: ({"load-data", "preprocess", "report"} <= set(ir.jobs)
                 and any(n.startswith("train") for n in ir.jobs))),
]


def _passes(desc: str, grader: Callable, llm: TemplateLLM, t: float,
            seed: int, max_rounds: int) -> bool:
    res = nl_to_workflow(desc, llm=llm, temperature=t, seed=seed,
                         max_rounds=max_rounds)
    if res.error is not None or res.workflow is None:
        return False
    try:
        return bool(grader(res.workflow))
    except Exception:
        return False


def pass_at_k(tier: str, use_references: bool, *, ks=(1, 3, 5),
              temps=(0.2, 0.6, 0.8), n_seeds: int = 5) -> Dict:
    """Best pass@k across temperatures (paper's evaluation procedure)."""
    max_rounds = 4 if use_references else 1   # 'ours' adds self-calibration
    best = {k: 0.0 for k in ks}
    tokens = 0
    for t in temps:
        totals = {k: 0 for k in ks}
        for desc, grader in SUITE:
            llm = TemplateLLM(tier, use_references=use_references)
            results = [_passes(desc, grader, llm, t, seed, max_rounds)
                       for seed in range(n_seeds)]
            tokens += llm.tokens_used
            for k in ks:
                # pass@k: any of the first k samples passes
                totals[k] += any(results[:k])
        for k in ks:
            best[k] = max(best[k], totals[k] / len(SUITE))
    return {"model": tier + ("+ours" if use_references else ""),
            "pass@1": round(best[1] * 100, 2),
            "pass@3": round(best[3] * 100, 2),
            "pass@5": round(best[5] * 100, 2),
            "tokens_per_workflow": tokens // (len(SUITE) * len(temps) * 5)}


def run(n_seeds: int = 5) -> List[Dict]:
    rows = []
    for tier in ("gpt-3.5", "gpt-4"):
        rows.append(pass_at_k(tier, use_references=False, n_seeds=n_seeds))
        rows.append(pass_at_k(tier, use_references=True, n_seeds=n_seeds))
    # Table III analog: cost per workflow
    for tier in ("gpt-3.5", "gpt-4"):
        llm = TemplateLLM(tier)
        nl_to_workflow(SUITE[0][0], llm=llm, seed=0)
        rows.append({"model": tier, "cost_tokens": llm.tokens_used,
                     "cost_usd": round(llm.cost_usd(), 5)})
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
