"""RQ1 analog: engine scheduling throughput at Ant-Group-like volume.

Pushes thousands of small workflows (mean ~6 steps, 36-core jobs, ~1h-scale
simulated durations) through the multi-cluster scheduling queue and reports
scheduler throughput (workflows/s of real wall time) plus simulated cluster
utilization — the 22k workflows/day claim needs ~0.25 wf/s sustained."""
from __future__ import annotations

import random
import time
from typing import Dict, List

from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.ir import Job, Resources, WorkflowIR


def _small_wf(i: int, rng: random.Random) -> WorkflowIR:
    wf = WorkflowIR(f"wf-{i}")
    n = rng.randint(3, 9)
    prev = None
    for s in range(n):
        wf.add_job(Job(name=f"s{s}", est_time_s=rng.uniform(60, 7200),
                       resources=Resources(cpu=rng.choice([4, 16, 36, 64]))))
        if prev is not None and rng.random() < 0.8:
            wf.add_edge(prev, f"s{s}")
        prev = f"s{s}"
    return wf


def run(n_workflows: int = 2000, seed: int = 0) -> List[Dict]:
    rng = random.Random(seed)
    wfs = [(_small_wf(i, rng), f"user{i % 50}", rng.randint(0, 3))
           for i in range(n_workflows)]
    eng = MultiClusterEngine(clusters=[
        Cluster("gpu", cpu=40_000, mem_bytes=1 << 60, gpu=4_500),
        Cluster("cpu-a", cpu=800_000, mem_bytes=1 << 62),
        Cluster("cpu-b", cpu=800_000, mem_bytes=1 << 62),
    ])
    t0 = time.time()
    runs = eng.submit_many(wfs)
    wall = time.time() - t0
    ok = sum(r.succeeded() for r in runs.values())
    total_cpu_s = sum(eng.metrics["cluster_busy_s"].values())
    cap_cpu_s = sum(c.cpu for c in eng.clusters) * eng.metrics["makespan_s"]
    return [{
        "workflows": n_workflows,
        "succeeded": ok,
        "scheduler_wall_s": round(wall, 2),
        "workflows_per_s": round(n_workflows / wall, 1),
        "sim_makespan_h": round(eng.metrics["makespan_s"] / 3600, 2),
        "scheduled_jobs": eng.metrics["scheduled_jobs"],
        "sim_cluster_utilization": round(total_cpu_s / cap_cpu_s, 4),
        "daily_capacity_at_this_rate": int(n_workflows / wall * 86400),
    }]


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
