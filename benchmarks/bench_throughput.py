"""RQ1 analog: engine scheduling throughput at Ant-Group-like volume.

Pushes thousands of small workflows (mean ~6 steps, 36-core jobs, ~1h-scale
simulated durations) through the multi-cluster scheduling queue and reports
scheduler throughput (workflows/s of real wall time) plus simulated cluster
utilization — the 22k workflows/day claim needs ~0.25 wf/s sustained.

Two scenarios: ``direct`` (the legacy batch handed straight to
``submit_many``) and ``admission_queue`` (the same workload offered
concurrently through the gateway's backpressured multi-tenant
``AdmissionQueue`` and drained into ``submit_many`` in weighted
round-robin tenant order — the concurrent-submission path)."""
from __future__ import annotations

import random
import time
from typing import Dict, List

from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.gateway import AdmissionQueue, AdmittedItem
from repro.core.ir import Job, Resources, WorkflowIR


def _small_wf(i: int, rng: random.Random) -> WorkflowIR:
    wf = WorkflowIR(f"wf-{i}")
    n = rng.randint(3, 9)
    prev = None
    for s in range(n):
        wf.add_job(Job(name=f"s{s}", est_time_s=rng.uniform(60, 7200),
                       resources=Resources(cpu=rng.choice([4, 16, 36, 64]))))
        if prev is not None and rng.random() < 0.8:
            wf.add_edge(prev, f"s{s}")
        prev = f"s{s}"
    return wf


def _clusters() -> List[Cluster]:
    return [
        Cluster("gpu", cpu=40_000, mem_bytes=1 << 60, gpu=4_500),
        Cluster("cpu-a", cpu=800_000, mem_bytes=1 << 62),
        Cluster("cpu-b", cpu=800_000, mem_bytes=1 << 62),
    ]


def run(n_workflows: int = 2000, seed: int = 0) -> List[Dict]:
    rng = random.Random(seed)
    wfs = [(_small_wf(i, rng), f"user{i % 50}", rng.randint(0, 3))
           for i in range(n_workflows)]
    eng = MultiClusterEngine(clusters=_clusters())
    t0 = time.time()
    runs = eng.submit_many(wfs)
    wall = time.time() - t0
    ok = sum(r.succeeded() for r in runs.values())
    total_cpu_s = sum(eng.metrics["cluster_busy_s"].values())
    cap_cpu_s = sum(c.cpu for c in eng.clusters) * eng.metrics["makespan_s"]
    rows = [{
        "scenario": "direct",
        "workflows": n_workflows,
        "succeeded": ok,
        "scheduler_wall_s": round(wall, 2),
        "workflows_per_s": round(n_workflows / wall, 1),
        "sim_makespan_h": round(eng.metrics["makespan_s"] / 3600, 2),
        "scheduled_jobs": eng.metrics["scheduled_jobs"],
        "sim_cluster_utilization": round(total_cpu_s / cap_cpu_s, 4),
        "daily_capacity_at_this_rate": int(n_workflows / wall * 86400),
    }]

    # concurrent-submission scenario: the same workload offered through the
    # backpressured multi-tenant admission queue (every 5th user gets
    # double WRR weight) and drained into submit_many. Workflow/engine
    # construction stays OUTSIDE the timed window, exactly like the direct
    # scenario, so the two workflows_per_s figures are comparable
    rng = random.Random(seed)
    items = [AdmittedItem(wf=_small_wf(i, rng), tenant=f"user{i % 50}",
                          priority=rng.randint(0, 3))
             for i in range(n_workflows)]
    queue = AdmissionQueue(max_depth_per_tenant=n_workflows,
                           max_total=2 * n_workflows,
                           weights={f"user{u}": 2 for u in range(0, 50, 5)})
    eng2 = MultiClusterEngine(clusters=_clusters())
    t0 = time.time()
    for it in items:
        queue.offer(it)
    runs2 = eng2.submit_admitted(queue)
    wall2 = time.time() - t0
    rows.append({
        "scenario": "admission_queue",
        "workflows": n_workflows,
        "succeeded": sum(r.succeeded() for r in runs2.values()),
        "scheduler_wall_s": round(wall2, 2),
        "workflows_per_s": round(n_workflows / wall2, 1),
        "sim_makespan_h": round(eng2.metrics["makespan_s"] / 3600, 2),
        "scheduled_jobs": eng2.metrics["scheduled_jobs"],
        "queue_shed": queue.stats["shed"],
    })
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
