"""Static-analysis overhead: the submission-time lint gate must be noise.

Two claims pinned here (see docs/diagnostics.md):

* ``analysis_overhead`` — linting the RQ1 throughput population (n small
  workflows, same generator as ``bench_throughput``) costs < 2% of the
  event-driven ``submit_many`` wall time at n=2000, so the default
  ``lint="error"`` gate does not move the scheduler-throughput numbers.
* ``scaling`` — lint wall time is O(V+E): microseconds per job stay flat
  as a single workflow grows from ~50 to ~3200 steps.
"""
from __future__ import annotations

import random
import time
from typing import Dict, List

from benchmarks.bench_throughput import _clusters, _small_wf
from repro.core.analysis import lint
from repro.core.engines.cluster import MultiClusterEngine
from repro.core.ir import Job, WorkflowIR


def _big_wf(k: int, rng: random.Random) -> WorkflowIR:
    """One deep workflow of k jobs: a chain plus ~0.3 skip edges/job."""
    wf = WorkflowIR(f"scale-{k}")
    for s in range(k):
        wf.add_job(Job(name=f"s{s}"))
        if s:
            wf.add_edge(f"s{s - 1}", f"s{s}")
        if s >= 2 and rng.random() < 0.3:
            wf.add_edge(f"s{rng.randrange(s - 1)}", f"s{s}")
    return wf


def run(n_workflows: int = 2000, seed: int = 0,
        sizes=(50, 200, 800, 3200)) -> List[Dict]:
    rng = random.Random(seed)
    pop = [(_small_wf(i, rng), f"user{i % 50}", rng.randint(0, 3))
           for i in range(n_workflows)]
    clusters = _clusters()

    lint_wall, n_err = 1e9, 0
    for _rep in range(3):               # best-of-3: one sweep is ~15 ms
        for wf, _user, _prio in pop:
            wf._topo_cache = None
        t0 = time.perf_counter()
        n_err = 0
        for wf, _user, _prio in pop:
            n_err += len(lint(wf, clusters=clusters,
                              max_inflight_steps=64).errors)
        lint_wall = min(lint_wall, time.perf_counter() - t0)

    eng = MultiClusterEngine(clusters=clusters)
    t0 = time.perf_counter()
    runs = eng.submit_many(pop, lint="off")   # pure scheduling wall
    submit_wall = time.perf_counter() - t0
    overhead_pct = 100.0 * lint_wall / submit_wall
    rows = [{
        "scenario": "analysis_overhead",
        "n_workflows": n_workflows,
        "lint_errors": n_err,
        "succeeded": sum(r.succeeded() for r in runs.values()),
        "lint_wall_s": round(lint_wall, 4),
        "submit_wall_s": round(submit_wall, 3),
        "overhead_pct": round(overhead_pct, 3),
        "overhead_under_2pct": overhead_pct < 2.0,
    }]

    per_job = {}
    for k in sizes:
        wf = _big_wf(k, random.Random(seed + k))
        wall = min(_timed_lint(wf) for _ in range(3))
        per_job[k] = 1e6 * wall / k
        rows.append({
            "scenario": "scaling",
            "n_jobs": k,
            "n_edges": len(wf.edges),
            "lint_ms": round(wall * 1e3, 3),
            "us_per_job": round(per_job[k], 3),
        })
    # O(V+E): per-job cost must not grow with size (compare against the
    # mid size; the smallest is constant-overhead dominated)
    rows[0]["linear_ok"] = per_job[sizes[-1]] < 3.0 * per_job[sizes[1]]
    return rows


def _timed_lint(wf: WorkflowIR) -> float:
    wf._topo_cache = None              # defeat cross-repeat cache priming
    t0 = time.perf_counter()
    res = lint(wf, clusters=_clusters(), max_inflight_steps=1 << 20)
    assert res.ok(), [str(d) for d in res.errors]
    return time.perf_counter() - t0


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
