"""App. D.B analog: COULER policy effectiveness vs cache capacity
(paper: 10G/20G/30G; scaled to this container's workload sizes)."""
from __future__ import annotations

from typing import Dict, List

from benchmarks.bench_caching import run_one
from benchmarks.workloads import SCENARIOS


from benchmarks.bench_caching import CAPACITY


def run(scale: float = 1.0) -> List[Dict]:
    rows = []
    for scenario in SCENARIOS:
        base = CAPACITY[scenario]
        for frac in (0.5, 1.0, 2.5):
            rows.append(run_one(scenario, "couler", int(base * frac),
                                scale=scale))
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
