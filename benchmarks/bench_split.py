"""§IV.B analog: big-workflow auto-parallelism.

Measures, for 400- and 1200-node DAGs: the CRD/spec size before vs after
the split (the 2MB Kubernetes limit), number of parts, budget compliance,
and the scheduled makespan with vs without split-driven part parallelism
(event-driven multi-cluster simulation — no sleeping)."""
from __future__ import annotations

from typing import Dict, List

from repro.core.autosplit import Budget, schedule_parts, split_workflow
from repro.core.engines.argo import to_argo_yaml
from repro.core.engines.cluster import Cluster, MultiClusterEngine
from repro.core.ir import Job, Resources, WorkflowIR


def _big_workflow(n_nodes: int, branches: int = 8) -> WorkflowIR:
    """Wide-and-deep production-style DAG: a root fan-out into branch
    chains with periodic joins."""
    wf = WorkflowIR(f"big-{n_nodes}")
    wf.add_job(Job(name="root", est_time_s=1.0))
    per = (n_nodes - 1) // branches
    for b in range(branches):
        prev = "root"
        for i in range(per):
            name = f"b{b}-s{i}"
            wf.add_job(Job(name=name, est_time_s=1.0,
                           resources=Resources(cpu=2)))
            wf.add_edge(prev, name)
            prev = name
    return wf


def _makespan(wf_or_parts, engine) -> float:
    if isinstance(wf_or_parts, list):
        runs = engine.submit_many([(p, "u0", 0) for p in wf_or_parts])
    else:
        engine.submit(wf_or_parts)
    return engine.metrics["makespan_s"]


def run() -> List[Dict]:
    rows = []
    budget = Budget(spec_bytes=64 * 1024, steps=200)   # scaled CRD limit
    for n in (400, 1200):
        wf = _big_workflow(n)
        yaml_before = len(to_argo_yaml(wf).encode())
        parts = split_workflow(wf, budget)
        yaml_after = max(len(to_argo_yaml(p).encode()) for p in parts)
        waves = schedule_parts(wf, parts)

        clusters = lambda: [Cluster("a", cpu=256, mem_bytes=1 << 60),
                            Cluster("b", cpu=256, mem_bytes=1 << 60)]
        mk_whole = _makespan(wf, MultiClusterEngine(clusters()))
        mk_parts = _makespan(parts, MultiClusterEngine(clusters()))
        rows.append({
            "nodes": n,
            "yaml_bytes_before": yaml_before,
            "max_part_yaml_bytes": yaml_after,
            "within_crd_budget": yaml_after <= budget.spec_bytes,
            "parts": len(parts),
            "waves": len(waves),
            "makespan_unsplit_s": mk_whole,
            "makespan_split_s": mk_parts,
        })
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
