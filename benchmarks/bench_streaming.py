"""Streaming artifact pipelines: whole-artifact vs chunked makespan.

An 8-stage linear pipeline with equal per-stage cost is the worst case for
whole-artifact handoff: stage k+1 cannot start until stage k has fully
materialized, so makespan ~= stages * stage_time. Chunked channels overlap
the stages — once the pipeline fills, every stage works concurrently on a
different chunk and makespan approaches ONE stage time plus the fill/drain
ramp. The acceptance bar is streamed makespan <= 1.5x the slowest stage
(vs ~8x for whole-artifact), artifacts bit-identical between the two runs,
and peak in-flight steps within the gateway bound throughout.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import couler
from repro.core.engines.local import LocalEngine

STAGES = 8
MAX_INFLIGHT_STEPS = 16


def _stage_fn(k: int, chunk_sleep_s: float):
    def fn(c):
        time.sleep(chunk_sleep_s)
        return c * 2 + k
    return fn


def _source(n_chunks: int, chunk_sleep_s: float):
    def gen():
        for i in range(n_chunks):
            time.sleep(chunk_sleep_s)
            yield i
    return gen


def _whole_wf(n_chunks: int, chunk_sleep_s: float):
    """Same computation with whole-artifact handoff: each stage receives
    the fully materialized list and maps over it."""
    def src():
        g = _source(n_chunks, chunk_sleep_s)()
        return list(g)

    def stage(k):
        f = _stage_fn(k, chunk_sleep_s)
        return lambda xs: [f(c) for c in xs]

    with couler.workflow("stream-whole") as ir:
        cur = couler.run_step(src, step_name="p", cacheable=False)
        for k in range(1, STAGES):
            cur = couler.run_step(stage(k), cur, step_name=f"m{k}",
                                  cacheable=False)
    return ir


def _stream_wf(n_chunks: int, chunk_sleep_s: float):
    with couler.workflow("stream-chunk") as ir:
        cur = couler.run_stream(_source(n_chunks, chunk_sleep_s),
                                step_name="p", cacheable=False)
        for k in range(1, STAGES):
            cur = couler.map_stream(_stage_fn(k, chunk_sleep_s), cur,
                                    step_name=f"m{k}", cacheable=False)
    return ir


def _run_one(ir) -> Dict:
    eng = LocalEngine(max_workers=STAGES + 2, enable_speculation=False,
                      max_inflight_steps=MAX_INFLIGHT_STEPS,
                      promote_interval_s=0.0)
    t0 = time.time()
    run = eng.submit(ir, optimize=False)
    wall = time.time() - t0
    assert run.succeeded(), run.status
    peak = eng.gateway.stats["peak_inflight_steps"]
    out = run.artifacts[f"m{STAGES - 1}:out"]
    eng.close()
    return {"wall_s": wall, "peak": peak, "out": out}


def run(n_chunks: int = 48, chunk_sleep_s: float = 0.008) -> List[Dict]:
    stage_time = n_chunks * chunk_sleep_s
    whole = _run_one(_whole_wf(n_chunks, chunk_sleep_s))
    streamed = _run_one(_stream_wf(n_chunks, chunk_sleep_s))
    assert streamed["out"] == whole["out"], "streamed output diverged"
    ratio = streamed["wall_s"] / stage_time
    return [{
        "stages": STAGES,
        "n_chunks": n_chunks,
        "chunk_sleep_ms": chunk_sleep_s * 1e3,
        "slowest_stage_s": round(stage_time, 3),
        "whole_wall_s": round(whole["wall_s"], 3),
        "streamed_wall_s": round(streamed["wall_s"], 3),
        "speedup": round(whole["wall_s"] / max(streamed["wall_s"], 1e-9), 2),
        "streamed_over_stage": round(ratio, 2),
        "meets_1p5x_bar": ratio <= 1.5,
        "artifacts_identical": True,
        "peak_inflight_steps": max(whole["peak"], streamed["peak"]),
        "bounded_inflight_ok": max(whole["peak"], streamed["peak"])
        <= MAX_INFLIGHT_STEPS,
    }]


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
