"""Consolidated roofline table from the dry-run JSONs (EXPERIMENTS.md feed)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

OUT = Path("out/dryrun")


SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load(mesh_tag: str = "pod16x16", strategy: str = "baseline") -> List[Dict]:
    rows = []
    for f in sorted(OUT.glob(f"{mesh_tag}/*/*.json")):
        stem_ok = (f.stem in SHAPES if strategy == "baseline"
                   else f.stem.endswith(f".{strategy}"))
        if not stem_ok:
            continue
        d = json.loads(f.read_text())
        if d.get("status") == "skip":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "status": "skip", "reason": d["reason"]})
            continue
        if d.get("status") != "ok":
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "status": d.get("status", "?")})
            continue
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "status": "ok",
            "compile_s": d["compile_s"],
            "mem_gib": round(d["memory_analysis"].get(
                "total_per_device_bytes", 0) / 2**30, 2),
            "compute_s": round(r["compute_s"], 4),
            "memory_s": round(r["memory_s"], 4),
            "collective_s": round(r["collective_s"], 4),
            "collective_s_bf16adj": round(r.get("collective_s_bf16adj",
                                                r["collective_s"]), 4),
            "dominant": r["dominant"],
            "useful": round(r["useful_flops_ratio"], 3),
            "roofline_frac": round(r["roofline_fraction"], 4),
        })
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | dom | compute_s | memory_s | collective_s "
           "(bf16adj) | mem/dev GiB | useful | roofline-frac |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        if r.get("status") == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | | |")
        elif r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['dominant'][:4]} | "
                f"{r['compute_s']} | {r['memory_s']} | {r['collective_s']} "
                f"({r['collective_s_bf16adj']}) | "
                f"{r['mem_gib']} | {r['useful']} | {r['roofline_frac']} |")
    return "\n".join(out)


def run() -> List[Dict]:
    rows = load()
    ok = [r for r in rows if r.get("status") == "ok"]
    skip = [r for r in rows if r.get("status") == "skip"]
    return [{"cells_ok": len(ok), "cells_skipped": len(skip),
             "dominant_collective": sum(r["dominant"] == "collective" for r in ok),
             "dominant_memory": sum(r["dominant"] == "memory" for r in ok),
             "dominant_compute": sum(r["dominant"] == "compute" for r in ok)}]


if __name__ == "__main__":
    print(markdown_table(load()))
