"""Fig. 8 analog: automatic hyperparameter configuration.

HP:Ours (Alg. 4: surrogate-predicted logs over the search space) vs
HP-baseline1 ("expert pick") and HP-baseline2 ("literature defaults"),
validated by ACTUALLY training the small JAX LM with each setting and
reporting measured final losses.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.autotune import (DataCard, ModelCard, default_search_space,
                                 train_real_model, tune)

HP_BASELINE1 = {"learning_rate": 1e-4, "batch_size": 64,
                "weight_decay": 0.0}          # conservative expert pick
HP_BASELINE2 = {"learning_rate": 3e-4, "batch_size": 32,
                "weight_decay": 0.1}          # literature defaults


def run(steps: int = 60) -> List[Dict]:
    dc = DataCard("synthetic-lm", n_examples=50_000, seq_len=32)
    mc = ModelCard("reduced-stablelm", n_params=600_000)
    ours = tune(dc, mc, llm=None).best
    rows = []
    for name, hp in (("HP:Ours", ours), ("HP-baseline1", HP_BASELINE1),
                     ("HP-baseline2", HP_BASELINE2)):
        out = train_real_model(hp, steps=steps)
        rows.append({"config": name, **{k: v for k, v in hp.items()},
                     "final_loss": round(out["final_loss"], 4),
                     "first_loss": round(out["losses"][0], 4)})
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
