"""Observability overhead: tracing must be free enough to leave on.

Two claims pinned here (see docs/observability.md):

* ``observability_overhead`` — the collector work added by observing the
  n=2000 event-driven admitted-batch submit path (the RQ1 population,
  handles attached so every run emits lifecycle events and gets a span
  tree) costs < 2% of that path's wall time. Measured directly: the
  batch runs once unobserved (best-of-reps submit wall), then a fresh
  ``ObsCollector`` ingests the recorded event streams — the identical
  code path attached mode runs — and the ingest wall is taken as a
  fraction of the submit wall. (A naive A/B of two full submits cannot
  resolve a sub-1% effect against multi-percent scheduler-wall noise.)
* ``registry_microbench`` — one ``Counter.inc`` through the thread-safe
  registry, measured against the racy ``dict[k] += 1`` it replaced; the
  ratio is reported so a regression in the per-update cost is visible
  even when the end-to-end pin still passes.
* ``telemetry_overhead`` — the continuous-telemetry fabric (per-step
  straggler notes for every step of the population, one SLO note per
  run, plus TimeSeriesDB sampling + detector/burn evaluation ticks)
  costs < 2% of the same submit wall. Same measurement shape: the
  telemetry calls replay against the recorded runs and their wall is
  taken as a fraction of the submit wall.
"""
from __future__ import annotations

import random
import time
from typing import Dict, List

from benchmarks.bench_throughput import _clusters, _small_wf
from repro.core.engines.cluster import MultiClusterEngine
from repro.core.gateway import AdmissionQueue, AdmittedItem
from repro.core.gateway.run import AsyncWorkflowRun
from repro.core.obs import MetricsRegistry, ObsCollector


def _submit_once(pop):
    eng = MultiClusterEngine(clusters=_clusters())
    q = AdmissionQueue(max_depth_per_tenant=1 << 20, max_total=1 << 20)
    items = [AdmittedItem(wf=wf, tenant=user, priority=prio,
                          handle=AsyncWorkflowRun(wf.name, tenant=user))
             for wf, user, prio in pop]
    for it in items:
        q.offer(it)
    t0 = time.perf_counter()
    runs = eng.submit_admitted(q)
    wall = time.perf_counter() - t0
    assert len(runs) == len(pop)
    return wall, items, runs, eng


def run(n_workflows: int = 2000, seed: int = 0, reps: int = 3) -> List[Dict]:
    rng = random.Random(seed)
    # unique names: submit_admitted keys results per batch by name
    pop = [(_small_wf(i, rng), f"user{i % 50}", rng.randint(0, 3))
           for i in range(n_workflows)]

    submit_wall, items, runs, eng = min(
        (_submit_once(pop) for _ in range(reps)), key=lambda r: r[0])

    ingest_wall, n_events = 1e9, 0
    for _ in range(reps + 2):      # ingest reps are cheap; stabler minimum
        c = ObsCollector(max_runs=n_workflows)
        streams = [(it, it.handle.events_so_far()) for it in items]
        n_events = sum(len(evs) for _, evs in streams)
        t0 = time.perf_counter()
        for it, evs in streams:
            c.ingest(evs, wf=it.wf, run_id=runs[it.wf.name].run_id,
                     tenant=it.tenant)
        ingest_wall = min(ingest_wall, time.perf_counter() - t0)
        assert len(c.trees()) == n_workflows
    overhead_pct = 100.0 * ingest_wall / submit_wall
    rows = [{
        "scenario": "observability_overhead",
        "n_workflows": n_workflows,
        "n_events": n_events,
        "submit_wall_s": round(submit_wall, 4),
        "ingest_wall_s": round(ingest_wall, 4),
        "overhead_pct": round(overhead_pct, 3),
        "overhead_under_2pct": overhead_pct < 2.0,
    }]

    n = 200_000
    reg = MetricsRegistry()
    c = reg.counter("bench_total")
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
    inc_ns = 1e9 * (time.perf_counter() - t0) / n
    d = {"k": 0}
    t0 = time.perf_counter()
    for _ in range(n):
        d["k"] += 1
    dict_ns = 1e9 * (time.perf_counter() - t0) / n
    rows.append({
        "scenario": "registry_microbench",
        "n_ops": n,
        "counter_inc_ns": round(inc_ns, 1),
        "dict_add_ns": round(dict_ns, 1),
        "inc_over_dict": round(inc_ns / dict_ns, 2),
    })

    # continuous-telemetry fabric replayed against the same population:
    # every step duration through the straggler detector, one SLO note
    # per run, and one full sampling + evaluation tick per 500 workflows
    # (matches the gateway's default 0.25s cadence at this batch's wall)
    from repro.core.obs.anomaly import AnomalyMonitor
    from repro.core.obs.slo import SLO, SLOMonitor
    from repro.core.obs.timeseries import TimeSeriesDB

    tenants = {it.wf.name: it.tenant for it in items}
    snapshot = eng.registry.snapshot()
    n_ticks = max(1, n_workflows // 500)
    # the run records are the data source, not the fabric: extract the
    # per-step durations outside the timed region (the live gateway gets
    # them for free off the StepRecord at each terminal publish)
    feed = [(name, tenants[name], r.status == "Succeeded", r.wall_time_s,
             [(sname, rec.duration()) for sname, rec in r.steps.items()])
            for name, r in runs.items()]
    n_steps = sum(len(steps) for *_x, steps in feed)
    tel_wall = 1e9
    for _ in range(reps + 2):
        mon = AnomalyMonitor(registry=MetricsRegistry())
        slo = SLOMonitor([SLO(tenant=f"user{u}") for u in range(50)])
        tsdb = TimeSeriesDB()
        note_step, note_run = mon.note_step_duration, slo.note_run
        t0 = time.perf_counter()
        for name, tenant, ok, wall_s, steps in feed:
            for sname, dur in steps:
                note_step(name, sname, dur, tenant=tenant)
            note_run(tenant, ok=ok, makespan_s=wall_s)
        for _t in range(n_ticks):
            tsdb.sample(snapshot)
            mon.evaluate(tsdb)
            slo.evaluate()
        tel_wall = min(tel_wall, time.perf_counter() - t0)
    tel_pct = 100.0 * tel_wall / submit_wall
    rows.append({
        "scenario": "telemetry_overhead",
        "n_workflows": n_workflows,
        "n_step_notes": n_steps,
        "n_sampling_ticks": n_ticks,
        "submit_wall_s": round(submit_wall, 4),
        "telemetry_wall_s": round(tel_wall, 4),
        "overhead_pct": round(tel_pct, 3),
        "overhead_under_2pct": tel_pct < 2.0,
    })
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
