"""NL -> unified interface -> execution (paper §III + App. C running example).

    PYTHONPATH=src python examples/nl_to_workflow.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.engines.local import LocalEngine
from repro.core.llm import TemplateLLM
from repro.core.nl2wf import decompose, nl_to_workflow

DESCRIPTION = (
    "I need to design a workflow to select the optimal image classification "
    "model. Load the dataset named imagenet-mini, preprocess it, train the "
    "ResNet, ViT and DenseNet models respectively, evaluate accuracy on the "
    "validation data, then select the best model and generate a report.")


def main():
    print("NL description:\n ", DESCRIPTION, "\n")
    print("Step 1 — modular decomposition (chain of thought):")
    for st in decompose(DESCRIPTION):
        print(f"   [{st.kind:12s}] {st.text}")

    res = nl_to_workflow(DESCRIPTION, llm=TemplateLLM("gpt-4"),
                         temperature=0.0, seed=0)
    print("\nSteps 2-3 — generated COULER code (self-calibration scores "
          f"{['%.2f' % s for s in res.scores]}):\n")
    print(res.code)

    if res.error:
        print("generation error:", res.error)
        return
    run = LocalEngine().submit(res.workflow)
    print("execution:", run.status, run.counts())
    print("selected best:", run.artifacts.get("select-best:out"))
    print("LLM tokens used:", res.tokens_used)


if __name__ == "__main__":
    main()
