"""AutoML workflow (paper App. F + §IV.C): LLM hyperparameter tuning
(Data Card + Model Card -> predicted logs -> pick), then REAL concurrent
training of the chosen config vs a baseline, model selection via couler.

    PYTHONPATH=src python examples/automl_pipeline.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import couler
from repro.core.autotune import (DataCard, ModelCard, train_real_model, tune)
from repro.core.engines.local import LocalEngine


def main():
    dc = DataCard("synthetic-lm", input_type="text", label_space="tokens",
                  eval_metric="loss", n_examples=50_000, seq_len=32)
    mc = ModelCard("tiny-lm", structure="decoder-transformer",
                   n_params=600_000)
    print("Algorithm 4: predicting training logs over the search space ...")
    ours = tune(dc, mc).best
    baseline = {"learning_rate": 1e-4, "batch_size": 64, "weight_decay": 0.0}
    print("  HP:Ours      =", ours)
    print("  HP-baseline1 =", baseline)

    with couler.workflow("automl") as ir:
        outs = couler.concurrent([
            lambda: couler.run_step(train_real_model, ours, step_name="train-ours",
                                    est_time_s=30),
            lambda: couler.run_step(train_real_model, baseline,
                                    step_name="train-baseline", est_time_s=30),
        ])
        best = couler.run_step(
            lambda a, b: {"winner": "ours" if a["final_loss"] < b["final_loss"]
                          else "baseline",
                          "ours": a["final_loss"], "baseline": b["final_loss"]},
            outs[0], outs[1], step_name="select")
    run = LocalEngine(max_workers=2, enable_speculation=False).submit(ir)
    print("workflow:", run.status)
    print("result:", run.artifacts["select:out"])


if __name__ == "__main__":
    main()
