"""End-to-end driver: train a small LM for a few hundred steps THROUGH the
COULER workflow engine — data prep / shard caching / training / eval /
checkpointing are workflow steps, with automatic artifact caching and
restart-from-failure (the paper's production loop on the JAX substrate).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch stablelm-1.6b]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core import couler
from repro.core.caching import CacheStore, CoulerPolicy
from repro.core.engines.local import LocalEngine
from repro.data.pipeline import CachedShardReader, ShardedCorpus
from repro.training import train as TR
from repro.training.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default="out/train_lm")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = reduced(spec.model).replace(
        d_model=args.d_model, num_layers=4,
        param_dtype="float32", compute_dtype="float32")
    tcfg = spec.train.__class__(optimizer="adamw", learning_rate=1e-3,
                                remat="none")
    cache = CacheStore(capacity_bytes=1 << 28, policy=CoulerPolicy())
    ckpt = CheckpointManager(f"{args.out}/ckpt", cache=cache)

    # ---------------- workflow steps ----------------
    def prepare_corpus():
        corpus = ShardedCorpus(f"{args.out}/shards", n_shards=8,
                               tokens_per_shard=args.batch * (args.seq + 1) * 8,
                               vocab=cfg.vocab_size, read_delay_s=0.002)
        corpus.materialize()
        return corpus

    def train(corpus, steps):
        reader = CachedShardReader(corpus, cache=cache)
        state = TR.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        start = ckpt.latest_step()
        if start is not None:                         # restart-from-failure
            state = jax.tree.map(jnp.asarray,
                                 ckpt.restore(like=jax.tree.map(
                                     lambda x: x, state)))
            print(f"  resumed from checkpoint step {start}")
        step_fn = jax.jit(TR.make_train_step(cfg, tcfg))
        losses = []
        t0 = time.time()
        it = iter(reader.batches(args.batch, args.seq, epochs=1000))
        while int(state["step"]) < steps:
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
            s = int(state["step"])
            if s % 50 == 0:
                ckpt.async_save(s, state)
                print(f"  step {s:4d} loss {losses[-1]:.4f} "
                      f"({s / (time.time() - t0):.1f} steps/s, "
                      f"shard-cache hit {reader.cache.hit_ratio():.0%})")
        ckpt.wait()
        ckpt.save(int(state["step"]), state)
        return {"losses": losses, "first": losses[0], "last": losses[-1]}

    def evaluate(result):
        improved = result["last"] < result["first"]
        print(f"  eval: first loss {result['first']:.4f} -> "
              f"last {result['last']:.4f} improved={improved}")
        return improved

    with couler.workflow("train-lm") as ir:
        corpus = couler.run_step(prepare_corpus, step_name="prepare-corpus",
                                 est_time_s=0.5)
        result = couler.run_step(train, corpus, args.steps,
                                 step_name="train", cacheable=False,
                                 est_time_s=60.0)
        couler.run_step(evaluate, result, step_name="evaluate")

    eng = LocalEngine(cache=cache, enable_speculation=False)
    run = eng.submit(ir)
    print("workflow:", run.status, run.counts())
    assert run.succeeded() and run.artifacts["evaluate:out"] is True


if __name__ == "__main__":
    main()
