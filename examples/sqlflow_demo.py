"""SQLFlow frontend (paper §V.E): SQL statements -> COULER workflows.

    PYTHONPATH=src python examples/sqlflow_demo.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.sqlflow import run_sql, to_workflow

TRAIN = """
SELECT * FROM iris.train
TO TRAIN DNNClassifier
WITH model.n_classes = 3, model.hidden_units = [10]
COLUMN sepal_len, sepal_width, petal_length, petal_width
LABEL class
INTO sqlflow_models.my_dnn_model;
"""

PREDICT = """
SELECT * FROM iris.test
TO PREDICT iris.predict.class
USING sqlflow_models.my_dnn_model;
"""


def main():
    ir = to_workflow(TRAIN)
    print("TRAIN statement lowers to DAG:", " -> ".join(ir.topo_order()))
    r1 = run_sql(TRAIN)
    model = r1.artifacts["save-model:out"]
    print("trained + saved:", model["saved_as"],
          "weights", model["weights"].shape)

    r2 = run_sql(PREDICT, model_registry={model["saved_as"]: model})
    preds = r2.artifacts["predict:out"]["preds"]
    print(f"PREDICT -> {len(preds)} predictions, first 10: {preds[:10]}")


if __name__ == "__main__":
    main()
