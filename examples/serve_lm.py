"""Serve a small LM with batched requests: prefill via sequential cache
fill + batched decode steps (the serve_step that the decode_32k /
long_500k dry-run cells lower at production scale).

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-370m]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = reduced(spec.model).replace(param_dtype="float32",
                                      compute_dtype="float32")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen_len
    caches = T.init_caches(cfg, args.batch, max_len, jnp.float32)

    step = jax.jit(lambda p, t, c, i: T.apply_lm_decode(p, cfg, t, c, i))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)

    # prefill: feed prompt tokens through the decode path to fill caches
    t0 = time.time()
    for i in range(args.prompt_len):
        logits, caches = step(params, prompts[:, i:i + 1], caches,
                              jnp.int32(i))
    prefill_s = time.time() - t0

    # batched greedy decode
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.prompt_len, max_len - 1):
        logits, caches = step(params, tok, caches, jnp.int32(i))
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        out.append(tok)
    decode_s = time.time() - t0
    gen = jnp.concatenate(out, axis=1)

    tps = args.batch * gen.shape[1] / decode_s
    print(f"arch={args.arch} family={cfg.family}")
    print(f"prefill: {args.prompt_len} toks x {args.batch} reqs "
          f"in {prefill_s:.2f}s")
    print(f"decode:  {gen.shape[1]} toks x {args.batch} reqs "
          f"in {decode_s:.2f}s ({tps:.1f} tok/s)")
    print("sample token ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
