"""Quickstart: the paper's diamond DAG + control flow on the local engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import couler
from repro.core.engines.argo import to_argo_yaml
from repro.core.engines.local import LocalEngine


def main():
    # --- explicit DAG (paper Code 1) -----------------------------------
    with couler.workflow("diamond") as ir:
        def job(name):
            return couler.run_container(
                image="docker/whalesay:latest", command=["cowsay"],
                args=[name], step_name=name,
                fn=lambda n=name: f"[{n}]")
        couler.dag([
            [lambda: job("A")],
            [lambda: job("A"), lambda: job("B")],   # A -> B
            [lambda: job("A"), lambda: job("C")],   # A -> C
            [lambda: job("B"), lambda: job("D")],   # B -> D
            [lambda: job("C"), lambda: job("D")],   # C -> D
        ])
    run = LocalEngine().submit(ir)
    print("diamond:", run.status, run.counts())

    # --- control flow: coin flip (paper Code 3/5) ----------------------
    state = {"flips": 0}

    def flip_coin():
        state["flips"] += 1
        return "heads" if state["flips"] >= 3 else "tails"

    with couler.workflow("coinflip") as ir2:
        r = couler.run_step(flip_coin, step_name="flip")
        couler.exec_while(couler.equal(r, "tails"), lambda: r)
        couler.when(couler.equal(r, "heads"),
                    lambda: couler.run_step(lambda: "it was heads",
                                            step_name="announce"))
    run2 = LocalEngine().submit(ir2)
    print("coinflip:", run2.artifacts.get("announce:out"),
          f"(after {state['flips']} flips)")

    # --- same IR, different engine: Argo YAML --------------------------
    yaml = to_argo_yaml(ir)
    print("\n--- argo manifest (first 12 lines) ---")
    print("\n".join(yaml.splitlines()[:12]))


if __name__ == "__main__":
    main()
