from repro.data.pipeline import (CachedShardReader, ShardedCorpus,
                                 synthetic_batches)
