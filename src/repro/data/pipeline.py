"""Data pipeline: deterministic synthetic corpus + sharded file reader with
artifact-cache integration (paper App. D.C: table/file caching).

The synthetic corpus is a noisy affine token chain — learnable structure so
example/benchmark training losses genuinely decrease. ``ShardedCorpus``
materializes shards on disk (the "remote storage" stand-in); the
``CachedShardReader`` reads them through a ``CacheStore``, so repeated
epochs / multiple consumers hit the cache exactly like the paper's 70-85%
repeated-read workloads.
"""
from __future__ import annotations

import threading
import time
from pathlib import Path
from queue import Queue
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.caching import CacheStore


def _chain(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    a, c = 31, 17
    x = np.empty(n, dtype=np.int32)
    x[0] = rng.integers(0, vocab)
    noise = rng.random(n)
    rand = rng.integers(0, vocab, n)
    for i in range(1, n):
        x[i] = (a * x[i - 1] + c) % vocab if noise[i] > 0.15 else rand[i]
    return x


def synthetic_batches(batch: int, seq: int, vocab: int, seed: int = 0,
                      n: int = 100) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    for _ in range(n):
        toks = _chain(rng, batch * (seq + 1), vocab).reshape(batch, seq + 1)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class ShardedCorpus:
    """Deterministic on-disk shard files (the 'remote' store)."""

    def __init__(self, root: str, n_shards: int = 8, tokens_per_shard: int = 65536,
                 vocab: int = 512, seed: int = 0, read_delay_s: float = 0.0):
        self.root = Path(root)
        self.n_shards = n_shards
        self.tokens_per_shard = tokens_per_shard
        self.vocab = vocab
        self.seed = seed
        self.read_delay_s = read_delay_s   # emulated remote-storage latency
        self.root.mkdir(parents=True, exist_ok=True)

    def shard_path(self, i: int) -> Path:
        return self.root / f"shard-{i:05d}.npy"

    def materialize(self) -> List[Path]:
        out = []
        for i in range(self.n_shards):
            p = self.shard_path(i)
            if not p.exists():
                rng = np.random.default_rng(self.seed * 1000 + i)
                np.save(p, _chain(rng, self.tokens_per_shard, self.vocab))
            out.append(p)
        return out

    def read_shard(self, i: int) -> np.ndarray:
        if self.read_delay_s:
            time.sleep(self.read_delay_s)      # remote round-trip
        return np.load(self.shard_path(i))


class CachedShardReader:
    """Reads shards through the artifact cache + background prefetch."""

    def __init__(self, corpus: ShardedCorpus, cache: Optional[CacheStore] = None,
                 prefetch: int = 2):
        self.corpus = corpus
        self.cache = cache or CacheStore(capacity_bytes=1 << 28)
        self.prefetch = prefetch
        self.read_times: List[float] = []

    def _key(self, i: int) -> str:
        return f"shard:{self.corpus.root.name}:{i}"

    def read(self, i: int) -> np.ndarray:
        t0 = time.time()
        hit = self.cache.get(self._key(i))
        if hit is not None:
            self.read_times.append(time.time() - t0)
            return hit.value
        arr = self.corpus.read_shard(i)
        dur = time.time() - t0
        self.read_times.append(dur)
        self.cache.offer(self._key(i), arr, compute_time_s=dur,
                         producer=f"shard-{i}")
        return arr

    def epoch(self, order: Optional[List[int]] = None) -> Iterator[np.ndarray]:
        order = order if order is not None else list(range(self.corpus.n_shards))
        q: Queue = Queue(maxsize=max(1, self.prefetch))
        done = object()

        def worker():
            for i in order:
                q.put(self.read(i))
            q.put(done)

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is done:
                break
            yield item

    def batches(self, batch: int, seq: int, epochs: int = 1
                ) -> Iterator[Dict[str, np.ndarray]]:
        need = batch * (seq + 1)
        for _ in range(epochs):
            buf = np.empty(0, dtype=np.int32)
            for arr in self.epoch():
                buf = np.concatenate([buf, arr])
                while len(buf) >= need:
                    chunk, buf = buf[:need], buf[need:]
                    toks = chunk.reshape(batch, seq + 1)
                    yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
