"""Deterministic fault injection — the chaos half of App. B.B's
reliability story.

``FaultPlan`` is a pure, seedable description of WHAT may fail: per-site
probabilities for transient step crashes, permanent crashes, worker loss
(the pool slot running a step dies mid-execution), and simulated cluster
preemption (``MultiClusterEngine``: a cluster goes dark, its in-flight
jobs are evicted, capacity returns after ``preemption_dark_s``).

``ChaosInjector`` is the runtime the engines consult:

* ``LocalEngine`` calls ``begin_attempt(workflow, step)`` at the start of
  every execution attempt (step boundary). The returned fault, if any, is
  raised either before the fn runs (crashes) or after it ran with the
  result discarded (worker loss — the work happened, the slot carrying
  the result died).
* Checkpoint-wired steps (``couler.add_job(..., checkpoint=...)``) get
  their worker-loss faults delivered MID-STEP instead: ``begin_attempt``
  also returns a kill iteration, and the ``StepCheckpointSession`` raises
  at that tick — exercising resume-from-latest-checkpoint rather than
  restart-from-step-start.
* ``MultiClusterEngine`` draws per-cluster preemption times from
  ``random.Random(f"{seed}:{cluster}")`` inside its event-driven
  simulator.

Decisions derive from ``sha256(seed | site | consult-index)``, so a
replay with the same plan injects the identical fault sequence regardless
of wall-clock timing or thread interleaving: a step's attempts are
sequential, which makes the per-site consult counter deterministic. The
counter never resets — not on retry, not on workflow re-admission — so
``max_failures_per_site`` is a hard cap guaranteeing convergence: after
that many injected faults a site runs clean forever.
"""
from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Optional, Tuple

from repro.core.engines.base import TransientError
from repro.core.obs.metrics import MetricsRegistry, StatsView


class InjectedFault(Exception):
    """Marker mixin: distinguishes injected faults from organic errors."""


class InjectedCrash(InjectedFault, TransientError):
    """Transient step crash (matches the controller's retryable set)."""


class WorkerLost(InjectedFault, TransientError):
    """The pool slot executing a step died; any un-persisted result is
    gone. Transient — the controller retries the step."""


class InjectedPermanentCrash(InjectedFault, RuntimeError):
    """Non-transient crash: the retry loop must NOT absorb it (the step
    fails, and recovery — if any — happens at re-admission scope)."""


@dataclass(frozen=True)
class FaultPlan:
    """Seeded description of the faults to inject. All rates are
    per-attempt probabilities in [0, 1]; they partition one uniform draw
    (crash, then permanent, then worker loss), so their sum must be <= 1.
    """

    seed: int = 0
    crash_rate: float = 0.0            # transient InjectedCrash
    permanent_rate: float = 0.0        # InjectedPermanentCrash
    worker_loss_rate: float = 0.0      # WorkerLost (mid-step for ckpt steps)
    # checkpoint-wired steps: the kill iteration is drawn uniformly from
    # [0, mid_step_kill_window)
    mid_step_kill_window: int = 8
    # MultiClusterEngine: per-cluster Poisson preemption process
    preemption_rate_per_s: float = 0.0
    preemption_dark_s: float = 5.0
    # hard per-(workflow, step) injection cap — guarantees convergence
    max_failures_per_site: int = 3
    # restrict injection to these sites — entries match a bare step name
    # or a qualified "workflow/step" (None = every step)
    targets: Optional[FrozenSet[str]] = None
    # straggler injection (telemetry/anomaly exercises): per-attempt
    # probability of delaying a step by straggler_delay_s before it runs.
    # Drawn from a separate consult sequence ("straggler" coords), so
    # enabling it never perturbs the crash/loss fault replay above.
    straggler_rate: float = 0.0
    straggler_delay_s: float = 0.25

    def __post_init__(self):
        total = self.crash_rate + self.permanent_rate + self.worker_loss_rate
        if total > 1.0 + 1e-9:
            raise ValueError(f"fault rates sum to {total} > 1")

    def _u(self, *coords: str) -> float:
        """Deterministic uniform in [0, 1) keyed on (seed, *coords)."""
        h = hashlib.sha256(
            "|".join((str(self.seed),) + coords).encode()).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64


class ChaosInjector:
    """Runtime consulted by the engines; thread-safe, deterministic.

    One ``begin_attempt`` call per execution attempt per site. The
    per-site consult counter is monotonic across retries AND workflow
    re-admissions (it lives here, not in the ``StepRecord`` the gateway
    resets), so the injected sequence replays identically and the
    ``max_failures_per_site`` cap always converges.
    """

    def __init__(self, plan: FaultPlan,
                 registry: Optional[MetricsRegistry] = None):
        self.plan = plan
        self._lock = threading.Lock()
        self._consults: Dict[Tuple[str, str], int] = {}
        self._injected: Dict[Tuple[str, str], int] = {}
        self._straggler_consults: Dict[Tuple[str, str], int] = {}
        self._m_straggler = None
        self.registry = registry if registry is not None \
            else MetricsRegistry("chaos")
        self._m = {
            "consults": self.registry.counter("chaos_consults_total"),
            "crash": self.registry.counter("chaos_injected_total",
                                           kind="crash"),
            "crash_permanent": self.registry.counter(
                "chaos_injected_total", kind="crash_permanent"),
            "worker_lost": self.registry.counter("chaos_injected_total",
                                                 kind="worker_lost"),
            "mid_step_kill": self.registry.counter(
                "chaos_mid_step_kills_total"),
        }

    @property
    def stats(self) -> StatsView:
        return StatsView(self._m)

    def begin_attempt(self, workflow: str, step: str,
                      checkpointed: bool = False
                      ) -> Tuple[Optional[BaseException], Optional[int]]:
        """Consult the plan for one execution attempt of (workflow, step).

        Returns ``(fault, kill_iteration)``: both None for a clean
        attempt; ``(exc, None)`` to fail at the step boundary;
        ``(WorkerLost, k)`` (checkpointed steps only) to kill the slot at
        iteration ``k`` of the step body — the engine wires ``k`` into the
        ``StepCheckpointSession`` tick.
        """
        plan = self.plan
        site = (workflow, step)
        with self._lock:
            k = self._consults.get(site, 0)
            self._consults[site] = k + 1
            self._m["consults"].inc()
            if plan.targets is not None and step not in plan.targets \
                    and f"{workflow}/{step}" not in plan.targets:
                return None, None
            if self._injected.get(site, 0) >= plan.max_failures_per_site:
                return None, None
            u = plan._u("step", workflow, step, str(k))
            lo = plan.crash_rate
            if u < lo:
                kind = "crash"
            elif u < (lo := lo + plan.permanent_rate):
                kind = "crash_permanent"
            elif u < lo + plan.worker_loss_rate:
                kind = "worker_lost"
            else:
                return None, None
            self._injected[site] = self._injected.get(site, 0) + 1
            self._m[kind].inc()
            tag = f"{workflow}/{step} consult {k}"
            if kind == "crash":
                return InjectedCrash(f"injected transient crash: {tag}"), None
            if kind == "crash_permanent":
                return InjectedPermanentCrash(
                    f"injected permanent crash: {tag}"), None
            exc = WorkerLost(f"injected worker loss: {tag}")
            if checkpointed:
                self._m["mid_step_kill"].inc()
                at = int(plan._u("kill-iter", workflow, step, str(k))
                         * max(1, plan.mid_step_kill_window))
                return exc, at
            return exc, None

    def straggler_delay(self, workflow: str, step: str) -> float:
        """Consult the plan's straggler process for one attempt: returns
        the delay to sleep before executing (0.0 for a clean attempt).
        Separate consult counter and coord prefix from ``begin_attempt``,
        so the crash/loss draw sequence is unchanged by straggler use."""
        plan = self.plan
        if plan.straggler_rate <= 0.0:
            return 0.0
        site = (workflow, step)
        with self._lock:
            k = self._straggler_consults.get(site, 0)
            self._straggler_consults[site] = k + 1
            if plan.targets is not None and step not in plan.targets \
                    and f"{workflow}/{step}" not in plan.targets:
                return 0.0
            if plan._u("straggler", workflow, step, str(k)) \
                    >= plan.straggler_rate:
                return 0.0
            # lazy: the series only exists once a straggler actually fires
            # (keeps pre-existing snapshot shapes stable when unused)
            if self._m_straggler is None:
                self._m_straggler = self.registry.counter(
                    "chaos_injected_total", kind="straggler")
            self._m_straggler.inc()
            return plan.straggler_delay_s

    def injected_at(self, workflow: str, step: str) -> int:
        with self._lock:
            return self._injected.get((workflow, step), 0)
