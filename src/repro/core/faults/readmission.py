"""Straggler-aware re-admission policy.

A workflow that fails (step retry budget exhausted, cluster preempted)
does not have to stay failed: the gateway re-enters it into the
``AdmissionQueue`` — resetting failed steps, keeping the satisfied
frontier — after a capped-exponential, jittered backoff. Priority AGES
with each re-admission, so a repeatedly-unlucky tenant climbs the
weighted queue instead of starving behind fresh arrivals, while the
jittered backoff keeps a burst of simultaneous failures from stampeding
the queue in lockstep.

``max_readmissions`` bounds the loop: a workflow still failing after
that many round trips stays ``Failed`` (something is wrong with it, not
with the cluster).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Optional

from repro.core.faults.retry import capped_jittered_delay


@dataclass(frozen=True)
class ReadmissionPolicy:
    base_backoff_s: float = 0.05
    max_backoff_s: float = 2.0
    max_readmissions: int = 8
    # priority increment per re-admission (aging: retried runs climb)
    aging_priority_step: int = 1
    jitter: bool = True

    def should_readmit(self, readmit_count: int) -> bool:
        """True when a run that has already been re-admitted
        ``readmit_count`` times gets another round trip."""
        return readmit_count < self.max_readmissions

    def delay_s(self, readmit_count: int,
                rng: Optional[random.Random] = None) -> float:
        return capped_jittered_delay(readmit_count, self.base_backoff_s,
                                     self.max_backoff_s, rng=rng,
                                     jitter=self.jitter)

    def aged_priority(self, priority: int) -> int:
        return priority + self.aging_priority_step
