"""Transient-retry policy: capped exponential backoff with decorrelated
jitter, plus the one shared retry decision both of ``LocalEngine``'s
execution paths use.

The un-capped ``retry_backoff_s * 2**(attempt-1)`` the engine used to
compute inline grows without bound (attempt 20 of a 20ms base is over an
hour) and, jitterless, synchronizes every step that failed on the same
transient cause into a retry stampede. ``RetryPolicy`` fixes both: the
delay is clamped to ``cap_s`` and drawn from ``uniform(base, 3*delay)``
(decorrelated jitter), so colliding retriers spread out.

``retry_after_transient`` consolidates the duplicated retry logic from
the streaming path and ``_invoke_with_retry``: classify the error, emit
the ``WORKER_LOST`` / ``STEP_RETRY`` events, sleep the backoff, and tell
the caller whether to loop again. Retries are thereby visible in the
event stream (TraceChecker invariant 7) instead of silently absorbed.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.engines.base import is_transient
from repro.core.faults.plan import WorkerLost
from repro.core.gateway.events import EventType

# jitter draws need no replay guarantee (fault *decisions* are the
# deterministic part — see faults.plan); one shared source is fine
_jitter_rng = random.Random(0x5EED)


def capped_jittered_delay(attempt: int, base_s: float, cap_s: float,
                          rng: Optional[random.Random] = None,
                          jitter: bool = True) -> float:
    """Backoff before retry ``attempt`` (1-based): exponential in the
    attempt number, clamped to ``cap_s``, decorrelated-jittered."""
    d = min(cap_s, base_s * (2 ** max(0, attempt - 1)))
    if jitter and d > 0:
        d = min(cap_s, (rng or _jitter_rng).uniform(base_s, 3.0 * d))
    return max(0.0, d)


@dataclass(frozen=True)
class RetryPolicy:
    base_s: float = 0.02
    cap_s: float = 2.0
    jitter: bool = True

    def delay_s(self, attempt: int,
                rng: Optional[random.Random] = None) -> float:
        return capped_jittered_delay(attempt, self.base_s, self.cap_s,
                                     rng=rng, jitter=self.jitter)


def retry_after_transient(exc: BaseException, *, attempt: int,
                          retry_limit: int, policy: RetryPolicy,
                          step: str = "",
                          publish: Optional[Callable] = None,
                          rng: Optional[random.Random] = None,
                          sleep: Callable[[float], None] = time.sleep
                          ) -> bool:
    """One retry decision after ``exc`` on attempt ``attempt`` (1-based).

    Returns True when the caller should retry — after publishing
    ``WORKER_LOST`` (worker-loss faults) and ``STEP_RETRY`` (carrying the
    UPCOMING attempt number, so per-step attempts strictly increase) and
    sleeping the backoff. Returns False for non-transient errors or an
    exhausted budget; the caller marks the step Failed and re-raises.
    """
    if not is_transient(exc) or attempt > retry_limit:
        return False
    if publish is not None:
        err = f"{type(exc).__name__}: {exc}"
        if isinstance(exc, WorkerLost):
            publish(EventType.WORKER_LOST, step=step, attempt=attempt,
                    error=err)
        publish(EventType.STEP_RETRY, step=step, attempt=attempt + 1,
                error=err)
    sleep(policy.delay_s(attempt, rng))
    return True
