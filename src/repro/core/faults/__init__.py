"""Fault tolerance: deterministic chaos injection, frontier
checkpoint-resume, and straggler-aware re-admission.

See ``docs/fault_tolerance.md`` for the fault model, the event additions
(``STEP_RETRY`` / ``WORKER_LOST`` / ``CLUSTER_PREEMPTED`` /
``WORKFLOW_REQUEUED``), resume semantics, and every knob.
"""
from repro.core.faults.frontier import (FRONTIER_PRODUCER, FrontierStore,
                                        load_run_snapshot, restore_frontier,
                                        run_snapshot)
from repro.core.faults.plan import (ChaosInjector, FaultPlan, InjectedCrash,
                                    InjectedFault, InjectedPermanentCrash,
                                    WorkerLost)
from repro.core.faults.readmission import ReadmissionPolicy
from repro.core.faults.retry import (RetryPolicy, capped_jittered_delay,
                                     retry_after_transient)

__all__ = ["FaultPlan", "ChaosInjector", "InjectedFault", "InjectedCrash",
           "InjectedPermanentCrash", "WorkerLost", "RetryPolicy",
           "capped_jittered_delay", "retry_after_transient",
           "ReadmissionPolicy", "FrontierStore", "restore_frontier",
           "run_snapshot", "load_run_snapshot", "FRONTIER_PRODUCER"]
