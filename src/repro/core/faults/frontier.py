"""Frontier checkpoint-resume: persist step-level completion through the
artifact cache so a crashed (or entirely restarted) run resumes from the
last completed frontier instead of re-running from scratch.

Two persistence channels, both tiny JSON snapshots of per-step
``(status, attempts, cache_key)``:

* ``FrontierStore.record(run)`` offers the snapshot to the (tiered)
  artifact cache under ``frontier:{workflow}`` after every step terminal
  event — the gateway drives this when the engine has a frontier store
  attached. Because the cache may be shared (``SharedRemoteTier``), a
  FRESH engine/gateway instance attached to the same store can pick the
  snapshot up.
* ``WorkflowRun.persist`` (the App. B.B metadata database) now includes
  each step's ``cache_key``; ``load_run_snapshot`` reads one of those
  JSON files back into the same snapshot shape.

``restore_frontier`` turns a snapshot back into a live ``WorkflowRun``:
steps recorded done are kept only if their outputs are still
reconstructable — the stored cache key must hit (for streaming steps:
the ``{key}#n`` manifest plus every chunk) — otherwise they quietly
degrade to ``Pending`` and re-run. Restored steps are marked ``Cached``
(their artifacts came from the store), so the normal resume path treats
them as satisfied.

Frontier snapshots are offered with ``producer="__frontier__"`` — a name
outside every workflow DAG, which the Eq. 3/4 scorer treats by its
recency fallback. They are a few hundred bytes; keeping them hot is
exactly what fault tolerance wants.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.core.engines.base import StepRecord, StepStatus, WorkflowRun
from repro.core.ir import WorkflowIR

FRONTIER_PRODUCER = "__frontier__"


def run_snapshot(run: WorkflowRun) -> Dict[str, Any]:
    """The persisted frontier shape (a subset of ``persist``'s schema)."""
    return {
        "workflow": run.workflow.name,
        "run_id": run.run_id,
        "status": run.status,
        "steps": {k: {"status": r.status.value, "attempts": r.attempts,
                      "cache_key": r.cache_key}
                  for k, r in run.steps.items()},
    }


def load_run_snapshot(path) -> Dict[str, Any]:
    """Load a ``WorkflowRun.persist`` JSON file as a frontier snapshot."""
    return json.loads(Path(path).read_text())


class FrontierStore:
    """Records/loads frontier snapshots through an artifact cache."""

    PREFIX = "frontier:"

    def __init__(self, cache):
        self.cache = cache

    def key(self, workflow_name: str) -> str:
        return f"{self.PREFIX}{workflow_name}"

    def record(self, run: WorkflowRun) -> None:
        blob = json.dumps(run_snapshot(run))
        self.cache.offer(self.key(run.workflow.name), blob,
                         compute_time_s=0.0, producer=FRONTIER_PRODUCER,
                         nbytes=len(blob))

    def load(self, wf: WorkflowIR) -> Optional[Dict[str, Any]]:
        hit = self.cache.get(self.key(wf.name))
        return json.loads(hit.value) if hit is not None else None


def _restore_chunks(cache, key: str) -> Optional[List[Any]]:
    """Rebuild a streaming step's full chunk list from the chunk-granular
    cache; None unless the manifest AND every chunk hit (a partial prefix
    is not a finished step — the step re-runs and replays the prefix
    itself)."""
    m = cache.get(f"{key}#n")
    if m is None:
        return None
    chunks: List[Any] = []
    for i in range(int(m.value)):
        hit = cache.get(f"{key}#c{i}")
        if hit is None:
            return None
        chunks.append(hit.value)
    return chunks


def restore_frontier(wf: WorkflowIR, snapshot: Optional[Dict[str, Any]],
                     cache) -> WorkflowRun:
    """Reconstruct a resumable ``WorkflowRun`` for ``wf`` from a frontier
    snapshot + cache hits. Walks topo order; a recorded-done step whose
    stored cache key still hits becomes ``Cached`` with its artifacts
    restored, anything else (missed, evicted, non-cacheable, previously
    failed) starts over as ``Pending``. ``Skipped`` steps stay skipped —
    their condition held in the recorded run."""
    run = WorkflowRun(workflow=wf)
    for n in wf.jobs:
        run.steps[n] = StepRecord()
    if not snapshot:
        return run
    steps = snapshot.get("steps", {})
    for n in wf.topo_order():
        info = steps.get(n)
        if info is None:
            continue
        status = info.get("status", "")
        if status == StepStatus.SKIPPED.value:
            run.steps[n].status = StepStatus.SKIPPED
            continue
        if status not in (StepStatus.SUCCEEDED.value,
                          StepStatus.CACHED.value):
            continue
        job = wf.jobs[n]
        key = info.get("cache_key") or ""
        if not key or not job.cacheable:
            continue                       # unreconstructable: re-run
        if job.stream_output or job.stream_input:
            value = _restore_chunks(cache, key)
            if value is None:
                continue
        else:
            hit = cache.get(key)
            if hit is None:
                continue
            value = hit.value
        for out in job.outputs:
            run.artifacts[out] = value
        rec = run.steps[n]
        rec.status = StepStatus.CACHED
        rec.cache_key = key
        rec.attempts = int(info.get("attempts", 0))
    return run
