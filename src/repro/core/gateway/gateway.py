"""``WorkflowGateway`` — asyncio submission layer over a ``LocalEngine``.

One gateway owns one event loop (a daemon thread), one shared step worker
pool, one admission queue, and (for multi-tier caches) one background
promotion task. Every in-flight workflow of the engine is multiplexed onto
these shared resources:

* the **pump** coroutine drains the admission queue in weighted
  round-robin tenant order and spawns one lightweight task per workflow
  (no per-run threads);
* each workflow task replays the engine's push-based completion
  scheduling as coroutines: ready steps become asyncio tasks that execute
  ``LocalEngine._exec_step`` on the shared pool, and each completion
  decrements successor indegrees exactly as the sync scheduler did;
* a global ``max_inflight_steps`` semaphore bounds how many steps of ALL
  workflows may execute at once (backpressure below the admission gate);
* ``promote_interval_s`` drives ``TieredCacheStore.promote()`` from a
  real background task (the store's ``auto_promote_every`` hit-count
  trigger remains as a fallback for engines without a gateway);
* ``stop()`` cancels the background tasks, drains the loop, and joins the
  thread — ``LocalEngine.close()`` calls it on engine shutdown.

The sync facade (``LocalEngine.submit``) funnels through this same path
(``submit_nowait(block=True)`` + ``handle.result()``), so sync and async
submissions produce identical ``WorkflowRun`` results.

Streaming (``couler.run_stream`` / ``couler.map_stream``): for each
streamed artifact consumed chunk-wise inside a part, ``_run_part`` builds
an ``ArtifactChannel`` (bounded buffer + backpressure; see
``gateway.channels``) and starts the consumer as soon as the producer
emits its first chunk — the consumer's indegree contribution from that
producer is credited early, while every other dependency still gates it
normally. The in-flight-steps semaphore applies unchanged, so
``max_inflight_steps`` must be at least the streaming pipeline depth or
the stages cannot coexist (the channel's stall timeout turns that
misconfiguration into a failed run rather than a hang). A run cancelled
mid-stream interrupts blocked producers/consumers via the channel; the
interrupted steps are reverted to ``Pending`` so the run stays
resumable, replaying any chunk prefix already cached.

Speculative straggler backups reserve a slot from the same semaphore via
``try_reserve_step_slot`` (non-blocking; no spare slot means no backup),
so ``peak_inflight_steps`` honours the bound with speculation included.

Caveat: ``submit()`` called *from inside a step function* of the same
engine occupies a pool worker while it waits; deeply nested blocking
submissions can exhaust the pool — nest with ``submit_async`` instead.
"""
from __future__ import annotations

import asyncio
import concurrent.futures as cf
import threading
import time
import weakref
from typing import Dict, List, Optional, Set

from repro.core.autosplit import schedule_parts, split_workflow
from repro.core.engines.base import StepRecord, StepStatus, WorkflowRun
from repro.core.gateway.admission import AdmissionQueue, AdmittedItem
from repro.core.gateway.channels import (ArtifactChannel, StepContext,
                                         StreamCancelled)
from repro.core.gateway.events import EventType
from repro.core.gateway.run import AsyncWorkflowRun
from repro.core.ir import WorkflowIR
from repro.core.obs.metrics import MetricsRegistry, StatsView

_EVENT_FOR_STATUS = {
    StepStatus.SUCCEEDED: EventType.STEP_SUCCEEDED,
    StepStatus.CACHED: EventType.STEP_CACHED,
    StepStatus.SKIPPED: EventType.STEP_SKIPPED,
    StepStatus.FAILED: EventType.STEP_FAILED,
}


class WorkflowGateway:
    """Asyncio-driven submission gateway; see module docstring."""

    def __init__(self, engine, max_workers: Optional[int] = None,
                 max_inflight_steps: Optional[int] = None,
                 max_inflight_workflows: Optional[int] = None,
                 admission: Optional[AdmissionQueue] = None,
                 promote_interval_s: float = 0.25,
                 check_events: bool = False,
                 readmission=None,
                 registry: Optional[MetricsRegistry] = None,
                 collector=None,
                 telemetry_interval_s: float = 0.0,
                 anomaly=None,
                 slo=None,
                 telemetry_path=None):
        self.engine = engine
        # sanitizer mode: attach a TraceChecker to every run's publish
        # path so an invariant breach raises at the offending event
        self.check_events = check_events
        # straggler-aware re-admission: a failed (not cancelled) run
        # re-enters the admission queue after a capped, jittered backoff
        # with aged priority (repro.core.faults.ReadmissionPolicy); the
        # satisfied step frontier is kept, failed steps reset. None (the
        # default) keeps failures terminal.
        self.readmission = readmission
        self.max_workers = max_workers or getattr(engine, "max_workers", 8)
        self.max_inflight_steps = (max_inflight_steps
                                   if max_inflight_steps
                                   else 2 * self.max_workers)
        self.max_inflight_workflows = max_inflight_workflows
        # one registry per gateway; a default admission queue shares it so
        # per-tenant depth/shed series land next to the gateway's own
        self.registry = registry if registry is not None else \
            MetricsRegistry("gateway")
        self.admission = admission if admission is not None else \
            AdmissionQueue(registry=self.registry)
        # span collector (couler.observe / attach_collector): when set,
        # every submitted run is registered and observed
        self.collector = collector
        self.promote_interval_s = promote_interval_s
        # continuous telemetry (couler.telemetry / telemetry_interval_s>0):
        # a TimeSeriesDB sampled on the loop's daemon cadence, plus the
        # optional anomaly monitor (in-band ALERT events) and SLO monitor
        # (burn-rate alerts + admission priority nudge)
        self.telemetry_interval_s = telemetry_interval_s
        self.telemetry_path = telemetry_path
        self.tsdb = None
        self.anomaly = anomaly
        self.slo = slo
        self._telemetry_task: Optional[asyncio.Task] = None
        if telemetry_interval_s and telemetry_interval_s > 0:
            from repro.core.obs.timeseries import TimeSeriesDB
            self.tsdb = TimeSeriesDB(path=telemetry_path)
        if self.anomaly is not None:
            self.anomaly.bind(self.registry)
        if self.slo is not None:
            self.slo.bind(self.registry)
        m = self.registry
        # workflow outcome counters — all increments go through the
        # thread-safe instruments (the old dict was mutated from the loop
        # thread AND worker threads without a lock); the legacy
        # ``gateway.stats`` mapping survives as a read view below
        self._m_wf = {
            "submitted": m.counter("gateway_workflows_submitted_total"),
            "completed": m.counter("gateway_workflows_completed_total"),
            "failed": m.counter("gateway_workflows_failed_total"),
            "cancelled": m.counter("gateway_workflows_cancelled_total"),
            "readmitted": m.counter("gateway_workflows_readmitted_total"),
        }
        self._m_inflight = m.gauge("gateway_inflight_steps")
        self._m_peak = m.gauge("gateway_peak_inflight_steps")
        self._m_chunks = m.counter("gateway_stream_chunks_total")
        self._m_replayed = m.counter("gateway_stream_chunks_replayed_total")
        self._m_rewinds = m.counter("gateway_stream_rewinds_total")
        self._m_stalls = m.counter("gateway_stream_backpressure_stalls_total")
        self._m_stall_s = m.counter("gateway_stream_backpressure_stall_s")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        self._step_sem: Optional[asyncio.Semaphore] = None
        self._wf_sem: Optional[asyncio.Semaphore] = None
        self._wake: Optional[asyncio.Event] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._promote_task: Optional[asyncio.Task] = None
        self._wf_tasks: Set[asyncio.Task] = set()
        self._start_lock = threading.Lock()
        self._started = threading.Event()
        self._closed = False
        self.admission.add_listener(self._on_offer)

    @property
    def stats(self) -> StatsView:
        """Legacy dict-compatible view over the registry instruments."""
        fields = dict(self._m_wf)
        fields["peak_inflight_steps"] = self._m_peak
        return StatsView(fields)

    def attach_collector(self, collector) -> None:
        """Attach an ``ObsCollector`` (``couler.observe``): every run
        submitted from now on is span-traced and ``run.report()`` works."""
        self.collector = collector

    # -- lifecycle ---------------------------------------------------------
    def ensure_started(self) -> None:
        if self._started.is_set():
            return
        with self._start_lock:
            if self._started.is_set():
                return
            if self._closed:
                raise RuntimeError("gateway is closed")
            self._thread = threading.Thread(
                target=self._loop_main, daemon=True,
                name=f"wf-gateway-{id(self):x}")
            self._thread.start()
        self._started.wait()

    def _loop_main(self) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        self._pool = cf.ThreadPoolExecutor(
            max_workers=self.max_workers, thread_name_prefix="gateway-step")
        self._step_sem = asyncio.Semaphore(self.max_inflight_steps)
        if self.max_inflight_workflows:
            self._wf_sem = asyncio.Semaphore(self.max_inflight_workflows)
        self._wake = asyncio.Event()
        self._pump_task = loop.create_task(self._pump())
        if self.promote_interval_s and self._cache_promotable():
            self._promote_task = loop.create_task(self._promote_loop())
        if self.telemetry_interval_s and self.tsdb is not None:
            self._telemetry_task = loop.create_task(self._telemetry_loop())
        self._started.set()
        try:
            loop.run_forever()
        finally:
            loop.close()

    def _cache_promotable(self) -> bool:
        cache = getattr(self.engine, "cache", None)
        tiers = getattr(cache, "tiers", None)
        return callable(getattr(cache, "promote", None)) \
            and tiers is not None and len(tiers) > 1

    def stop(self, wait: bool = True, timeout: float = 10.0) -> None:
        """Cancel the pump/promotion/workflow tasks, stop the loop, join
        the thread, and release the worker pool. Idempotent."""
        with self._start_lock:
            self._closed = True
            loop, thread = self._loop, self._thread
        if loop is None or not self._started.is_set():
            return

        def _begin_shutdown() -> None:
            loop.create_task(self._shutdown())

        try:
            loop.call_soon_threadsafe(_begin_shutdown)
        except RuntimeError:              # loop already closed
            return
        if wait and thread is not None \
                and thread is not threading.current_thread():
            thread.join(timeout)
        if self._pool is not None:
            self._pool.shutdown(wait=False)

    async def _shutdown(self) -> None:
        # sweep until quiescent: workflow tasks spawn step tasks, and a
        # step completing mid-sweep may spawn successors
        cur = asyncio.current_task()
        while True:
            rest = [t for t in asyncio.all_tasks()
                    if t is not cur and not t.done()]
            if not rest:
                break
            for t in rest:
                t.cancel()
            await asyncio.gather(*rest, return_exceptions=True)
        asyncio.get_running_loop().stop()

    # -- submission (thread-safe; callable from any thread) ----------------
    def submit_nowait(self, wf: WorkflowIR, optimize: bool = True,
                      tenant: str = "default", priority: int = 0,
                      run: Optional[WorkflowRun] = None,
                      resume: bool = False,
                      block: bool = False,
                      lint: str = "error") -> AsyncWorkflowRun:
        """Lint + validate + enqueue one workflow; returns its handle
        immediately. Lint errors (``repro.core.analysis``) raise
        ``WorkflowLintError`` unless ``lint="warn"|"off"``; resumed runs
        were gated on first submission and are not re-linted. Raises
        ``QueueFull`` when the tenant's queue is at capacity (pass
        ``block=True`` to wait for space instead — the sync facade does)."""
        if self._closed:
            raise RuntimeError("gateway is closed")
        self.ensure_started()
        if run is None:
            if lint != "off":
                from repro.core.analysis import lint_gate
                lint_gate(wf, mode=lint,
                          max_inflight_steps=self.max_inflight_steps)
            wf.validate()
            run = WorkflowRun(workflow=wf)
            for n in wf.jobs:
                run.steps[n] = StepRecord()
        handle = AsyncWorkflowRun(wf.name, run=run, tenant=tenant)
        if self.check_events:
            from repro.core.analysis import TraceChecker
            handle.add_observer(TraceChecker(wf=wf).observe)
        if self.collector is not None:
            # register before the ADMITTED publish inside admission.offer
            # so the span tree sees the full stream; the weakref on the
            # run lets run.report() find its tree without pinning the
            # collector
            self.collector.register_run(run.run_id, wf=wf, tenant=tenant)
            handle.add_observer(self.collector.observe)
            run._obs_collector = weakref.ref(self.collector)
        item = AdmittedItem(wf=wf, tenant=tenant, priority=priority,
                            optimize=optimize, resume=resume, handle=handle)
        self.admission.offer(item, block=block)
        return handle

    def _on_offer(self) -> None:
        loop, wake = self._loop, self._wake
        if loop is None or wake is None or self._closed:
            return
        try:
            loop.call_soon_threadsafe(wake.set)
        except RuntimeError:
            pass

    # -- pump: admission queue -> workflow tasks ---------------------------
    async def _pump(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            item = self.admission.pop()
            if item is None:
                self._wake.clear()
                if len(self.admission) == 0:
                    await self._wake.wait()
                continue
            if self._wf_sem is not None:
                await self._wf_sem.acquire()
            task = loop.create_task(self._run_workflow(item))
            self._wf_tasks.add(task)
            task.add_done_callback(self._wf_task_done)

    def _wf_task_done(self, task: asyncio.Task) -> None:
        self._wf_tasks.discard(task)
        if self._wf_sem is not None:
            self._wf_sem.release()

    # -- per-workflow execution (mirrors LocalEngine's sync scheduler) -----
    async def _run_workflow(self, item: AdmittedItem) -> None:
        handle = item.handle
        run = handle.run
        eng = self.engine
        self._m_wf["submitted"].inc()
        loop = asyncio.get_running_loop()
        try:
            if handle.cancel_requested:       # cancelled while queued
                run.status = "Cancelled"
                self._m_wf["cancelled"].inc()
                handle._publish(EventType.WORKFLOW_DONE, status=run.status)
                handle._finish(run)
                return
            wf = run.workflow
            t0 = time.time()
            if item.optimize and not item.resume:
                parts = split_workflow(wf, eng.budget)
            else:
                parts = [wf]
            ok = True
            if len(parts) == 1:
                ok = await self._run_part(parts[0], run, handle)
            else:
                # maximum parallelism (Eq. 1): independent parts of a wave
                # run concurrently, waves in dependency order
                waves = schedule_parts(wf, parts)
                for wave in waves:
                    if not ok:
                        break
                    results = await asyncio.gather(
                        *(self._run_part(parts[i], run, handle)
                          for i in wave))
                    ok = all(results)
            dt = time.time() - t0
            run.wall_time_s = run.wall_time_s + dt if item.resume else dt
            if not ok:
                if self._maybe_readmit(item, run, handle):
                    await loop.run_in_executor(self._pool, run.persist)
                    return          # handle finishes on a later round trip
                run.status = "Failed"
                self._m_wf["failed"].inc()
            elif handle.cancel_requested and any(
                    r.status == StepStatus.PENDING
                    for r in run.steps.values()):
                run.status = "Cancelled"
                self._m_wf["cancelled"].inc()
            else:
                run.status = "Succeeded"
                self._m_wf["completed"].inc()
            await loop.run_in_executor(self._pool, run.persist)
            if self.slo is not None:
                self.slo.note_run(
                    handle.tenant, ok=(run.status == "Succeeded"),
                    makespan_s=run.wall_time_s,
                    queue_wait_s=max(0.0, t0 - item.offered_at))
            handle._publish(EventType.WORKFLOW_DONE, status=run.status)
            handle._finish(run)
        except asyncio.CancelledError:
            run.status = "Cancelled"
            handle._publish(EventType.WORKFLOW_DONE, status=run.status)
            handle._finish(run)
            raise
        except Exception as e:  # noqa: BLE001 — internal error, not a step
            run.status = "Failed"
            self._m_wf["failed"].inc()
            handle._publish(EventType.WORKFLOW_DONE, status="Failed",
                            error=f"{type(e).__name__}: {e}")
            handle._fail(e)

    # -- straggler-aware re-admission --------------------------------------
    def _maybe_readmit(self, item: AdmittedItem, run: WorkflowRun,
                       handle: AsyncWorkflowRun) -> bool:
        """Failed-run recovery (loop thread): when a re-admission policy
        allows another round trip, reset the unsatisfied steps, announce
        ``WORKFLOW_REQUEUED`` (a new checker epoch), and schedule the
        backoff re-offer. The handle stays unfinished — callers keep
        awaiting the same run across round trips."""
        pol = self.readmission
        if pol is None or handle.cancel_requested or self._closed \
                or not pol.should_readmit(item.readmit_count):
            return False
        failed = sorted(n for n, r in run.steps.items()
                        if r.status == StepStatus.FAILED)
        keep = (StepStatus.SUCCEEDED, StepStatus.SKIPPED, StepStatus.CACHED)
        for n, rec in run.steps.items():
            if rec.status not in keep:
                run.steps[n] = StepRecord()
        run.status = "Queued"
        item.readmit_count += 1
        item.resume = True              # keep the satisfied frontier
        item.priority = pol.aged_priority(item.priority)
        self._m_wf["readmitted"].inc()
        handle._publish(EventType.WORKFLOW_REQUEUED,
                        attempt=item.readmit_count,
                        error=f"steps failed: {', '.join(failed)}"
                              if failed else "")
        if self.anomaly is not None:
            alert = self.anomaly.note_requeue(run.workflow.name,
                                              tenant=handle.tenant)
            if alert is not None:
                handle._publish(EventType.ALERT, status=alert.detector,
                                error=alert.reason)
        delay = pol.delay_s(item.readmit_count)
        asyncio.get_running_loop().create_task(
            self._requeue_later(item, delay))
        return True

    async def _requeue_later(self, item: AdmittedItem, delay: float) -> None:
        handle, run = item.handle, item.handle.run
        try:
            await asyncio.sleep(delay)
        except asyncio.CancelledError:
            # gateway shutdown mid-backoff: finish the handle so sync
            # waiters unblock; the persisted run stays resumable
            run.status = "Cancelled"
            handle._publish(EventType.WORKFLOW_DONE, status="Cancelled")
            handle._finish(run)
            raise
        if handle.cancel_requested:
            run.status = "Cancelled"
            self._m_wf["cancelled"].inc()
            handle._publish(EventType.WORKFLOW_DONE, status="Cancelled")
            handle._finish(run)
            return
        # block=True from an executor thread: re-admission must not shed
        await asyncio.get_running_loop().run_in_executor(
            None, lambda: self.admission.offer(item, block=True))

    def _record_frontier(self, run: WorkflowRun) -> None:
        """Fire-and-forget frontier snapshot through the engine's
        ``FrontierStore`` (if attached) after each step terminal event —
        the persistence half of checkpoint-resume."""
        store = getattr(self.engine, "frontier", None)
        if store is None:
            return
        try:
            self._pool.submit(store.record, run)
        except RuntimeError:            # pool shutting down
            pass

    async def _run_part(self, wfp: WorkflowIR, run: WorkflowRun,
                        handle: AsyncWorkflowRun) -> bool:
        """Asyncio port of ``LocalEngine._run_part``: per-job indegree
        counters decremented on completion, each finished step costing
        O(out-degree); steps execute on the SHARED pool gated by the
        global in-flight-steps semaphore."""
        eng = self.engine
        eng.cache.attach_workflow(run.workflow)
        satisfied = (StepStatus.SUCCEEDED, StepStatus.SKIPPED,
                     StepStatus.CACHED)
        done: Set[str] = {n for n, r in run.steps.items()
                          if n in wfp.jobs and r.status in satisfied}
        total = len(wfp.jobs)
        if len(done) >= total:
            return True
        # remaining unsatisfied dependencies per not-yet-done job; a pred
        # outside this part that is not already satisfied never resolves
        # here, which leaves the job pending and ends the part
        indeg: Dict[str, int] = {}
        ready: List[str] = []
        for n in wfp.jobs:
            if n in done:
                continue
            k = 0
            for p in run.workflow.predecessors(n):
                if p not in wfp.jobs and p not in run.steps:
                    continue
                rec = run.steps.get(p)
                if rec is not None and rec.status in satisfied:
                    continue
                k += 1
            indeg[n] = k
            if k == 0:
                ready.append(n)

        # streaming channels: one per streamed artifact consumed chunk-wise
        # in this part whose producer is also here and not yet satisfied;
        # consumers of already-done (or out-of-part) producers fall back to
        # the materialized artifact
        channels: Dict[str, ArtifactChannel] = {}
        by_producer: Dict[str, ArtifactChannel] = {}
        early: Dict[str, Set[str]] = {}   # consumer -> early-startable preds
        for n, j in wfp.jobs.items():
            if n in done or not (j.stream_input and j.stream_arg):
                continue
            p = j.stream_arg.split(":")[0]
            pj = wfp.jobs.get(p)
            if pj is None or not pj.stream_output or p in done:
                continue
            ch = channels.get(j.stream_arg)
            if ch is None:
                ch = ArtifactChannel(j.stream_arg, producer=p,
                                     capacity=pj.stream_buffer_chunks)
                channels[j.stream_arg] = ch
                by_producer[p] = ch
            ch.expect_consumer(n)
            # conditioned consumers cannot start before their predicate's
            # artifact exists; they launch normally and read the channel
            # history (or the materialized value) once ready
            if j.condition is None:
                early.setdefault(n, set()).add(p)
        ctx = StepContext(channels=channels, publish=handle._publish)
        if channels:
            handle.add_cancel_callback(
                lambda chans=tuple(channels.values()):
                    [c.cancel() for c in chans])

        loop = asyncio.get_running_loop()
        # completion handling is inlined at the tail of each step task (the
        # loop is single-threaded, so no locking): each finished step costs
        # O(out-degree) with no waiter re-registration — the part coroutine
        # only awaits one future resolved when the outstanding count drains
        state = {"failed": False, "outstanding": 0}
        part_done: asyncio.Future = loop.create_future()
        # consumer->producer edges already credited by an early (first-chunk)
        # start; finish_one must not decrement them a second time
        credited: Set[tuple] = set()

        def finish_one(name: str, status: Optional[StepStatus]) -> None:
            j = wfp.jobs.get(name)
            if j is not None and j.stream_arg in channels:
                # release the phantom cursor of a consumer that terminated
                # without ever attaching (skipped / failed / cancelled)
                channels[j.stream_arg].consumer_done(name)
            chn = by_producer.get(name)
            if chn is not None and status is not None and not chn.finished:
                # the engine closes/aborts on every normal exit; this is
                # belt-and-braces so readers never block on a dead producer
                chn.abort(RuntimeError(
                    f"{name} ended without closing its stream"))
            if status is not None:
                if status == StepStatus.FAILED:
                    state["failed"] = True      # in-flight steps drain out
                else:
                    done.add(name)
                    if not state["failed"] and not handle.cancel_requested:
                        for s in run.workflow.successors(name):
                            if s in indeg and s not in done \
                                    and (s, name) not in credited:
                                indeg[s] -= 1
                                if indeg[s] == 0:
                                    spawn(s)
            state["outstanding"] -= 1
            if state["outstanding"] == 0 and not part_done.done():
                part_done.set_result(None)

        def stream_ready(p: str) -> None:
            # producer p emitted its first chunk (scheduled onto the loop,
            # so serialized with finish_one): credit its edge to chunk-wise
            # consumers now — every *other* dependency still gates them
            if state["failed"] or handle.cancel_requested or p in done:
                return
            for s, ps in early.items():
                if p in ps and (s, p) not in credited \
                        and s in indeg and s not in done:
                    credited.add((s, p))
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        spawn(s)

        for p, chn in by_producer.items():
            chn.on_first_chunk = (
                lambda p=p: loop.call_soon_threadsafe(stream_ready, p))

        async def exec_one(name: str) -> None:
            status: Optional[StepStatus] = None
            try:
                async with self._step_sem:
                    if handle.cancel_requested:
                        return              # never launched: stays Pending
                    handle._publish(EventType.STEP_STARTED, step=name)
                    self._note_inflight(+1)
                    try:
                        status = await loop.run_in_executor(
                            self._pool, eng._exec_step, wfp.jobs[name], run,
                            ctx)
                    except StreamCancelled:
                        # cancelled mid-stream: revert to Pending so the
                        # run stays resumable; like a never-launched step
                        # it gets no terminal event (taxonomy exception)
                        run.steps[name] = StepRecord()
                        status = None
                    except Exception as e:  # noqa: BLE001
                        rec = run.steps[name]
                        rec.error = f"{type(e).__name__}: {e}"
                        rec.status = StepStatus.FAILED
                        status = StepStatus.FAILED
                    finally:
                        self._note_inflight(-1)
                    if status is not None:
                        handle._publish(
                            _EVENT_FOR_STATUS.get(status,
                                                  EventType.STEP_FAILED),
                            step=name, status=status.value,
                            error=run.steps[name].error)
                        self._record_frontier(run)
                        prof = getattr(run.steps[name], "profile", None)
                        if prof:
                            self._fold_profile(run, name, prof)
                        if self.anomaly is not None \
                                and status is StepStatus.SUCCEEDED:
                            self._note_step_telemetry(handle, run, name)
            finally:
                finish_one(name, status)

        def spawn(name: str) -> None:
            state["outstanding"] += 1
            loop.create_task(exec_one(name))

        for n in ready:
            spawn(n)
        if state["outstanding"]:
            await part_done
        if channels:
            self._fold_channel_stats(channels, run)
        return not state["failed"]

    def _fold_channel_stats(self, channels: Dict[str, ArtifactChannel],
                            run: WorkflowRun) -> None:
        """Part teardown: aggregate each channel's chunk/backpressure
        counters into the registry and annotate the producer's span —
        producer stall time is measured inside ``put`` and cannot be
        derived from the event stream alone."""
        for ch in channels.values():
            st = ch.stats
            self._m_chunks.inc(st["puts"])
            self._m_replayed.inc(st["replayed"])
            self._m_rewinds.inc(st["rewinds"])
            self._m_stalls.inc(st["stalls"])
            self._m_stall_s.inc(st["stall_s"])
            if self.collector is not None:
                self.collector.annotate_step(
                    run.run_id, ch.producer,
                    stream_stall_s=st["stall_s"],
                    stream_chunks=st["puts"],
                    stream_stalls=st["stalls"],
                    stream_max_lead=st["max_lead"])

    def _note_inflight(self, delta: int) -> None:
        # thread-safe now (registry gauges): speculation reserves slots
        # from worker threads, the loop thread drives exec_one — the old
        # dict high-water update could lose peaks across those contexts
        self._m_peak.set_max(self._m_inflight.add(delta))

    @property
    def _inflight_steps(self) -> int:
        """Live in-flight step count (reads the registry gauge; kept as an
        attribute-shaped view for pre-registry call sites and tests)."""
        return int(self._m_inflight.value)

    # -- speculation slot accounting (thread-safe) -------------------------
    def try_reserve_step_slot(self, timeout: float = 2.0) -> bool:
        """Try to reserve one in-flight-step slot from a worker thread
        WITHOUT waiting for one to free up — used by the engine's straggler
        speculation so backup copies count against the same
        ``max_inflight_steps`` bound as scheduled steps. Returns False when
        the bound is saturated (no backup launches) or the gateway is not
        running; ``timeout`` only bounds the loop round-trip."""
        loop = self._loop
        if loop is None or self._closed or not self._started.is_set():
            return False

        async def _try() -> bool:
            sem = self._step_sem
            if sem is None or sem.locked():
                return False
            await sem.acquire()
            self._note_inflight(+1)
            return True

        try:
            return asyncio.run_coroutine_threadsafe(_try(), loop) \
                .result(timeout)
        except Exception:       # loop closing, or timed out: no slot
            return False

    def release_step_slot(self) -> None:
        """Release a slot taken via ``try_reserve_step_slot``."""
        loop = self._loop
        if loop is None:
            return

        def _rel() -> None:
            self._note_inflight(-1)
            if self._step_sem is not None:
                self._step_sem.release()

        try:
            loop.call_soon_threadsafe(_rel)
        except RuntimeError:    # loop already closed: nothing to release
            pass

    # -- background cache promotion ---------------------------------------
    async def _promote_loop(self) -> None:
        """Drive ``TieredCacheStore.promote()`` periodically so hot
        artifacts climb toward MEM without relying on the hit-count
        trigger. Cancellation (engine shutdown) exits cleanly."""
        loop = asyncio.get_running_loop()
        while True:
            await asyncio.sleep(self.promote_interval_s)
            try:
                await loop.run_in_executor(self._pool,
                                           self.engine.cache.promote)
            except RuntimeError:   # pool shut down mid-flight
                return
            except Exception:  # noqa: BLE001 — promotion is advisory
                pass

    # -- continuous telemetry ----------------------------------------------
    def start_telemetry(self, interval_s: float = 0.25, anomaly=None,
                        slo=None, path=None):
        """Turn on continuous telemetry on a live gateway
        (``couler.telemetry``): create the ``TimeSeriesDB`` (JSONL-backed
        when ``path`` is given), bind the anomaly / SLO monitors to this
        gateway's registry, and schedule the sampling task on the loop.
        Returns ``(tsdb, anomaly, slo)``. Idempotent for the task: calling
        again just updates the monitors/interval."""
        from repro.core.obs.timeseries import TimeSeriesDB
        self.telemetry_interval_s = interval_s
        if self.tsdb is None:
            self.tsdb = TimeSeriesDB(path=path or self.telemetry_path)
        if anomaly is not None:
            self.anomaly = anomaly
        if self.anomaly is not None:
            self.anomaly.bind(self.registry)
        if slo is not None:
            self.slo = slo
        if self.slo is not None:
            self.slo.bind(self.registry)
        if self._started.is_set() and self._telemetry_task is None \
                and self._loop is not None and not self._closed:
            def _sched() -> None:
                if self._telemetry_task is None:
                    self._telemetry_task = \
                        self._loop.create_task(self._telemetry_loop())
            try:
                self._loop.call_soon_threadsafe(_sched)
            except RuntimeError:
                pass
        return self.tsdb, self.anomaly, self.slo

    def _telemetry_sources(self) -> List[MetricsRegistry]:
        """Registries feeding the TSDB, identity-deduped: the gateway's
        own (admission shares it by default) plus the engine's cache /
        chaos-injector / collector registries when distinct."""
        seen: List[MetricsRegistry] = []
        candidates = [
            self.registry,
            getattr(self.admission, "registry", None),
            getattr(getattr(self.engine, "cache", None), "registry", None),
            getattr(getattr(self.engine, "injector", None), "registry",
                    None),
            getattr(self.collector, "registry", None)
            if self.collector is not None else None,
        ]
        for r in candidates:
            if r is not None and all(r is not s for s in seen):
                seen.append(r)
        return seen

    def _telemetry_tick(self, now: Optional[float] = None) -> None:
        """One sampling pass (pool thread): merge registry snapshots into
        the TSDB, GC idle admission tenants, run the streaming detectors
        and the SLO burn evaluation + admission nudge."""
        tsdb = self.tsdb
        if tsdb is None:
            return
        merged: Dict[str, object] = {}
        for reg in self._telemetry_sources():
            merged.update(reg.snapshot())
        tsdb.sample(merged, ts=now)
        gc = getattr(self.admission, "gc_idle_tenants", None)
        if callable(gc):
            gc(now=now)
        if self.anomaly is not None:
            self.anomaly.evaluate(tsdb, now)
        if self.slo is not None:
            self.slo.evaluate(now)
            self.slo.nudge(self.admission)

    async def _telemetry_loop(self) -> None:
        """Periodic sampling task (same template as ``_promote_loop``);
        ticks run on the pool so snapshot/detector cost never blocks the
        loop. Cancellation (engine shutdown) exits cleanly."""
        loop = asyncio.get_running_loop()
        while True:
            await asyncio.sleep(self.telemetry_interval_s)
            try:
                await loop.run_in_executor(self._pool, self._telemetry_tick)
            except RuntimeError:   # pool shut down mid-flight
                return
            except Exception:  # noqa: BLE001 — telemetry is advisory
                pass

    def _fold_profile(self, run: WorkflowRun, step: str,
                      prof: Dict[str, float]) -> None:
        """Record a step's compute-layer profile (jit compile vs execute
        split, device memory) as registry histograms/gauges and annotate
        its span so ``run.report()`` shows the breakdown."""
        m = self.registry
        if "compile_s" in prof:
            m.histogram("step_compile_s").observe(prof["compile_s"])
        if "execute_s" in prof:
            m.histogram("step_execute_s").observe(prof["execute_s"])
        if "device_bytes_in_use" in prof:
            m.gauge("device_bytes_in_use").set(prof["device_bytes_in_use"])
        if self.collector is not None:
            self.collector.annotate_step(run.run_id, step, **prof)

    def _note_step_telemetry(self, handle: AsyncWorkflowRun,
                             run: WorkflowRun, step: str) -> None:
        """Feed a succeeded step's duration to the straggler detector;
        publish any resulting alert in-band. Runs on the loop thread right
        after the step's terminal publish — never from inside an observer
        (the handle's publish lock is not reentrant)."""
        rec = run.steps.get(step)
        if rec is None or rec.start is None or rec.end is None \
                or rec.end <= rec.start:
            return
        alert = self.anomaly.note_step_duration(
            run.workflow.name, step, rec.end - rec.start,
            tenant=handle.tenant)
        if alert is not None:
            handle._publish(EventType.ALERT, step=step,
                            status=alert.detector, error=alert.reason)
