"""Typed lifecycle events emitted by the workflow gateway.

See ``repro.core.gateway`` (package docstring) for the full taxonomy and
the ordering invariants every event stream satisfies.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class EventType(str, enum.Enum):
    """Lifecycle event kinds, in the order they may appear in a stream."""

    WORKFLOW_ADMITTED = "WORKFLOW_ADMITTED"   # passed the backpressure gate
    STEP_STARTED = "STEP_STARTED"             # step handed to the worker pool
    STEP_STREAMING = "STEP_STREAMING"         # step is emitting chunks
    STEP_CHUNK = "STEP_CHUNK"                 # one chunk emitted (see .chunk)
    STEP_RETRY = "STEP_RETRY"                 # transient failure; retrying
    WORKER_LOST = "WORKER_LOST"               # pool slot died mid-execution
    STEP_SUCCEEDED = "STEP_SUCCEEDED"
    STEP_CACHED = "STEP_CACHED"               # outputs served from the store
    STEP_SKIPPED = "STEP_SKIPPED"             # couler.when condition false
    STEP_FAILED = "STEP_FAILED"
    CLUSTER_PREEMPTED = "CLUSTER_PREEMPTED"   # run-scope: cluster went dark
    WORKFLOW_REQUEUED = "WORKFLOW_REQUEUED"   # failed run re-enters admission
    ALERT = "ALERT"                           # anomaly detector fired in-band
    WORKFLOW_DONE = "WORKFLOW_DONE"           # terminal; exactly one per run


STEP_EVENTS = frozenset({EventType.STEP_STARTED, EventType.STEP_STREAMING,
                         EventType.STEP_CHUNK, EventType.STEP_RETRY,
                         EventType.WORKER_LOST, EventType.STEP_SUCCEEDED,
                         EventType.STEP_CACHED, EventType.STEP_SKIPPED,
                         EventType.STEP_FAILED})


@dataclass(frozen=True)
class WorkflowEvent:
    """One lifecycle event of one run.

    ``seq`` is a per-run monotonic counter (0 is always the admission
    event); ``status`` carries the step status for STEP_* events and the
    terminal run status ("Succeeded"/"Failed"/"Cancelled") for
    WORKFLOW_DONE and the firing detector name for ALERT (whose ``error``
    carries the human-readable reason). ``chunk`` is the 0-based chunk
    index for STEP_CHUNK
    events (-1 otherwise). ``attempt`` is the 1-based attempt number for
    retry-related events: the attempt about to run for STEP_RETRY, the
    attempt that died for WORKER_LOST / CLUSTER_PREEMPTED, the admission
    round for WORKFLOW_REQUEUED (0 when not applicable).
    """

    type: EventType
    workflow: str
    run_id: str
    tenant: str = "default"
    step: str = ""
    status: str = ""
    error: str = ""
    chunk: int = -1
    attempt: int = 0
    seq: int = -1
    ts: float = 0.0

    @property
    def terminal(self) -> bool:
        return self.type is EventType.WORKFLOW_DONE

    @property
    def is_step_event(self) -> bool:
        return self.type in STEP_EVENTS
