"""``AsyncWorkflowRun`` — the awaitable handle returned by ``submit_async``.

The handle is **loop-agnostic**: execution happens on the gateway's own
event loop (or, for the generic ``Engine.submit_async`` fallback, in a
worker thread), while awaiting and event iteration work from whatever
asyncio loop the caller runs — results ride a ``concurrent.futures.Future``
and events are fanned out to per-subscriber ``asyncio.Queue``s via
``call_soon_threadsafe``. The same handle therefore also has a blocking
``result()`` for sync facades.

Subscribers never miss events: ``events()`` atomically replays the full
history recorded so far before streaming live ones, so iterating after the
run finished still yields the complete, ordered stream.
"""
from __future__ import annotations

import asyncio
import concurrent.futures as cf
import itertools
import threading
import time
from typing import AsyncIterator, Callable, List, Optional, Tuple

from repro.core.engines.base import WorkflowRun
from repro.core.gateway.events import EventType, WorkflowEvent


class AsyncWorkflowRun:
    """Awaitable handle for one submitted workflow.

    * ``await handle`` / ``handle.result()`` -> the finished ``WorkflowRun``
    * ``async for ev in handle.events()`` -> ordered lifecycle events,
      ending with the single terminal ``WORKFLOW_DONE``
    * ``handle.cancel()`` -> cooperative cancellation: running steps finish,
      no new steps launch, the run ends ``Cancelled`` and stays resumable
      via ``engine.resume(run)``.
    """

    def __init__(self, workflow_name: str, run: Optional[WorkflowRun] = None,
                 tenant: str = "default"):
        self.workflow_name = workflow_name
        self.tenant = tenant
        self.run = run
        self._result: "cf.Future[WorkflowRun]" = cf.Future()
        self._lock = threading.Lock()
        self._history: List[WorkflowEvent] = []
        self._subs: List[Tuple[asyncio.AbstractEventLoop, asyncio.Queue]] = []
        self._cancel = threading.Event()
        self._cancel_cbs: List[Callable[[], None]] = []
        self._seq = itertools.count()
        # synchronous observers (TraceChecker sanitizer, ObsCollector):
        # called under the publish lock so each sees events in seq order
        self._observers: List[Callable[[WorkflowEvent], object]] = []

    def add_observer(self, cb: Callable[[WorkflowEvent], object]) -> None:
        """Register a synchronous per-event hook. Called under the publish
        lock in registration order — observers must be fast and must not
        publish; an observer raising (the TraceChecker sanitizer does, by
        design) propagates out of the offending ``_publish``."""
        with self._lock:
            self._observers.append(cb)

    # -- awaiting ----------------------------------------------------------
    def __await__(self):
        return asyncio.wrap_future(self._result).__await__()

    def result(self, timeout: Optional[float] = None) -> WorkflowRun:
        """Block until the run finishes (the sync facade's wait)."""
        return self._result.result(timeout)

    def done(self) -> bool:
        return self._result.done()

    @property
    def run_id(self) -> str:
        return self.run.run_id if self.run is not None else ""

    @property
    def status(self) -> str:
        return self.run.status if self.run is not None else "Pending"

    # -- cancellation ------------------------------------------------------
    def cancel(self) -> bool:
        """Request cooperative cancellation. Returns False if the run
        already finished. Steps currently executing run to completion;
        steps not yet launched stay ``Pending`` (so the resulting
        ``WorkflowRun`` is resumable)."""
        if self._result.done():
            return False
        self._cancel.set()
        with self._lock:
            cbs = list(self._cancel_cbs)
        for cb in cbs:
            try:
                cb()
            except Exception:
                pass
        return True

    @property
    def cancel_requested(self) -> bool:
        return self._cancel.is_set()

    def add_cancel_callback(self, cb: Callable[[], None]) -> None:
        """Register a callback fired on ``cancel()`` (used by the gateway
        to interrupt blocked artifact-channel producers/consumers so a
        cancelled run drains instead of waiting out its streams). Called
        immediately if cancellation was already requested; must be
        thread-safe."""
        with self._lock:
            if not self._cancel.is_set():
                self._cancel_cbs.append(cb)
                return
        cb()

    # -- event stream ------------------------------------------------------
    async def events(self) -> AsyncIterator[WorkflowEvent]:
        """Async iterator over lifecycle events; terminates after the
        single ``WORKFLOW_DONE`` event. Safe to call from any loop, any
        number of times, before or after completion."""
        loop = asyncio.get_running_loop()
        q: asyncio.Queue = asyncio.Queue()
        with self._lock:
            backlog = list(self._history)
            self._subs.append((loop, q))
        try:
            for ev in backlog:
                yield ev
                if ev.terminal:
                    return
            while True:
                ev = await q.get()
                yield ev
                if ev.terminal:
                    return
        finally:
            with self._lock:
                try:
                    self._subs.remove((loop, q))
                except ValueError:
                    pass

    def events_so_far(self) -> List[WorkflowEvent]:
        """Snapshot of the events recorded so far (sync; for inspection)."""
        with self._lock:
            return list(self._history)

    # -- gateway-internal publishing ---------------------------------------
    def _publish(self, type_: EventType, step: str = "", status: str = "",
                 error: str = "", chunk: int = -1,
                 attempt: int = 0) -> WorkflowEvent:
        # seq assignment and history append happen under one lock: chunk
        # events arrive from worker threads concurrently with loop-thread
        # lifecycle events, and history must stay seq-sorted
        with self._lock:
            ev = WorkflowEvent(type=type_, workflow=self.workflow_name,
                               run_id=self.run_id, tenant=self.tenant,
                               step=step, status=status, error=error,
                               chunk=chunk, attempt=attempt,
                               seq=next(self._seq), ts=time.time())
            self._history.append(ev)
            dead = []
            for sub in self._subs:
                loop, q = sub
                try:
                    loop.call_soon_threadsafe(q.put_nowait, ev)
                except RuntimeError:      # subscriber's loop closed
                    dead.append(sub)
            for sub in dead:
                self._subs.remove(sub)
            for observer in self._observers:
                # a sanitizer raises TraceViolation at the offending
                # publish; the lock is released by the with-statement on
                # the way out
                observer(ev)
        return ev

    def _finish(self, run: WorkflowRun) -> None:
        self.run = run
        if not self._result.done():
            self._result.set_result(run)

    def _fail(self, exc: BaseException) -> None:
        if not self._result.done():
            self._result.set_exception(exc)
