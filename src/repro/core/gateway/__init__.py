"""Async workflow gateway: asyncio submission layer between the user API
and the engines.

The gateway multiplexes thousands of concurrent workflows onto shared
executor resources: one event loop, one step worker pool, one (thread-safe)
artifact store, and one backpressured multi-tenant admission queue per
``LocalEngine``. ``couler.run_async`` / ``couler.stream`` and
``Engine.submit_async`` are the user-facing entry points;
``LocalEngine.submit`` is a thin sync facade over the same path.

Event taxonomy
--------------
Every run's event stream (``AsyncWorkflowRun.events()``) is a totally
ordered sequence of ``WorkflowEvent``s:

``WORKFLOW_ADMITTED``
    The submission passed the backpressure gate (per-tenant bounded queue;
    a full queue sheds load with ``QueueFull`` instead). Always the first
    event (``seq == 0``).
``STEP_STARTED``
    A step acquired an in-flight slot and was handed to the worker pool.
``STEP_STREAMING``
    A streaming step (``couler.run_stream`` / ``couler.map_stream``) is
    about to emit its first chunk — downstream chunk-wise consumers may
    start from this point, before the producer's terminal event.
``STEP_CHUNK``
    One chunk delivered into the step's artifact channel (or replayed
    from the chunk-granular cache); ``chunk`` is its 0-based index.
``STEP_RETRY``
    A transient failure was absorbed and the step is about to re-run;
    ``attempt`` is the attempt number about to execute (so attempts on a
    step's retries strictly increase). Emitted on every retry — organic
    ``TransientError``s and injected chaos alike.
``WORKER_LOST``
    The pool slot executing the step died (``repro.core.faults``
    worker-loss injection); ``attempt`` is the attempt that died. Always
    followed by either a ``STEP_RETRY`` or the step's ``STEP_FAILED``.
``STEP_SUCCEEDED`` / ``STEP_CACHED`` / ``STEP_SKIPPED`` / ``STEP_FAILED``
    The step's terminal status: executed, served from the artifact store
    (Algorithm 2 consumer side), skipped by its ``couler.when`` condition,
    or failed after exhausting the transient-error retry budget. Always
    preceded by that step's ``STEP_STARTED``.
``CLUSTER_PREEMPTED``
    Run-scoped (``MultiClusterEngine`` chaos): the cluster running
    ``step`` went dark and evicted it; the job re-enters placement.
``WORKFLOW_REQUEUED``
    The run failed but a ``ReadmissionPolicy`` accepted it back into the
    admission queue (capped exponential backoff + priority aging);
    ``attempt`` is the re-admission round. Opens a new *epoch*: completed
    steps stay completed, failed steps reset to Pending and may emit a
    fresh ``STEP_STARTED``.
``ALERT``
    A streaming anomaly detector fired in-band (continuous telemetry,
    ``couler.telemetry``): ``status`` names the detector (``straggler``,
    ``readmission_storm``, ...), ``error`` carries the human-readable
    reason, and ``step`` is set for step-scoped detectors. Advisory —
    alerts never change run or step state.
``WORKFLOW_DONE``
    Terminal; exactly one per run, always last, with ``status`` in
    ``{"Succeeded", "Failed", "Cancelled"}``. A cancelled run keeps its
    unlaunched steps ``Pending`` and is resumable via ``engine.resume``.

Invariants — the **executable specification** is
``repro.core.analysis.TraceChecker``, a linear-time automaton that
consumes each run's event stream and raises ``TraceViolation`` naming the
broken invariant. The gateway/streaming test suites and the sanity fuzz
all validate streams through that single checker, and
``WorkflowGateway(check_events=True)`` (or
``LocalEngine(check_events=True)``) attaches one per run inline —
sanitizer mode — so a breach raises at the offending publish. In prose:

1. ``WORKFLOW_ADMITTED`` is first (seq 0) and precedes every ``STEP_*``
   event.
2. Exactly one terminal event per run, and nothing follows it.
3. Every ``STEP_SUCCEEDED/CACHED/SKIPPED/FAILED`` is preceded by its own
   ``STEP_STARTED``.
4. Every ``STEP_STREAMING``/``STEP_CHUNK`` falls strictly between its own
   step's ``STEP_STARTED`` and terminal event, and the step's first
   ``STEP_CHUNK`` is preceded by its ``STEP_STREAMING``.
5. Within one *attempt* a step's ``STEP_CHUNK`` indices are 0,1,2,…;
   a retried producer rewinds its channel and restarts at chunk 0, so
   indices reset only after a failure-triggered rewind.
6. A consumer's ``STEP_STARTED`` may precede its producer's terminal
   event (that is the point of streaming) but never the producer's
   ``STEP_STREAMING``.
7. ``STEP_RETRY`` / ``WORKER_LOST`` fall strictly between their own
   step's ``STEP_STARTED`` and terminal event, and a step's
   ``STEP_RETRY`` attempt numbers strictly increase within an epoch.
8. ``WORKFLOW_REQUEUED`` falls strictly between admission and the
   terminal event and resets the checker's per-step bookkeeping (new
   epoch — re-admitted steps may legally re-emit ``STEP_STARTED``);
   ``CLUSTER_PREEMPTED`` may appear anywhere in that same span.
9. ``ALERT`` falls strictly between admission and the terminal event and
   always names its detector in ``status``; it touches no step
   bookkeeping.

Exception (encoded in the checker's cancel scoping): a step interrupted
*mid-stream* by cooperative cancellation is reverted to ``Pending`` (the
run stays resumable) and — like a step that never launched — gets no
terminal step event; its ``STEP_STARTED`` / ``STEP_STREAMING`` /
``STEP_CHUNK`` events remain in the history, so invariant 3's
completeness half applies only to runs that ended ``Succeeded``.

Workflows are also statically linted before admission
(``repro.core.analysis.lint``; diagnostics table in
``docs/diagnostics.md``) — errors reject at ``submit``/``submit_async``
time unless ``lint="warn"|"off"``.

The generic ``Engine.submit_async`` fallback (engines without a native
async path, e.g. ``MultiClusterEngine`` or the YAML generators) emits only
the coarse pair ``WORKFLOW_ADMITTED`` / ``WORKFLOW_DONE``.
"""
from repro.core.gateway.admission import (AdmissionQueue, AdmittedItem,
                                          QueueFull)
from repro.core.gateway.channels import (ArtifactChannel, StepContext,
                                         StreamBroken, StreamCancelled,
                                         StreamError, StreamReader,
                                         StreamRewound, StreamStalled)
from repro.core.gateway.events import STEP_EVENTS, EventType, WorkflowEvent
from repro.core.gateway.gateway import WorkflowGateway
from repro.core.gateway.run import AsyncWorkflowRun

__all__ = ["AdmissionQueue", "AdmittedItem", "QueueFull", "EventType",
           "STEP_EVENTS", "WorkflowEvent", "WorkflowGateway",
           "AsyncWorkflowRun", "ArtifactChannel", "StreamReader",
           "StepContext", "StreamError", "StreamCancelled", "StreamRewound",
           "StreamBroken", "StreamStalled"]
