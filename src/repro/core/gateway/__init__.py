"""Async workflow gateway: asyncio submission layer between the user API
and the engines.

The gateway multiplexes thousands of concurrent workflows onto shared
executor resources: one event loop, one step worker pool, one (thread-safe)
artifact store, and one backpressured multi-tenant admission queue per
``LocalEngine``. ``couler.run_async`` / ``couler.stream`` and
``Engine.submit_async`` are the user-facing entry points;
``LocalEngine.submit`` is a thin sync facade over the same path.

Event taxonomy
--------------
Every run's event stream (``AsyncWorkflowRun.events()``) is a totally
ordered sequence of ``WorkflowEvent``s:

``WORKFLOW_ADMITTED``
    The submission passed the backpressure gate (per-tenant bounded queue;
    a full queue sheds load with ``QueueFull`` instead). Always the first
    event (``seq == 0``).
``STEP_STARTED``
    A step acquired an in-flight slot and was handed to the worker pool.
``STEP_SUCCEEDED`` / ``STEP_CACHED`` / ``STEP_SKIPPED`` / ``STEP_FAILED``
    The step's terminal status: executed, served from the artifact store
    (Algorithm 2 consumer side), skipped by its ``couler.when`` condition,
    or failed after exhausting the transient-error retry budget. Always
    preceded by that step's ``STEP_STARTED``.
``WORKFLOW_DONE``
    Terminal; exactly one per run, always last, with ``status`` in
    ``{"Succeeded", "Failed", "Cancelled"}``. A cancelled run keeps its
    unlaunched steps ``Pending`` and is resumable via ``engine.resume``.

Invariants (pinned by ``tests/test_gateway.py`` and the event-ordering
fuzz in ``scripts/sanity.py``):

1. ``WORKFLOW_ADMITTED`` precedes every ``STEP_*`` event.
2. Exactly one terminal event per run, and nothing follows it.
3. Every ``STEP_SUCCEEDED/CACHED/SKIPPED/FAILED`` is preceded by its own
   ``STEP_STARTED``.

The generic ``Engine.submit_async`` fallback (engines without a native
async path, e.g. ``MultiClusterEngine`` or the YAML generators) emits only
the coarse pair ``WORKFLOW_ADMITTED`` / ``WORKFLOW_DONE``.
"""
from repro.core.gateway.admission import (AdmissionQueue, AdmittedItem,
                                          QueueFull)
from repro.core.gateway.events import STEP_EVENTS, EventType, WorkflowEvent
from repro.core.gateway.gateway import WorkflowGateway
from repro.core.gateway.run import AsyncWorkflowRun

__all__ = ["AdmissionQueue", "AdmittedItem", "QueueFull", "EventType",
           "STEP_EVENTS", "WorkflowEvent", "WorkflowGateway",
           "AsyncWorkflowRun"]
