"""Backpressured multi-tenant admission queue (weighted round-robin).

Each tenant gets a bounded FIFO; ``offer`` sheds load with ``QueueFull``
when the tenant's queue (or the global bound) is at capacity — or blocks
until space frees when ``block=True`` (the sync facade's choice, so plain
``submit()`` never sheds). ``pop``/``drain`` serve tenants by classic
weighted round-robin: up to ``weight`` items from the current tenant, then
rotate — a tenant flooding its queue cannot starve the others.

The queue is plain-threading (no asyncio), so the same instance can feed
the asyncio ``WorkflowGateway`` pump *and* a batch scheduler
(``MultiClusterEngine.submit_admitted`` drains it into ``submit_many``).

The ``WORKFLOW_ADMITTED`` event is published under the queue lock, before
the item becomes poppable, so it always precedes any ``STEP_*`` event of
that run.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, TYPE_CHECKING

from repro.core.gateway.events import EventType
from repro.core.ir import WorkflowIR
from repro.core.obs.metrics import MetricsRegistry, StatsView

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.core.gateway.run import AsyncWorkflowRun


class QueueFull(RuntimeError):
    """Shed-load signal: the tenant's (or the global) queue is full."""

    def __init__(self, tenant: str, depth: int, limit: int):
        super().__init__(f"admission queue full for tenant {tenant!r}: "
                         f"depth={depth} limit={limit}")
        self.tenant = tenant
        self.depth = depth
        self.limit = limit


@dataclass
class AdmittedItem:
    """One queued submission (workflow + tenant metadata + optional async
    handle for lifecycle events)."""

    wf: WorkflowIR
    tenant: str = "default"
    priority: int = 0
    optimize: bool = True
    resume: bool = False
    handle: Optional["AsyncWorkflowRun"] = None
    offered_at: float = field(default_factory=time.time)
    # times this run has re-entered the queue after failure (gateway
    # re-admission); the first offer of a handle is readmit_count == 0
    readmit_count: int = 0


class AdmissionQueue:
    """Bounded per-tenant queues drained in weighted round-robin order."""

    def __init__(self, max_depth_per_tenant: int = 1024,
                 max_total: int = 8192,
                 weights: Optional[Dict[str, int]] = None,
                 default_weight: int = 1,
                 registry: Optional[MetricsRegistry] = None,
                 tenant_retention_s: float = 300.0):
        self.max_depth_per_tenant = max_depth_per_tenant
        self.max_total = max_total
        self.weights = dict(weights or {})
        self.default_weight = max(1, default_weight)
        # per-tenant series label GC: a tenant idle (no offer / pop / shed)
        # longer than this is dropped from the registry so long-lived
        # gateways don't accumulate unbounded label cardinality
        self.tenant_retention_s = tenant_retention_s
        self._cv = threading.Condition()
        self._queues: Dict[str, Deque[AdmittedItem]] = {}
        self._ring: Deque[str] = deque()   # active tenants, WRR order
        self._credit = 0                   # remaining serves for ring[0]
        self._total = 0
        self._listeners: List[Callable[[], None]] = []
        # aggregate counters + per-tenant shed/depth series (the gateway
        # passes its registry in so everything lands in one snapshot);
        # the legacy ``stats`` dict is a read view over the aggregates
        self.registry = registry if registry is not None \
            else MetricsRegistry("admission")
        self._m = {k: self.registry.counter(f"admission_{k}_total")
                   for k in ("offered", "shed", "popped")}
        self._m_depth = self.registry.gauge("admission_depth")
        self.registry.gauge_fn("admission_tenants", lambda: len(self._ring))
        # separate attribute (not in self._m — the legacy StatsView dict
        # shape is pinned by tests)
        self._m_gc = self.registry.counter("admission_tenant_gc_total")
        self._last_active: Dict[str, float] = {}
        self._last_gc = time.time()

    @property
    def stats(self) -> StatsView:
        return StatsView(self._m)

    def _tenant_shed(self, tenant: str) -> None:
        self._m["shed"].inc()
        self.registry.counter("admission_shed_total", tenant=tenant).inc()
        self._last_active[tenant] = time.time()

    # -- producer side -----------------------------------------------------
    def add_listener(self, cb: Callable[[], None]) -> None:
        """Register a callback fired (outside the lock) after each
        successful offer — the gateway uses this to wake its pump."""
        with self._cv:
            self._listeners.append(cb)

    def offer(self, item: AdmittedItem, block: bool = False,
              timeout: Optional[float] = None) -> None:
        """Enqueue ``item`` or raise ``QueueFull``. With ``block=True``,
        wait (up to ``timeout``) for space instead of shedding."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while True:
                depth = len(self._queues.get(item.tenant, ()))
                if (depth < self.max_depth_per_tenant
                        and self._total < self.max_total):
                    break
                if not block:
                    self._tenant_shed(item.tenant)
                    raise QueueFull(item.tenant, depth,
                                    self.max_depth_per_tenant)
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    self._tenant_shed(item.tenant)
                    raise QueueFull(item.tenant, depth,
                                    self.max_depth_per_tenant)
                if not self._cv.wait(remaining):
                    self._tenant_shed(item.tenant)
                    raise QueueFull(item.tenant, depth,
                                    self.max_depth_per_tenant)
            if item.handle is not None and not item.readmit_count:
                # under the lock, before the item is poppable: ADMITTED
                # is guaranteed to precede every STEP_* of this run.
                # Re-admitted runs already announced WORKFLOW_REQUEUED;
                # ADMITTED stays unique (invariant 1).
                item.handle._publish(EventType.WORKFLOW_ADMITTED)
            if item.tenant not in self._queues:
                self._queues[item.tenant] = deque()
                self._ring.append(item.tenant)
            self._queues[item.tenant].append(item)
            self._total += 1
            self._m["offered"].inc()
            self.registry.counter("admission_offered_total",
                                  tenant=item.tenant).inc()
            self._m_depth.inc()
            self.registry.gauge("admission_depth",
                                tenant=item.tenant).inc()
            self._last_active[item.tenant] = time.time()
            listeners = list(self._listeners)
        for cb in listeners:
            cb()
        if time.time() - self._last_gc > self.tenant_retention_s:
            self.gc_idle_tenants()

    def try_offer(self, item: AdmittedItem) -> bool:
        try:
            self.offer(item)
            return True
        except QueueFull:
            return False

    # -- consumer side (WRR) -----------------------------------------------
    def pop(self) -> Optional[AdmittedItem]:
        with self._cv:
            return self._pop_locked()

    def drain(self, max_n: Optional[int] = None) -> List[AdmittedItem]:
        """Pop up to ``max_n`` items (all, if None) in WRR order."""
        out: List[AdmittedItem] = []
        with self._cv:
            while max_n is None or len(out) < max_n:
                item = self._pop_locked()
                if item is None:
                    break
                out.append(item)
        return out

    def _pop_locked(self) -> Optional[AdmittedItem]:
        while self._ring:
            t = self._ring[0]
            q = self._queues.get(t)
            if not q:
                self._ring.popleft()
                self._queues.pop(t, None)
                self._credit = 0
                continue
            if self._credit <= 0:
                self._credit = max(1, int(self.weights.get(
                    t, self.default_weight)))
            item = q.popleft()
            self._total -= 1
            self._credit -= 1
            if not q:                       # tenant drained: leave the ring
                self._ring.popleft()
                self._queues.pop(t, None)
                self._credit = 0
            elif self._credit <= 0:         # served its weight: next tenant
                self._ring.rotate(-1)
            self._m["popped"].inc()
            self._m_depth.dec()
            self.registry.gauge("admission_depth", tenant=t).dec()
            self._last_active[t] = time.time()
            self._cv.notify_all()           # space freed: wake blocked offers
            return item
        return None

    # -- per-tenant label GC ------------------------------------------------
    def gc_idle_tenants(self, now: Optional[float] = None) -> List[str]:
        """Drop the per-tenant registry series (``tenant=`` label) of
        tenants idle longer than ``tenant_retention_s`` with nothing
        queued. Aggregate counters are untouched; a returning tenant just
        re-creates its series from zero. Returns the tenants dropped.
        Called opportunistically from ``offer`` and from the gateway's
        telemetry tick."""
        now = time.time() if now is None else now
        with self._cv:
            self._last_gc = now
            doomed = [t for t, ts in self._last_active.items()
                      if now - ts > self.tenant_retention_s
                      and t not in self._queues]
            for t in doomed:
                del self._last_active[t]
        for t in doomed:                    # registry has its own lock
            self.registry.drop_labeled("tenant", t)
            self._m_gc.inc()
        return doomed

    # -- introspection -----------------------------------------------------
    def depth(self, tenant: str) -> int:
        with self._cv:
            return len(self._queues.get(tenant, ()))

    def tenants(self) -> List[str]:
        with self._cv:
            return list(self._ring)

    def __len__(self) -> int:
        return self._total
