"""Chunked producer→consumer artifact channels with backpressure.

An ``ArtifactChannel`` carries one streamed artifact between a producer
step and its chunk-wise consumers while both execute on the gateway's
shared worker pool. The channel is plain-threading (producers and
consumers run in pool threads; only the *scheduling* reaction to the
first chunk rides the asyncio loop, via ``on_first_chunk``):

* ``put`` appends a chunk and **blocks** once the producer is more than
  ``capacity`` chunks ahead of the slowest consumer — consumers that are
  declared (``expect_consumer``) but not yet attached count as cursor 0,
  so a producer can never sprint unboundedly before its consumer gets a
  step slot. ``consumer_done`` releases the phantom cursor of a consumer
  that terminated without ever attaching (skipped / cancelled / failed).
* ``reader`` attaches a cursor-tracked ``StreamReader``; iterating it
  yields chunks in order and ends after ``close(total)``. ``seek(k)``
  skips a cached prefix without waiting for those chunks to exist.
* ``rewind`` (producer transient-retry) clears the history and bumps the
  channel epoch; attached readers observe ``StreamRewound`` on their next
  access and restart from chunk 0 — consumer bodies re-map the stream,
  replaying their own cached chunk prefix instead of recomputing it.
* ``abort`` (producer permanent failure) raises ``StreamBroken`` in every
  reader; ``cancel`` (cooperative run cancellation) raises
  ``StreamCancelled`` in blocked producers *and* consumers so a cancelled
  run drains cleanly — the interrupted steps revert to ``Pending`` and
  the run stays resumable.

Deadlock note: a streaming pipeline needs one in-flight-step slot per
concurrently-live stage; size ``max_inflight_steps`` at or above the
streaming depth. As a safety net a ``put`` blocked longer than
``stall_timeout_s`` raises ``StreamStalled`` (fails the run) instead of
hanging forever.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Set


class StreamError(RuntimeError):
    """Base class for streaming-channel signals."""


class StreamCancelled(StreamError):
    """The run was cooperatively cancelled mid-stream."""


class StreamRewound(StreamError):
    """The producer retried and restarted its stream from chunk 0."""


class StreamBroken(StreamError):
    """The producer failed permanently mid-stream."""


class StreamStalled(StreamError):
    """Backpressure wait exceeded ``stall_timeout_s`` (likely an
    under-provisioned ``max_inflight_steps`` for the streaming depth)."""


class ArtifactChannel:
    """Bounded in-order chunk channel for one streamed artifact."""

    def __init__(self, artifact: str, producer: str, capacity: int = 8,
                 stall_timeout_s: float = 60.0):
        self.artifact = artifact
        self.producer = producer
        self.capacity = max(1, int(capacity))
        self.stall_timeout_s = stall_timeout_s
        # the producer's chunk cache key, set before its first put; chained
        # stream consumers derive their own cache key from it
        self.source_key = ""
        self.on_first_chunk: Optional[Callable[[], None]] = None
        self._cv = threading.Condition()
        self._chunks: List[Any] = []
        self._epoch = 0
        self._total: Optional[int] = None
        self._error: Optional[BaseException] = None
        self._cancelled = False
        self._expected: Set[str] = set()          # declared, not yet attached
        self._cursors: Dict[int, int] = {}        # reader id -> cursor
        self._rid = itertools.count()
        self._first_fired = False
        self.stats = {"puts": 0, "replayed": 0, "rewinds": 0, "max_lead": 0,
                      # backpressure accounting: how often a put blocked
                      # on a slow consumer, and for how long in total —
                      # the gateway folds these into its metrics registry
                      # and the producer span's stream-stall segment
                      "stalls": 0, "stall_s": 0.0}

    # -- consumer registration ---------------------------------------------
    def expect_consumer(self, name: str) -> None:
        """Declare a consumer that will attach later; until it does (or is
        released via ``consumer_done``) it throttles the producer at
        cursor 0."""
        with self._cv:
            self._expected.add(name)

    def consumer_done(self, name: str) -> None:
        """A declared consumer reached a terminal state; if it never
        attached, drop its phantom cursor so the producer is not throttled
        by a consumer that will never read."""
        with self._cv:
            self._expected.discard(name)
            self._cv.notify_all()

    def reader(self, consumer: str = "?") -> "StreamReader":
        with self._cv:
            rid = next(self._rid)
            self._cursors[rid] = 0
            self._expected.discard(consumer)
            self._cv.notify_all()
            return StreamReader(self, rid, self._epoch)

    # -- producer side ------------------------------------------------------
    def _min_cursor_locked(self) -> int:
        if self._expected:
            return 0
        if not self._cursors:
            return len(self._chunks)              # no consumers: unbounded
        return min(self._cursors.values())

    def put(self, chunk: Any, replay: bool = False) -> int:
        """Append one chunk (blocking while the lead exceeds ``capacity``);
        returns the chunk's index. Replayed (cache-prefix) chunks obey the
        same bound — an unbounded replay would defeat the buffer."""
        fire = False
        with self._cv:
            deadline = (time.monotonic() + self.stall_timeout_s
                        if self.stall_timeout_s else None)
            blocked_at = None
            while True:
                if self._cancelled:
                    raise StreamCancelled(self.artifact)
                if self._total is not None:
                    raise StreamError(f"{self.artifact}: put after close")
                if len(self._chunks) - self._min_cursor_locked() \
                        < self.capacity:
                    break
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise StreamStalled(
                        f"{self.artifact}: producer blocked "
                        f">{self.stall_timeout_s}s at lead "
                        f"{len(self._chunks) - self._min_cursor_locked()} "
                        f"(is max_inflight_steps >= the streaming depth?)")
                if blocked_at is None:
                    blocked_at = time.monotonic()
                    self.stats["stalls"] += 1
                self._cv.wait(remaining)
            if blocked_at is not None:
                self.stats["stall_s"] += time.monotonic() - blocked_at
            idx = len(self._chunks)
            self._chunks.append(chunk)
            self.stats["puts"] += 1
            if replay:
                self.stats["replayed"] += 1
            lead = len(self._chunks) - self._min_cursor_locked()
            if lead > self.stats["max_lead"]:
                self.stats["max_lead"] = lead
            if not self._first_fired:
                self._first_fired = True
                fire = True
            self._cv.notify_all()
        if fire and self.on_first_chunk is not None:
            self.on_first_chunk()
        return idx

    def close(self, total: int) -> None:
        with self._cv:
            self._total = total
            self._cv.notify_all()

    def abort(self, exc: BaseException) -> None:
        with self._cv:
            self._error = exc
            self._cv.notify_all()

    def cancel(self) -> None:
        with self._cv:
            self._cancelled = True
            self._cv.notify_all()

    def rewind(self) -> None:
        """Producer retry: clear the history and bump the epoch; attached
        readers raise ``StreamRewound`` on their next access and restart."""
        with self._cv:
            self._epoch += 1
            self._chunks.clear()
            self._total = None
            self._error = None
            self.stats["rewinds"] += 1
            self._cv.notify_all()

    # -- introspection ------------------------------------------------------
    @property
    def finished(self) -> bool:
        with self._cv:
            return (self._total is not None or self._error is not None
                    or self._cancelled)

    def history(self) -> List[Any]:
        with self._cv:
            return list(self._chunks)


class StreamReader:
    """One consumer's cursor over an ``ArtifactChannel``; iterate for
    chunks in order (blocking), ``seek`` past a cached prefix, ``close``
    to detach (always close — a dangling cursor throttles the producer)."""

    def __init__(self, ch: ArtifactChannel, rid: int, epoch: int):
        self._ch = ch
        self._rid = rid
        self._epoch = epoch

    def seek(self, cursor: int) -> None:
        ch = self._ch
        with ch._cv:
            if self._epoch != ch._epoch:
                raise StreamRewound(ch.artifact)
            ch._cursors[self._rid] = cursor
            ch._cv.notify_all()

    def __iter__(self) -> "StreamReader":
        return self

    def __next__(self) -> Any:
        ch = self._ch
        with ch._cv:
            while True:
                if ch._cancelled:
                    raise StreamCancelled(ch.artifact)
                if self._epoch != ch._epoch:
                    raise StreamRewound(ch.artifact)
                cur = ch._cursors.get(self._rid)
                if cur is None:
                    raise StreamError(f"{ch.artifact}: reader closed")
                if cur < len(ch._chunks):
                    ch._cursors[self._rid] = cur + 1
                    chunk = ch._chunks[cur]
                    ch._cv.notify_all()      # lead shrank: wake the producer
                    return chunk
                if ch._error is not None:
                    raise StreamBroken(
                        f"{ch.artifact}: producer {ch.producer} failed: "
                        f"{ch._error}") from ch._error
                if ch._total is not None:
                    raise StopIteration
                ch._cv.wait(1.0)

    def close(self) -> None:
        ch = self._ch
        with ch._cv:
            ch._cursors.pop(self._rid, None)
            ch._cv.notify_all()


class StepContext:
    """Per-part execution context the gateway hands to
    ``LocalEngine._exec_step``: the part's artifact channels (keyed by
    artifact name) and a thread-safe ``publish`` for streaming progress
    events (``STEP_STREAMING`` / ``STEP_CHUNK``)."""

    __slots__ = ("channels", "publish")

    def __init__(self, channels: Optional[Dict[str, ArtifactChannel]] = None,
                 publish: Optional[Callable] = None):
        self.channels: Dict[str, ArtifactChannel] = channels or {}
        self.publish = publish
