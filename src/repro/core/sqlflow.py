"""SQLFlow interface (paper §V.E): SQL -> COULER workflow.

COULER is SQLFlow's default backend; a statement like

    SELECT * FROM iris.train
    TO TRAIN DNNClassifier
    WITH model.n_classes = 3, model.hidden_units = [10]
    COLUMN sepal_len, sepal_width
    LABEL class
    INTO sqlflow_models.my_dnn_model;

compiles to a select -> train -> save workflow, and

    SELECT * FROM iris.test
    TO PREDICT iris.predict.class
    USING sqlflow_models.my_dnn_model;

compiles to select -> load-model -> predict -> write. This module parses
that dialect (the subset the paper shows) and emits the IR through the
unified API — the same IR every other frontend produces.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core import api as couler
from repro.core.ir import WorkflowIR


@dataclass
class TrainStatement:
    table: str
    estimator: str
    attrs: Dict[str, Any] = field(default_factory=dict)
    columns: List[str] = field(default_factory=list)
    label: str = ""
    into: str = ""


@dataclass
class PredictStatement:
    table: str
    output: str
    model: str


_TRAIN_RE = re.compile(
    r"SELECT\s+(?P<cols>.+?)\s+FROM\s+(?P<table>[\w.]+)\s+"
    r"TO\s+TRAIN\s+(?P<est>[\w.]+)"
    r"(?:\s+WITH\s+(?P<with>.*?))?"
    r"(?:\s+COLUMN\s+(?P<column>[\w,\s]+?))?"
    r"(?:\s+LABEL\s+(?P<label>\w+))?"
    r"\s+INTO\s+(?P<into>[\w.]+)\s*;?\s*$",
    re.IGNORECASE | re.DOTALL)

_PREDICT_RE = re.compile(
    r"SELECT\s+(?P<cols>.+?)\s+FROM\s+(?P<table>[\w.]+)\s+"
    r"TO\s+PREDICT\s+(?P<out>[\w.]+)\s+"
    r"USING\s+(?P<model>[\w.]+)\s*;?\s*$",
    re.IGNORECASE | re.DOTALL)


def parse(sql: str):
    """Parse one SQLFlow statement -> TrainStatement | PredictStatement."""
    sql = " ".join(sql.split())
    m = _TRAIN_RE.match(sql)
    if m:
        attrs: Dict[str, Any] = {}
        if m.group("with"):
            for part in re.split(r",(?![^\[]*\])", m.group("with")):
                if "=" not in part:
                    continue
                k, v = part.split("=", 1)
                v = v.strip()
                try:
                    attrs[k.strip()] = eval(v, {}, {})  # noqa: S307 literals
                except Exception:
                    attrs[k.strip()] = v
        cols = ([c.strip() for c in m.group("column").split(",")]
                if m.group("column") else [])
        return TrainStatement(table=m.group("table"), estimator=m.group("est"),
                              attrs=attrs, columns=cols,
                              label=m.group("label") or "",
                              into=m.group("into"))
    m = _PREDICT_RE.match(sql)
    if m:
        return PredictStatement(table=m.group("table"), output=m.group("out"),
                                model=m.group("model"))
    raise ValueError(f"unsupported SQLFlow statement: {sql[:80]}")


# ---------------------------------------------------------------------------
# lowering to the unified interface
# ---------------------------------------------------------------------------

class _SqlSteps:
    """Default step payloads (real tiny numpy compute)."""

    @staticmethod
    def select(table, columns=None, **kw):
        import numpy as np
        rng = np.random.default_rng(abs(hash(table)) % 2**31)
        n_cols = max(1, len(columns or []) or 4)
        return {"table": table, "X": rng.standard_normal((64, n_cols)),
                "y": rng.integers(0, 3, 64)}

    @staticmethod
    def train(data, estimator="", attrs=None, label="", **kw):
        import numpy as np
        X, y = data["X"], data["y"]
        n_classes = int((attrs or {}).get("model.n_classes", 3))
        W = np.zeros((X.shape[1], n_classes))
        for _ in range(20):                      # tiny softmax regression
            logits = X @ W
            p = np.exp(logits - logits.max(1, keepdims=True))
            p /= p.sum(1, keepdims=True)
            onehot = np.eye(n_classes)[y % n_classes]
            W -= 0.1 * X.T @ (p - onehot) / len(y)
        return {"estimator": estimator, "weights": W}

    @staticmethod
    def save_model(model, into="", **kw):
        return {"saved_as": into, **model}

    @staticmethod
    def load_model(name, registry=None, **kw):
        if registry and name in registry:
            return registry[name]
        return {"estimator": "unknown", "weights": None, "saved_as": name}

    @staticmethod
    def predict(data, model, output="", **kw):
        import numpy as np
        W = model.get("weights")
        if W is None:
            return {"output": output, "preds": []}
        preds = np.argmax(data["X"][:, : W.shape[0]] @ W, axis=1)
        return {"output": output, "preds": preds.tolist()}


def to_workflow(sql: str, name: str = "sqlflow",
                model_registry: Optional[Dict[str, Any]] = None) -> WorkflowIR:
    """One SQLFlow statement -> WorkflowIR via the unified API."""
    stmt = parse(sql)
    with couler.workflow(name) as ir:
        if isinstance(stmt, TrainStatement):
            data = couler.run_step(_SqlSteps.select, stmt.table,
                                   stmt.columns, step_name="select")
            model = couler.run_step(_SqlSteps.train, data,
                                    estimator=stmt.estimator,
                                    attrs=stmt.attrs, label=stmt.label,
                                    step_name="train")
            couler.run_step(_SqlSteps.save_model, model, into=stmt.into,
                            step_name="save-model")
        else:
            data = couler.run_step(_SqlSteps.select, stmt.table, None,
                                   step_name="select")
            model = couler.run_step(_SqlSteps.load_model, stmt.model,
                                    registry=model_registry,
                                    step_name="load-model")
            couler.run_step(_SqlSteps.predict, data, model,
                            output=stmt.output, step_name="predict")
    return ir


def run_sql(sql: str, engine=None, model_registry: Optional[Dict] = None):
    """Parse, lower and execute one statement; returns the WorkflowRun."""
    from repro.core.engines.local import LocalEngine
    ir = to_workflow(sql, model_registry=model_registry)
    if engine is None:
        # throwaway engine: release its gateway threads after the run
        engine = LocalEngine()
        try:
            return engine.submit(ir)
        finally:
            engine.close()
    return engine.submit(ir)
