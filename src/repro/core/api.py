"""COULER unified programming interface (paper §II.B, Appendix A, Table V).

The module-level functions mirror the paper's API:

    run_script / run_container / run_job / run_step
    when / equal / map_ / concurrent / exec_while / dag
    create_parameter_artifact / set_dependencies / run(submitter)

Workflows are built into the engine-agnostic IR; ``run(submitter=...)``
hands the IR to any backend engine (local threaded executor, multi-cluster
scheduler, Argo-YAML generator, Airflow generator). In this JAX adaptation a
"container" payload is a Python/JAX callable; image/command are retained for
the YAML backends.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.ir import Condition, Job, Resources, WorkflowIR

_local = threading.local()


class StepOutput:
    """Handle to a step's output artifact; passing it to another step's args
    creates a data edge (implicit workflow construction, paper code 2)."""

    def __init__(self, job_name: str, artifact: str):
        self.job_name = job_name
        self.artifact = artifact

    def __repr__(self):
        return f"StepOutput({self.job_name}:{self.artifact})"


class StreamOutput(StepOutput):
    """Handle to a *streamed* output artifact (``run_stream``/``map_stream``).

    Behaves as a normal ``StepOutput`` everywhere: a non-streaming consumer
    that receives it sees the fully materialized list of chunks. Passing it
    to ``map_stream`` instead wires the consumer chunk-wise onto the
    producer's ``ArtifactChannel`` so both overlap in time."""

    def __repr__(self):
        return f"StreamOutput({self.job_name}:{self.artifact})"


def _wf() -> WorkflowIR:
    wf = getattr(_local, "wf", None)
    if wf is None:
        wf = WorkflowIR("default")
        _local.wf = wf
    return wf


class workflow:
    """Context manager opening a fresh workflow under construction."""

    def __init__(self, name: str = "workflow", **configs):
        self.ir = WorkflowIR(name, configs)

    def __enter__(self) -> WorkflowIR:
        self._prev = getattr(_local, "wf", None)
        _local.wf = self.ir
        return self.ir

    def __exit__(self, *exc):
        _local.wf = self._prev
        return False


def current_workflow() -> WorkflowIR:
    return _wf()


def _unique(name: str) -> str:
    wf = _wf()
    if name not in wf.jobs:
        return name
    i = 2
    while f"{name}-{i}" in wf.jobs:
        i += 1
    return f"{name}-{i}"


def _add_step(name, fn, args, kwargs, *, kind, image="", command=None,
              resources=None, step_name=None, cacheable=True,
              est_time_s=1.0, est_mem_bytes=1 << 20, retry_limit=3) -> StepOutput:
    wf = _wf()
    name = step_name or name
    if getattr(_local, "in_dag", False) and name in wf.jobs:
        # explicit-DAG merge semantics (paper's diamond): re-invoking a step
        # with the same name references the existing node
        return StepOutput(name, wf.jobs[name].outputs[0])
    name = _unique(name)
    inputs, clean_args = [], []
    for a in (args or ()):
        if isinstance(a, StepOutput):
            inputs.append(a.artifact)
            clean_args.append(a)
        else:
            clean_args.append(a)
    out_art = f"{name}:out"
    job = Job(name=name, fn=fn, args=tuple(clean_args), kwargs=dict(kwargs or {}),
              inputs=inputs, outputs=[out_art], kind=kind, image=image,
              command=list(command or []),
              resources=resources or Resources(), cacheable=cacheable,
              est_time_s=est_time_s, est_mem_bytes=est_mem_bytes,
              retry_limit=retry_limit)
    wf.add_job(job)
    for a in inputs:
        src = a.split(":")[0]
        if src in wf.jobs:
            wf.add_edge(src, name)
    return StepOutput(name, out_art)


# ---------------------------------------------------------------------------
# paper Table V API
# ---------------------------------------------------------------------------

def run_step(fn: Callable, *args, step_name: Optional[str] = None,
             **kw) -> StepOutput:
    """JAX-native step: fn(*args) runs in a worker (our 'pod')."""
    opts = {k: kw.pop(k) for k in ("resources", "cacheable", "est_time_s",
                                   "est_mem_bytes", "retry_limit")
            if k in kw}
    return _add_step(step_name or getattr(fn, "__name__", "step"), fn, args,
                     kw, kind="job", step_name=step_name, **opts)


def run_stream(fn: Callable, *args, step_name: Optional[str] = None,
               buffer_chunks: int = 8, **kw) -> StreamOutput:
    """Streaming producer step: ``fn(*args)`` must return an iterable (a
    generator, typically) whose items are the output chunks. Downstream
    ``map_stream`` consumers start as soon as the first chunk is emitted;
    any other consumer sees the materialized list of chunks."""
    opts = {k: kw.pop(k) for k in ("resources", "cacheable", "est_time_s",
                                   "est_mem_bytes", "retry_limit")
            if k in kw}
    out = _add_step(step_name or getattr(fn, "__name__", "stream"), fn, args,
                    kw, kind="job", step_name=step_name, **opts)
    job = _wf().jobs[out.job_name]
    job.stream_output = True
    job.stream_buffer_chunks = buffer_chunks
    return StreamOutput(out.job_name, out.artifact)


def map_stream(fn: Callable[[Any], Any], source: StepOutput, *args,
               step_name: Optional[str] = None, buffer_chunks: int = 8,
               **kw) -> StreamOutput:
    """Chunk-wise consumer: applies ``fn(chunk, *args)`` to each chunk of
    ``source`` as it arrives, emitting its own streamed output (so stages
    chain into a pipeline). If ``source`` is not streamed (or its producer
    already finished), the materialized value is iterated instead — same
    results, no overlap."""
    opts = {k: kw.pop(k) for k in ("resources", "cacheable", "est_time_s",
                                   "est_mem_bytes", "retry_limit")
            if k in kw}
    out = _add_step(step_name or getattr(fn, "__name__", "map_stream"), fn,
                    (source,) + args, kw, kind="job", step_name=step_name,
                    **opts)
    job = _wf().jobs[out.job_name]
    job.stream_input = True
    job.stream_arg = source.artifact
    job.stream_output = True
    job.stream_buffer_chunks = buffer_chunks
    return StreamOutput(out.job_name, out.artifact)


def run_script(image: str = "", source: Optional[Callable] = None,
               step_name: Optional[str] = None, **kw) -> StepOutput:
    opts = {k: kw.pop(k) for k in ("resources", "cacheable", "est_time_s",
                                   "est_mem_bytes", "retry_limit")
            if k in kw}
    return _add_step(step_name or getattr(source, "__name__", "script"),
                     source, (), kw, kind="script", image=image,
                     step_name=step_name, **opts)


def run_container(image: str, command: Sequence[str] = (),
                  args: Sequence[Any] = (), step_name: Optional[str] = None,
                  fn: Optional[Callable] = None, output: Any = None,
                  **kw) -> StepOutput:
    opts = {k: kw.pop(k) for k in ("resources", "cacheable", "est_time_s",
                                   "est_mem_bytes", "retry_limit")
            if k in kw}
    return _add_step(step_name or "container", fn, tuple(args), kw,
                     kind="container", image=image, command=command,
                     step_name=step_name, **opts)


def run_job(fn: Callable, *args, num_workers: int = 1,
            step_name: Optional[str] = None, **kw) -> StepOutput:
    """Distributed job (maps to a multi-worker pod group)."""
    res = kw.pop("resources", Resources(cpu=float(num_workers)))
    return _add_step(step_name or getattr(fn, "__name__", "job"), fn, args,
                     kw, kind="job", resources=res, step_name=step_name)


def add_job(fn: Callable, *args, num_workers: int = 1,
            checkpoint: Optional[str] = None,
            step_name: Optional[str] = None, **kw) -> StepOutput:
    """Long (training-shaped) job with optional checkpoint-resume.

    With ``checkpoint=dir`` the step is checkpoint-wired: ``fn`` is
    called with an extra ``ckpt=`` keyword — a
    ``repro.training.checkpoint.StepCheckpointSession`` whose
    ``latest_step()`` / ``restore()`` / ``save(step, state)`` persist
    progress under ``dir`` — so a mid-step worker loss resumes from the
    latest checkpoint instead of the step's start (the engine retries the
    step, and the fn finds its own saved progress). Checkpoint-wired
    steps never speculate (two racers would share one directory).
    """
    res = kw.pop("resources", Resources(cpu=float(num_workers)))
    opts = {k: kw.pop(k) for k in ("cacheable", "est_time_s",
                                   "est_mem_bytes", "retry_limit")
            if k in kw}
    out = _add_step(step_name or getattr(fn, "__name__", "job"), fn, args,
                    kw, kind="job", resources=res, step_name=step_name,
                    **opts)
    if checkpoint:
        _wf().jobs[out.job_name].checkpoint = str(checkpoint)
    return out


def equal(a, b=None) -> Condition:
    if isinstance(a, StepOutput):
        return Condition("equal", a.artifact, b)
    return Condition("equal", str(a), b)


def not_equal(a, b=None) -> Condition:
    c = equal(a, b)
    return Condition("not_equal", c.artifact, c.value)


def when(cond: Condition, then: Callable[[], StepOutput]) -> StepOutput:
    """Conditional step (paper code 3): `then()` runs iff cond holds.

    The condition's artifact must already have a producing step —
    a missing producer raises here (CLR003) instead of silently
    evaluating the predicate over ``None`` mid-run."""
    out = then()
    wf = _wf()
    job = wf.jobs[out.job_name]
    job.condition = cond
    wf.check_condition_producers(job)
    src = cond.artifact.split(":")[0]
    if src in wf.jobs and src != out.job_name:
        wf.add_edge(src, out.job_name)
    return out


def exec_while(cond: Condition, body: Callable[[], StepOutput],
               max_iterations: int = 16) -> StepOutput:
    """Recursive step (paper code 5): re-run body while cond holds.

    Like ``when``, the loop condition is validated eagerly (CLR003);
    conditioning on the body step's own output is the normal case."""
    out = body()
    wf = _wf()
    job = wf.jobs[out.job_name]
    job.loop_condition = cond
    job.max_iterations = max_iterations
    wf.check_condition_producers(job)
    return out


def map_(fn: Callable[[Any], StepOutput], items: Sequence[Any]) -> List[StepOutput]:
    """Start one instance of fn per item (paper couler.map, code 6)."""
    return [fn(x) for x in items]


# keep the paper's exact name available too
map = map_  # noqa: A001


def concurrent(fns: Sequence[Callable[[], Any]]) -> List[Any]:
    """Run several steps with no edges between them (paper code 7)."""
    return [f() for f in fns]


def dag(chains: Sequence[Sequence[Callable[[], StepOutput]]]) -> None:
    """Explicit DAG definition (paper §II.B code 1): each chain is a list of
    thunks; consecutive thunks get dependency edges. Thunks naming an
    existing step (same step_name) are merged — the diamond example."""
    wf = _wf()
    _local.in_dag = True
    try:
        for chain in chains:
            prev: Optional[str] = None
            for thunk in chain:
                before = set(wf.jobs)
                out = thunk()
                name = out.job_name if isinstance(out, StepOutput) else None
                if name is None:
                    new = set(wf.jobs) - before
                    name = next(iter(new)) if new else None
                if prev is not None and name is not None and prev != name:
                    wf.add_edge(prev, name)
                prev = name
    finally:
        _local.in_dag = False


def set_dependencies(step: StepOutput, depends_on: Sequence[StepOutput]) -> None:
    for d in depends_on:
        _wf().add_edge(d.job_name, step.job_name)


def create_parameter_artifact(path: str = "", is_global: bool = False):
    class _Art:
        def __init__(self, p):
            self.path = p
    return _Art(path)


def lint(workflow_ir: Optional[WorkflowIR] = None, *, engine=None,
         clusters=None, max_inflight_steps: Optional[int] = None):
    """Statically analyze a workflow (the current one by default).

    Returns a ``repro.core.analysis.LintResult`` of typed ``CLR0xx``
    diagnostics — cycles, orphans, conditions on unproduced artifacts,
    streaming misuse, unschedulable resource requests, nondeterministic
    cacheable steps (see ``docs/diagnostics.md``). Engines run the same
    passes automatically at submit time (``lint="error"|"warn"|"off"``).
    """
    from repro.core.analysis import lint as _lint
    return _lint(workflow_ir or _wf(), engine=engine, clusters=clusters,
                 max_inflight_steps=max_inflight_steps)


def run(submitter=None, workflow_ir: Optional[WorkflowIR] = None,
        optimize: bool = True, **kw):
    """Submit the current workflow to an engine (paper §II.F)."""
    wf = workflow_ir or _wf()
    wf.validate()
    if submitter is None:
        # throwaway engine: release its gateway loop + worker pool after
        # the run instead of leaking one thread set per couler.run() call
        from repro.core.engines.local import LocalEngine
        submitter = LocalEngine()
        try:
            return submitter.submit(wf, optimize=optimize, **kw)
        finally:
            submitter.close()
    return submitter.submit(wf, optimize=optimize, **kw)


async def run_async(submitter=None, workflow_ir: Optional[WorkflowIR] = None,
                    optimize: bool = True, tenant: str = "default",
                    priority: int = 0, **kw):
    """Submit the current workflow through the async gateway path.

    Returns an ``AsyncWorkflowRun``: ``await`` it for the finished
    ``WorkflowRun``, iterate ``.events()`` for typed lifecycle events, or
    ``.cancel()`` for cooperative cancellation. Admission is backpressured
    per tenant — a full queue raises ``gateway.QueueFull`` (shed load)."""
    wf = workflow_ir or _wf()
    wf.validate()
    if submitter is None:
        from repro.core.engines.local import LocalEngine
        submitter = LocalEngine()
        handle = await submitter.submit_async(wf, optimize=optimize,
                                              tenant=tenant,
                                              priority=priority, **kw)
        # throwaway engine: tear its gateway down once the run finishes
        # (the callback fires on the gateway loop; stop() self-schedules)
        handle._result.add_done_callback(lambda _f: submitter.close())
        return handle
    return await submitter.submit_async(wf, optimize=optimize, tenant=tenant,
                                        priority=priority, **kw)


async def stream(submitter=None, workflow_ir: Optional[WorkflowIR] = None,
                 optimize: bool = True, tenant: str = "default",
                 priority: int = 0, **kw):
    """Async generator of gateway lifecycle events for the current
    workflow: yields ``WorkflowEvent``s in order, ending with the single
    terminal ``WORKFLOW_DONE`` (see ``repro.core.gateway`` for the
    taxonomy)."""
    handle = await run_async(submitter=submitter, workflow_ir=workflow_ir,
                             optimize=optimize, tenant=tenant,
                             priority=priority, **kw)
    async for ev in handle.events():
        yield ev


def observe(engine, collector=None):
    """Attach an observability collector to ``engine`` (span trees +
    ``run.report()`` critical-path breakdowns for every subsequent run).
    Returns the ``ObsCollector``; see ``repro.core.obs``."""
    from repro.core import obs
    return obs.observe(engine, collector)


def telemetry(engine, interval_s: float = 0.25, anomaly=None, slos=None,
              path=None):
    """Turn on continuous fleet telemetry on ``engine``'s gateway: a
    ``TimeSeriesDB`` sampled every ``interval_s`` seconds, streaming
    anomaly detection (``anomaly`` — an ``AnomalyMonitor``; one with the
    default detectors is created when None), and optional per-tenant SLO
    burn-rate alerting (``slos`` — an ``SLOMonitor`` or an iterable of
    ``SLO`` objectives). JSONL persistence when ``path`` is given.
    Returns ``(tsdb, anomaly_monitor, slo_monitor)``; see
    ``docs/observability.md``."""
    from repro.core.obs.anomaly import AnomalyMonitor
    from repro.core.obs.slo import SLOMonitor
    gw = getattr(engine, "gateway", None)
    if gw is None or not hasattr(gw, "start_telemetry"):
        raise TypeError(
            f"engine {type(engine).__name__} has no gateway — nothing to "
            "sample (MultiClusterEngine: use attach_telemetry instead)")
    if anomaly is None:
        anomaly = AnomalyMonitor()
    slo_mon = None
    if slos is not None:
        slo_mon = slos if isinstance(slos, SLOMonitor) else SLOMonitor(slos)
    return gw.start_telemetry(interval_s=interval_s, anomaly=anomaly,
                              slo=slo_mon, path=path)


def reset() -> None:
    _local.wf = WorkflowIR("default")
