"""Automatic hyperparameter tuning (paper §IV.C, Algorithm 4).

Data Card (Gebru et al.) + Model Card (Mitchell et al.) + a candidate
hyperparameter set H -> the LLM predicts a training log per h_i (no real
hardware), and the tuner picks h_t with the best predicted performance.
``validate_on_real_model`` then ACTUALLY trains a small JAX model with the
chosen h_t vs the baselines (our Fig. 8 analog in benchmarks).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.core.llm import SurrogateLLM


@dataclass
class DataCard:
    """Paper: dataset name, input type, label space, default eval metrics."""
    name: str
    input_type: str = "text"
    label_space: str = "tokens"
    eval_metric: str = "loss"
    n_examples: int = 100_000
    seq_len: int = 256

    def as_dict(self) -> Dict[str, Any]:
        return self.__dict__.copy()


@dataclass
class ModelCard:
    """Paper: model name, structure, descriptions, architecture hparams."""
    name: str
    structure: str = "decoder-transformer"
    description: str = ""
    n_params: int = 10_000_000
    arch_hparams: Dict[str, Any] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        d = self.__dict__.copy()
        d.pop("arch_hparams")
        d.update(self.arch_hparams)
        return d


def default_search_space() -> List[Dict[str, Any]]:
    lrs = [1e-4, 3e-4, 1e-3, 3e-3]
    batch = [16, 32, 64]
    wd = [0.0, 0.1]
    return [{"learning_rate": lr, "batch_size": b, "weight_decay": w}
            for lr, b, w in itertools.product(lrs, batch, wd)]


@dataclass
class TuneResult:
    best: Dict[str, Any]
    predicted_logs: List[Dict[str, Any]]
    ranking: List[Dict[str, Any]]


def tune(data_card: DataCard, model_card: ModelCard,
         search_space: Optional[Sequence[Dict[str, Any]]] = None,
         llm: Optional[SurrogateLLM] = None, steps: int = 200) -> TuneResult:
    """Algorithm 4: predicted log per h_i; pick best final metric."""
    llm = llm or SurrogateLLM()
    space = list(search_space or default_search_space())
    logs = [llm.predict_training_log(data_card.as_dict(),
                                     model_card.as_dict(), h, steps=steps)
            for h in space]                                      # lines 3-6
    ranked = sorted(logs, key=lambda d: d["final_loss"])         # lines 7-8
    return TuneResult(best=ranked[0]["hparams"], predicted_logs=logs,
                      ranking=[r["hparams"] for r in ranked])


# ---------------------------------------------------------------------------
# real-model validation (drives the Fig. 8 analog)
# ---------------------------------------------------------------------------

def train_real_model(hparams: Dict[str, Any], *, steps: int = 60,
                     d_model: int = 64, vocab: int = 256, seed: int = 0
                     ) -> Dict[str, Any]:
    """Actually train a tiny JAX LM with the given hyperparameters and
    return its measured loss curve (no surrogate)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch, reduced
    from repro.training import train as TR
    from repro.data.pipeline import synthetic_batches

    spec = get_arch("stablelm-1.6b")
    cfg = reduced(spec.model).replace(
        d_model=d_model, vocab_size=vocab, pad_vocab_multiple=16,
        param_dtype="float32", compute_dtype="float32")
    tcfg = spec.train.__class__(
        optimizer="adamw",
        learning_rate=float(hparams.get("learning_rate", 3e-4)),
        weight_decay=float(hparams.get("weight_decay", 0.1)),
        remat="none")
    bs = int(hparams.get("batch_size", 16))
    state = TR.init_train_state(cfg, tcfg, jax.random.PRNGKey(seed))
    step = jax.jit(TR.make_train_step(cfg, tcfg))
    losses = []
    for i, batch in enumerate(synthetic_batches(
            batch=bs, seq=32, vocab=cfg.vocab_size, seed=seed, n=steps)):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return {"hparams": dict(hparams), "losses": losses,
            "final_loss": sum(losses[-5:]) / 5}
