"""Big-workflow auto-parallelism (paper §IV.B, Algorithm 3).

A workflow whose *budget* C — spec bytes (alpha, the 2MB-CRD analog), step
count (beta, e.g. 200), pod count (gamma) — exceeds the engine limit is split
into multiple sub-workflows by a DFS over the DAG that greedily accumulates
vertices into a candidate until the candidate would exceed the budget
(O(|V|), as in the paper). Cross-sub-workflow data edges become artifact
handoffs through the cache store; sub-workflows whose mutual dependencies
allow it run in parallel (maximum parallelism goal, Eq. 1).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.ir import WorkflowIR


@dataclass(frozen=True)
class Budget:
    """C = alpha + beta + gamma (paper defaults: 2MB spec, 200 steps)."""
    spec_bytes: float = 2 * 1024 * 1024
    steps: float = 200
    pods: float = 512

    def exceeded_by(self, wf_budget: Dict[str, float]) -> bool:
        return (wf_budget["spec_bytes"] > self.spec_bytes
                or wf_budget["steps"] > self.steps
                or wf_budget["pods"] > self.pods)


def _budget_of(wf: WorkflowIR, names: Sequence[str]) -> Dict[str, float]:
    jobs = [wf.jobs[n] for n in names]
    return {"spec_bytes": sum(j.spec_size_bytes() for j in jobs),
            "steps": float(len(jobs)),
            "pods": sum(max(1.0, j.resources.cpu) for j in jobs)}


def split_workflow(wf: WorkflowIR, budget: Optional[Budget] = None
                   ) -> List[WorkflowIR]:
    """Algorithm 3. Returns sub-workflows in a valid execution order:
    every cross-edge goes from an earlier to a later sub-workflow."""
    budget = budget or Budget()
    if not budget.exceeded_by(wf.budget()):         # lines 9-11: fits whole
        return [wf]

    # DFS over the DAG in topological order (ensures cross-edges only flow
    # forward across sub-workflow boundaries). The candidate's budget is
    # accumulated incrementally — each vertex contributes its (spec bytes,
    # step, pods) terms exactly once — instead of re-deriving the whole
    # candidate's budget (an O(|cand|) json serialization) at every vertex.
    visited: Set[str] = set()
    cand: List[str] = []
    out_groups: List[List[str]] = []
    acc = {"spec_bytes": 0.0, "steps": 0.0, "pods": 0.0}

    def flush():
        if cand:
            out_groups.append(list(cand))
            cand.clear()
            acc["spec_bytes"] = acc["steps"] = acc["pods"] = 0.0

    def visit(v: str):
        if v in visited:
            return
        visited.add(v)
        job = wf.jobs[v]
        spec = job.spec_size_bytes()
        pods = max(1.0, job.resources.cpu)
        trial = {"spec_bytes": acc["spec_bytes"] + spec,
                 "steps": acc["steps"] + 1.0,
                 "pods": acc["pods"] + pods}
        if budget.exceeded_by(trial):                   # lines 15-19
            flush()
        cand.append(v)
        acc["spec_bytes"] += spec
        acc["steps"] += 1.0
        acc["pods"] += pods
        for nxt in sorted(wf.successors(v)):            # lines 21-24
            # only descend once all predecessors are visited (DAG safety)
            if all(p in visited for p in wf.predecessors(nxt)):
                visit(nxt)

    for v in wf.topo_order():                           # lines 3-6
        visit(v)
    flush()

    subs = [wf.subgraph(g, f"{wf.name}-part{i}")
            for i, g in enumerate(out_groups)]
    return subs


def cross_edges(wf: WorkflowIR, subs: Sequence[WorkflowIR]
                ) -> List[Tuple[str, str, int, int]]:
    """(src_job, dst_job, src_part, dst_part) for edges crossing parts."""
    owner: Dict[str, int] = {}
    for i, s in enumerate(subs):
        for n in s.jobs:
            owner[n] = i
    out = []
    for s, d in wf.edges:
        if owner[s] != owner[d]:
            out.append((s, d, owner[s], owner[d]))
    return out


def schedule_parts(wf: WorkflowIR, subs: Sequence[WorkflowIR]
                   ) -> List[List[int]]:
    """Waves of sub-workflow indices runnable in parallel (maximum
    parallelism over the part-DAG induced by cross edges)."""
    edges = cross_edges(wf, subs)
    deps: Dict[int, Set[int]] = {i: set() for i in range(len(subs))}
    for _, _, a, b in edges:
        if a != b:
            deps[b].add(a)
    done: Set[int] = set()
    waves: List[List[int]] = []
    remaining = set(range(len(subs)))
    while remaining:
        wave = sorted(i for i in remaining if deps[i] <= done)
        if not wave:
            raise ValueError("cyclic sub-workflow dependency (split bug)")
        waves.append(wave)
        done.update(wave)
        remaining -= set(wave)
    return waves


def validate_split(wf: WorkflowIR, subs: Sequence[WorkflowIR],
                   budget: Budget) -> None:
    """Invariants used by the property tests: partition + budget + acyclic."""
    all_names = [n for s in subs for n in s.jobs]
    assert sorted(all_names) == sorted(wf.jobs), "split must partition jobs"
    assert len(set(all_names)) == len(all_names), "no duplicated jobs"
    for i, s in enumerate(subs):
        if len(subs) > 1 and len(s.jobs) > 1:
            # each part respects the budget unless it is a single huge job
            b = _budget_of(s, list(s.jobs))
            assert (b["steps"] <= budget.steps
                    and b["spec_bytes"] <= budget.spec_bytes
                    and b["pods"] <= budget.pods), (i, b)
        s.validate()
    schedule_parts(wf, subs)  # raises on cycles
