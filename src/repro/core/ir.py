"""Workflow Intermediate Representation (paper §II.C).

A workflow is ``G = <J, E, C>`` — jobs, edges, configurations — engine- and
platform-agnostic. All optimizers (caching §IV.A, auto-parallel split §IV.B)
and all backend generators (Argo YAML, Airflow DAG, local/cluster executors)
operate on this IR, which is what makes the programming interface unified.

Adjacency & cache-invalidation contract
---------------------------------------
``WorkflowIR`` maintains indexed adjacency maps (``_preds``/``_succs``)
incrementally so ``predecessors()``/``successors()`` are O(degree) instead
of O(|E|) — these are the inner-loop primitives of every scheduler, cache
scorer, and the auto-split DFS. Derived structure (topological order, the
default-order adjacency matrix, the name→index map) is computed lazily and
cached. The rules:

* All structural mutation MUST go through ``add_job``/``add_edge`` (or the
  constructors ``from_json``/``subgraph``). Direct writes to ``self.jobs``
  or ``self.edges`` bypass the indices and are unsupported.
* Every structural mutation bumps ``structure_version`` and drops the
  cached topo order / adjacency matrix / index map.
* Mutating *job attributes* (``est_time_s``, ``resources`` …) does not
  change structure, so it does not touch the caches above — but consumers
  that memoize attribute-dependent quantities (e.g. the cache scorer's
  reconstruction cost, Eq. 3) key their memos on ``weights_version``;
  engines that refine time estimates call ``note_weights_changed()``.
* ``topo_order()``/``adjacency()`` return fresh copies; callers may mutate
  the returned list/array freely.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np


@dataclass
class Resources:
    cpu: float = 1.0
    mem_bytes: int = 1 << 28
    gpu: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


@dataclass
class Condition:
    """Runtime predicate on an upstream artifact: kind in {equal, not_equal,
    greater, less, truthy}."""
    kind: str
    artifact: str
    value: Any = None

    def evaluate(self, artifacts: Dict[str, Any]) -> bool:
        v = artifacts.get(self.artifact)
        if self.kind == "equal":
            return v == self.value
        if self.kind == "not_equal":
            return v != self.value
        if self.kind == "greater":
            return v > self.value
        if self.kind == "less":
            return v < self.value
        return bool(v)


@dataclass
class Job:
    name: str
    fn: Optional[Callable] = None
    args: Tuple = ()
    kwargs: Dict[str, Any] = field(default_factory=dict)
    inputs: List[str] = field(default_factory=list)    # artifact names
    outputs: List[str] = field(default_factory=list)
    resources: Resources = field(default_factory=Resources)
    retry_limit: int = 3
    kind: str = "script"                               # script|container|job
    image: str = ""
    command: List[str] = field(default_factory=list)
    condition: Optional[Condition] = None
    est_time_s: float = 1.0
    est_mem_bytes: int = 1 << 20
    cacheable: bool = True
    # loop metadata (exec_while)
    loop_condition: Optional[Condition] = None
    max_iterations: int = 16
    # streaming metadata: a stream_output job's fn is a generator whose
    # chunks flow through an ArtifactChannel; a stream_input job maps the
    # chunks of the upstream artifact named by stream_arg. A non-streaming
    # consumer of a streamed output sees the materialized list of chunks.
    stream_output: bool = False
    stream_input: bool = False
    stream_arg: Optional[str] = None
    stream_buffer_chunks: int = 8
    # checkpoint-wired step (couler.add_job(..., checkpoint=dir)): the fn
    # receives a ckpt= StepCheckpointSession saving/restoring through
    # training.checkpoint, so an intra-step kill resumes from the latest
    # checkpoint instead of the step's start
    checkpoint: Optional[str] = None

    def spec_size_bytes(self) -> int:
        """Serialized-spec size of this job — the CRD-size budget component."""
        d = {"name": self.name, "kind": self.kind, "image": self.image,
             "command": self.command, "inputs": self.inputs,
             "outputs": self.outputs, "resources": self.resources.as_dict()}
        return len(json.dumps(d))


class WorkflowIR:
    """DAG of jobs with artifact-labelled edges (see module docstring for
    the adjacency/invalidations contract)."""

    def __init__(self, name: str, configs: Optional[Dict] = None):
        self.name = name
        self.jobs: Dict[str, Job] = {}
        self.edges: Set[Tuple[str, str]] = set()
        self.configs: Dict[str, Any] = configs or {}
        # incrementally maintained adjacency indices
        self._preds: Dict[str, Set[str]] = {}
        self._succs: Dict[str, Set[str]] = {}
        # cheap acyclicity witness: job -> insertion index, and whether any
        # edge ever pointed from a later-inserted job to an earlier one.
        # All edges forward w.r.t. insertion order => acyclic, so the lint
        # cycle pass can skip its Kahn sweep for API-built workflows.
        self._insert_idx: Dict[str, int] = {}
        self._has_back_edge = False
        # lazily computed derived structure, dropped on mutation
        self._topo_cache: Optional[List[str]] = None
        self._index_cache: Optional[Dict[str, int]] = None
        self._adj_cache: Optional[np.ndarray] = None
        self._struct_version = 0
        self._weights_version = 0
        self._weights_counter = itertools.count(1)

    # -- versioning --------------------------------------------------------
    @property
    def structure_version(self) -> int:
        """Bumped on every add_job/add_edge; keys structural memos."""
        return self._struct_version

    @property
    def weights_version(self) -> int:
        """Bumped via note_weights_changed(); keys attribute-dependent
        memos (est_time_s feeds Eq. 3's w_i)."""
        return self._weights_version

    def note_weights_changed(self) -> None:
        # engines call this from pool worker threads; next() on the shared
        # counter is atomic, so concurrent bumps never collapse into one
        # observable value (a plain += could lose an update and leave
        # memo consumers serving stale Eq. 3 costs)
        self._weights_version = next(self._weights_counter)

    def _invalidate(self) -> None:
        self._struct_version += 1
        self._topo_cache = None
        self._index_cache = None
        self._adj_cache = None

    # -- construction ------------------------------------------------------
    def add_job(self, job: Job, _check_conditions: bool = True) -> Job:
        if job.name in self.jobs:
            return self.jobs[job.name]          # idempotent (paper's dag())
        if _check_conditions:
            self.check_condition_producers(job)
        self.jobs[job.name] = job
        self._insert_idx[job.name] = len(self.jobs)
        self._preds[job.name] = set()
        self._succs[job.name] = set()
        self._invalidate()
        return job

    def check_condition_producers(self, job: Job) -> None:
        """Eagerly reject a condition on an artifact nothing produces
        (diagnostic CLR003): the predicate could only ever evaluate over
        ``None``, so the mistake surfaced mid-run at the earliest. A job
        may condition on its own output (``exec_while`` loops do)."""
        for label, cond in (("condition", job.condition),
                            ("loop condition", job.loop_condition)):
            if cond is None:
                continue
            producer = cond.artifact.split(":")[0]
            if producer != job.name and producer not in self.jobs:
                raise ValueError(
                    f"workflow {self.name!r}: step {job.name!r} has a "
                    f"{label} on artifact {cond.artifact!r}, but no step "
                    f"named {producer!r} produces it (CLR003); add the "
                    f"producing step first or drop the condition")

    def add_edge(self, src: str, dst: str) -> None:
        if src not in self.jobs or dst not in self.jobs:
            raise KeyError(f"edge references unknown job: {src}->{dst}")
        if src == dst:
            raise ValueError(f"self-edge on {src}")
        if (src, dst) in self.edges:
            return                              # idempotent, keep caches
        self.edges.add((src, dst))
        if self._insert_idx[src] > self._insert_idx[dst]:
            self._has_back_edge = True
        self._succs[src].add(dst)
        self._preds[dst].add(src)
        self._invalidate()

    # -- structure ---------------------------------------------------------
    @property
    def job_names(self) -> List[str]:
        return list(self.jobs)

    def predecessors(self, name: str) -> List[str]:
        return list(self._preds.get(name, ()))

    def successors(self, name: str) -> List[str]:
        return list(self._succs.get(name, ()))

    def in_degree(self, name: str) -> int:
        return len(self._preds.get(name, ()))

    def out_degree(self, name: str) -> int:
        return len(self._succs.get(name, ()))

    def node_index(self) -> Dict[str, int]:
        """name -> position in job insertion order (cached)."""
        if self._index_cache is None:
            self._index_cache = {n: i for i, n in enumerate(self.jobs)}
        return self._index_cache

    def adjacency(self, order: Optional[Sequence[str]] = None) -> np.ndarray:
        if order is None:
            if self._adj_cache is None:
                self._adj_cache = self._build_adjacency(list(self.jobs))
            return self._adj_cache.copy()
        return self._build_adjacency(list(order))

    def _build_adjacency(self, order: List[str]) -> np.ndarray:
        idx = {n: i for i, n in enumerate(order)}
        A = np.zeros((len(order), len(order)), dtype=np.float64)
        for s in order:
            i = idx.get(s)
            if i is None:
                continue
            for d in self._succs.get(s, ()):
                j = idx.get(d)
                if j is not None:
                    A[i, j] = 1.0
        return A

    def degrees(self, order: Optional[Sequence[str]] = None) -> np.ndarray:
        A = self.adjacency(order)
        return A.sum(0) + A.sum(1)

    def topo_order(self) -> List[str]:
        if self._topo_cache is not None:
            return list(self._topo_cache)
        indeg = {n: len(self._preds[n]) for n in self.jobs}
        ready = deque(sorted(n for n, k in indeg.items() if k == 0))
        out: List[str] = []
        while ready:
            n = ready.popleft()
            out.append(n)
            for d in sorted(self._succs[n]):
                indeg[d] -= 1
                if indeg[d] == 0:
                    ready.append(d)
        if len(out) != len(self.jobs):
            raise ValueError(f"workflow {self.name} contains a cycle")
        self._topo_cache = out
        return list(out)

    def validate(self) -> None:
        self.topo_order()
        for s, d in self.edges:
            assert s in self.jobs and d in self.jobs

    def critical_path(self) -> Tuple[float, List[str]]:
        """Longest chain by est_time_s (paper Eq. 1: T = max over paths)."""
        finish: Dict[str, float] = {}
        parent: Dict[str, Optional[str]] = {}
        for n in self.topo_order():
            base, p = 0.0, None
            for q in self._preds[n]:
                if finish[q] > base:
                    base, p = finish[q], q
            finish[n] = base + self.jobs[n].est_time_s
            parent[n] = p
        if not finish:
            return 0.0, []
        end = max(finish, key=finish.get)
        path = [end]
        while parent[path[-1]] is not None:
            path.append(parent[path[-1]])
        return finish[end], list(reversed(path))

    def peak_parallel_mem(self) -> float:
        """Paper Eq. 2 proxy: S = max over antichains of summed job memory.
        Approximated by levels of the topological order."""
        level: Dict[str, int] = {}
        for n in self.topo_order():
            level[n] = 1 + max((level[p] for p in self._preds[n]), default=-1)
        by_level: Dict[int, float] = {}
        for n, l in level.items():
            by_level[l] = by_level.get(l, 0.0) + self.jobs[n].est_mem_bytes
        return max(by_level.values(), default=0.0)

    # -- budget (paper §IV.B): C = alpha(spec bytes) + beta(steps) + gamma(pods)
    def budget(self) -> Dict[str, float]:
        alpha = sum(j.spec_size_bytes() for j in self.jobs.values())
        beta = len(self.jobs)
        gamma = sum(max(1.0, j.resources.cpu) for j in self.jobs.values())
        return {"spec_bytes": alpha, "steps": beta, "pods": gamma}

    def subgraph(self, names: Sequence[str], name: str) -> "WorkflowIR":
        sub = WorkflowIR(name, dict(self.configs))
        keep = set(names)
        for n in names:
            # shares Job objects; a condition's producer may land in a
            # sibling part, so the eager CLR003 check is skipped here
            sub.add_job(self.jobs[n], _check_conditions=False)
        for n in names:
            for d in self._succs.get(n, ()):
                if d in keep:
                    sub.add_edge(n, d)
        return sub

    # -- serialization -----------------------------------------------------
    def to_json(self) -> str:
        def job_dict(j: Job):
            d = {k: v for k, v in dataclasses.asdict(j).items()
                 if k not in ("fn", "args", "kwargs", "condition",
                              "loop_condition", "resources")}
            d["resources"] = j.resources.as_dict()
            if j.condition:
                d["condition"] = dataclasses.asdict(j.condition)
            if j.loop_condition:
                d["loop_condition"] = dataclasses.asdict(j.loop_condition)
            return d
        return json.dumps({
            "name": self.name,
            "configs": {k: v for k, v in self.configs.items()
                        if isinstance(v, (int, float, str, bool, list, dict))},
            "jobs": [job_dict(j) for j in self.jobs.values()],
            "edges": sorted(self.edges),
        }, indent=1, default=str)

    @classmethod
    def from_json(cls, text: str) -> "WorkflowIR":
        d = json.loads(text)
        wf = cls(d["name"], d.get("configs", {}))
        for jd in d["jobs"]:
            cond = jd.pop("condition", None)
            loop = jd.pop("loop_condition", None)
            res = jd.pop("resources", None)
            job = Job(**{k: v for k, v in jd.items()
                         if k in {f.name for f in dataclasses.fields(Job)}})
            if res:
                job.resources = Resources(**res)
            if cond:
                job.condition = Condition(**cond)
            if loop:
                job.loop_condition = Condition(**loop)
            wf.add_job(job)
        for s, d_ in d["edges"]:
            wf.add_edge(s, d_)
        return wf

    def fingerprint(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()[:16]
