"""Tiered artifact store: Algorithm 2 generalized to MEM/SSD/REMOTE.

``TieredCacheStore`` composes an ordered list of ``CacheTier``s (fastest
first). Behavior per paper §IV.A, extended for multi-stage caching:

* **Admission** lands in the highest tier that can hold the artifact
  (normally MEM) and follows Algorithm 2 within that tier: if the tier is
  full, the newcomer's Eq. 6 score is compared against the tier's
  lowest-scored occupants.
* **Demotion cascades**: an occupant displaced from tier *t* is offered to
  tier *t+1* under the same contest rather than dropped; only artifacts
  displaced off the LAST tier are truly evicted. A newcomer that loses its
  contest cascades down the same way and is only ``rejected`` when it
  loses at the last tier.
* **Promotion** is a background pass (``promote()``, optionally automatic
  every ``auto_promote_every`` hits): every artifact is re-ranked by the
  policy's ``promotion_scores`` — for ``CoulerPolicy`` the Eq. 6 factor
  with observed hits folded into Eq. 4's reuse events and V(u) normalized
  per tier — and the ranking is greedily re-packed into the tiers, so hot
  artifacts climb back toward MEM and cold ones sink.
* **Sharing**: a ``SharedRemoteTier`` may be attached to several stores;
  ``promote()`` never steals from it — promoting a shared artifact into a
  private tier *copies* it up, leaving the remote replica for other
  clusters.

Eviction candidates come from per-tier lazily invalidated min-heaps keyed
on (store epoch, tier version): mutations only bump counters, and a heap
is rebuilt — through the policy's Eq. 3/4 memos, so unchanged items cost
O(1) — the next time a victim is actually needed.

``CacheStore`` (the legacy single-tier API from ``repro.core.caching``) is
a facade: one MEM-like tier, so Algorithm 2 degenerates to exactly the
pre-tier behavior — losing newcomers are rejected, displaced occupants are
evicted — with the same ``offer``/``get``/stats surface.

Chunk-granular entries (streaming pipelines)
--------------------------------------------
A streaming step with cache key ``K`` offers each chunk *i* under
``"K#c{i}"`` and, after the stream closes, a manifest ``"K#n"`` holding the
chunk count. The store itself treats these as ordinary artifacts — they are
admitted, demoted, promoted, and evicted independently, so the byte
ledger and Eq. 6 scoring need no special cases and a chunk run may span
MEM/SSD/REMOTE. The *contract* lives in the key scheme: the manifest is
offered last, so its presence promises the full run was offered once; a
replaying engine probes ``K#c0, K#c1, …`` until the first miss and
recomputes only the tail (chunks evicted mid-run simply shorten the
replayable prefix). Chunk streams are deterministic — equal key implies
equal chunk sequence — which is what makes a cached prefix + recomputed
tail equivalent to a full recompute.

Concurrent scoring contexts
---------------------------
``attach_workflow`` registers (not replaces) a workflow: many concurrent
runs may share one store, and each offered artifact carries a weakref to
its own producer DAG (``CachedArtifact.wf_ref``), which the Couler policy
scores against — so interleaved workflows no longer thrash the Eq. 3/4
memo or each other's frontier. ``store.workflow`` remains the most
recently attached DAG, used only as the fallback for artifacts offered
without a workflow.
"""
from __future__ import annotations

import heapq
import time
import weakref
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cache.policies import CachePolicy, CoulerPolicy
from repro.core.cache.scoring import CachedArtifact, sizeof
from repro.core.cache.tiers import (CacheTier, TierSpec, mem_spec,
                                    remote_spec, ssd_spec)
from repro.core.ir import WorkflowIR
from repro.core.obs.metrics import MetricsRegistry, StatsView


class _TierView:
    """Legacy ``CacheStore`` surface handed to policies when scoring one
    tier: V(u) normalizes to the TIER capacity, while ``items`` spans the
    whole store so Eq. 3's cached frontier sees every tier."""

    __slots__ = ("items", "capacity_bytes", "workflow")

    def __init__(self, items: Dict[str, CachedArtifact],
                 capacity_bytes: int, workflow: Optional[WorkflowIR]):
        self.items = items
        self.capacity_bytes = capacity_bytes
        self.workflow = workflow


class TieredCacheStore:
    """Multi-tier artifact store; see module docstring for semantics."""

    _STAT_KEYS = ("hits", "misses", "evictions", "admitted", "rejected",
                  "refreshed", "demotions", "promotions", "promote_passes",
                  "fetch_s", "score_time_s")

    def __init__(self, tiers: Optional[Sequence[CacheTier]] = None,
                 policy: Optional[CachePolicy] = None, name: str = "store",
                 auto_promote_every: int = 0,
                 registry: Optional[MetricsRegistry] = None):
        import threading
        self.name = name
        self.tiers: List[CacheTier] = (list(tiers) if tiers is not None
                                       else default_tiers())
        if not self.tiers:
            raise ValueError("need at least one tier")
        self.policy = policy or CoulerPolicy()
        self.workflow: Optional[WorkflowIR] = None
        self.auto_promote_every = auto_promote_every
        self._hits_since_promote = 0
        # hits THIS store served from shared tiers, by artifact name —
        # gates promote()'s copy-up so only locally hot replicas copy up
        self._shared_uses: Dict[str, int] = {}
        self._insertions = 0
        self._lock = threading.RLock()      # engines offer() from workers
        # per-event counters live in a metrics registry (fetch_s /
        # score_time_s are float counters there too); ``stats`` is a
        # dict-compatible view so the legacy surface survives unchanged
        self.registry = registry if registry is not None \
            else MetricsRegistry("cache")
        self._m = {k: self.registry.counter(
                       f"cache_{k}_total" if k not in ("fetch_s",
                                                       "score_time_s")
                       else f"cache_{k}", store=self.name)
                   for k in self._STAT_KEYS}
        self.registry.gauge_fn(f"cache_used_bytes{{store={self.name}}}",
                               lambda: self.used_bytes)
        for t in self.tiers:
            self.registry.gauge_fn(
                f"cache_tier_used_bytes{{store={self.name},tier={t.name}}}",
                (lambda tier: lambda: tier.used_bytes)(t))
        if hasattr(self.policy, "bind_metrics"):
            self.policy.bind_metrics(self.registry)
        self._epoch = 0                     # bumped on score-moving changes
        # per-tier lazily invalidated (score, insertion, name) min-heaps
        self._heaps: List[List[Tuple[float, int, str]]] = \
            [[] for _ in self.tiers]
        self._heap_keys: List[Optional[Tuple[int, int]]] = \
            [None for _ in self.tiers]
        self._wf_versions: Optional[Tuple] = None
        # every workflow whose artifacts may live here (weak: a finished
        # run's DAG must not be pinned by the cache); scoring contexts are
        # per-artifact via CachedArtifact.wf_ref
        self._workflows: "weakref.WeakValueDictionary[int, WorkflowIR]" = \
            weakref.WeakValueDictionary()

    @property
    def stats(self) -> StatsView:
        return StatsView(self._m)

    # -- legacy surface ----------------------------------------------------
    @property
    def items(self) -> Dict[str, CachedArtifact]:
        """Merged name → artifact view across tiers (upper tiers win)."""
        merged: Dict[str, CachedArtifact] = {}
        for t in reversed(self.tiers):
            merged.update(t.snapshot_items() if t.shared else t.items)
        return merged

    @property
    def used_bytes(self) -> int:
        return sum(t.used_bytes for t in self.tiers)

    @property
    def capacity_bytes(self) -> int:
        return sum(t.capacity_bytes for t in self.tiers)

    def attach_workflow(self, wf: WorkflowIR) -> None:
        """Register ``wf`` as a scoring context (additive — concurrent
        workflows sharing the store do not displace each other; re-attaching
        an already-registered workflow is free and bumps nothing)."""
        with self._lock:
            self.workflow = wf
            k = id(wf)
            if self._workflows.get(k) is not wf:
                self._workflows[k] = wf
                self._epoch += 1

    def hit_ratio(self) -> float:
        h = self._m["hits"].value
        tot = h + self._m["misses"].value
        return h / tot if tot else 0.0

    def contains(self, name: str) -> bool:
        return any(name in t.items for t in self.tiers)

    def find_tier(self, name: str) -> Optional[CacheTier]:
        """Highest tier holding `name`; no stats mutation (placement
        planners use this to price a fetch without recording a hit)."""
        for t in self.tiers:
            if name in t.items:
                return t
        return None

    # -- access ------------------------------------------------------------
    def get(self, name: str) -> Optional[CachedArtifact]:
        with self._lock:
            for t in self.tiers:
                art = t.items.get(name)
                if art is None:
                    continue
                art.last_used = time.time()
                art.uses += 1
                t.record_hit(self.name)
                if t.shared:
                    # per-STORE use count: art.uses aggregates every
                    # attached cluster, so promote()'s copy-up eligibility
                    # must not key on it (a cluster would replicate
                    # artifacts only its siblings ever touched)
                    if len(self._shared_uses) >= 4096:
                        self._shared_uses.clear()
                    self._shared_uses[name] = \
                        self._shared_uses.get(name, 0) + 1
                self._m["hits"].inc()
                self._m["fetch_s"].inc(t.access_time_s(art.bytes))
                self._epoch += 1            # last_used moved (LRU scores)
                if self.auto_promote_every:
                    self._hits_since_promote += 1
                    if self._hits_since_promote >= self.auto_promote_every:
                        self._hits_since_promote = 0
                        self.promote()
                return art
            self._m["misses"].inc()
            return None

    def offer(self, name: str, value: Any, compute_time_s: float,
              producer: str, nbytes: Optional[int] = None,
              workflow: Optional[WorkflowIR] = None) -> bool:
        """Algorithm 2: try to admit a newly produced artifact, demoting or
        evicting lower-importance items while capacity is exceeded.
        ``workflow`` (optional) pins the artifact's scoring context to its
        own producer DAG; without it scoring falls back to the most
        recently attached workflow."""
        b = nbytes if nbytes is not None else sizeof(value)
        with self._lock:
            if workflow is not None:
                k = id(workflow)
                if self._workflows.get(k) is not workflow:
                    self._workflows[k] = workflow
                    self._epoch += 1
            art = CachedArtifact(name=name, value=value, bytes=b,
                                 compute_time_s=compute_time_s,
                                 producer=producer, insertion=self._insertions,
                                 wf_ref=(weakref.ref(workflow)
                                         if workflow is not None else None))
            self._insertions += 1

            if not self.policy.admit(art):
                self._m["rejected"].inc()
                return False
            start = next((i for i, t in enumerate(self.tiers) if t.fits(b)),
                         None)
            if start is None:
                self._m["rejected"].inc()
                return False
            placed = self._place(art, start, "admitted")
            if placed is None:
                self._m["rejected"].inc()
                return False
            self._drop_stale(name, keep_idx=placed)
            return True

    # -- placement / cascade -----------------------------------------------
    def _place(self, art: CachedArtifact, idx: int,
               reason: str) -> Optional[int]:
        """Place `art` into tier `idx` per Algorithm 2, cascading it (and
        any displaced occupants) downward. Returns the tier index it landed
        in, or None if it fell off the last tier."""
        tier = self.tiers[idx]
        b = art.bytes
        down = idx + 1 if idx + 1 < len(self.tiers) else None
        if not tier.fits(b):
            if down is None:
                return None
            return self._place(art, down, reason)

        new_score: Optional[float] = None
        while True:
            # lines 10-11: fits -> cache it (atomically for shared tiers:
            # a sibling store may fill the tier between check and put)
            if tier.used_bytes + b <= tier.capacity_bytes:
                if self._try_insert(art, idx, reason):
                    return idx
                continue                   # lost the race; contest again
            if not tier.items:
                # unreachable for private tiers (empty => used==0 => the
                # fit branch above would have fired); under shared-tier
                # races just retry the atomic fit path
                if self._try_insert(art, idx, reason):
                    return idx
                continue
            # lines 16-31 (NodeSelection): compare vs lowest-scored items
            if new_score is None:
                self._sync_workflow_versions()
                t0 = time.perf_counter()
                new_score = self.policy.score(art, self._view(idx))
                self._m["score_time_s"].inc(time.perf_counter() - t0)
            ms = self._min_scored(idx)
            if ms is None:
                continue               # shared tier drained under us; retry
            k_min, s_min = ms
            if s_min >= new_score:
                if down is None:
                    return None            # loses at the last tier: drop
                return self._place(art, down, reason)   # cascade the loser
            self._displace(idx, k_min)
            # paper: re-evaluate remaining items after every removal — the
            # epoch bump invalidates the heap; the rebuild is cheap because
            # untouched items hit the policy memos

    def _displace(self, idx: int, name: str) -> None:
        """Remove `name` from tier `idx`; demote it downward, evicting it
        only when displaced off the last tier. Tolerates the victim having
        vanished (a sibling store racing on a shared tier)."""
        tier = self.tiers[idx]
        down = idx + 1 if idx + 1 < len(self.tiers) else None
        if down is None:
            if tier.remove(name, "evicted") is not None:
                self._m["evictions"].inc()
        else:
            victim = tier.remove(name, "demoted")
            if victim is not None and \
                    self._place(victim, down, "demoted") is None:
                self._m["evictions"].inc()
        self._epoch += 1

    def _try_insert(self, art: CachedArtifact, idx: int, reason: str) -> bool:
        """Insert into tier `idx`; for shared tiers the capacity re-check
        and put are one atomic step under the tier lock."""
        tier = self.tiers[idx]
        if not tier.shared:
            self._insert(art, idx, reason)
            return True
        ok, old = tier.put_if_fits(art, reason)
        if ok:
            self._count_insert(old, reason)
        return ok

    def _insert(self, art: CachedArtifact, idx: int, reason: str) -> None:
        self._count_insert(self.tiers[idx].put(art, reason), reason)

    def _count_insert(self, old: Optional[CachedArtifact],
                      reason: str) -> None:
        if old is not None:
            # same-key refresh: replace in place — NOT an eviction (and not
            # a second admission), so policy stats stay comparable
            self._m["refreshed"].inc()
        elif reason == "admitted":
            self._m["admitted"].inc()
        elif reason == "demoted":
            self._m["demotions"].inc()
        elif reason == "promoted":
            self._m["promotions"].inc()
        self._epoch += 1

    def _drop_stale(self, name: str, keep_idx: int) -> None:
        """A fresh version of `name` landed in tier `keep_idx`; any copy in
        another tier (e.g. a previously demoted one) is now stale."""
        for i, t in enumerate(self.tiers):
            if i != keep_idx and name in t.items:
                t.remove(name, "stale")
                self._epoch += 1

    # -- scoring machinery ---------------------------------------------------
    def _view(self, idx: int) -> _TierView:
        return _TierView(self.items, self.tiers[idx].capacity_bytes,
                         self.workflow)

    def _sync_workflow_versions(self) -> None:
        # heaps cache policy scores, which read every registered live
        # workflow's structure/weights versions — any drift invalidates
        wfs: Dict[int, WorkflowIR] = dict(self._workflows)
        if self.workflow is not None:
            wfs[id(self.workflow)] = self.workflow
        v = tuple(sorted((k, w.structure_version, w.weights_version)
                         for k, w in wfs.items()))
        if v != self._wf_versions:
            self._wf_versions = v
            self._epoch += 1

    def _min_scored(self, idx: int) -> Optional[Tuple[str, float]]:
        """Current lowest-scored item of tier `idx`; re-validates the heap
        if the store epoch or the tier version moved. Returns None if the
        tier turned out empty (a sibling store may drain a shared tier
        between the caller's non-empty check and the snapshot here)."""
        tier = self.tiers[idx]
        key = (self._epoch, tier.version)
        if self._heap_keys[idx] != key:
            arts = list((tier.snapshot_items() if tier.shared
                         else tier.items).values())
            t0 = time.perf_counter()
            scores = self.policy.score_many(arts, self._view(idx))
            self._m["score_time_s"].inc(time.perf_counter() - t0)
            heap = [(s, a.insertion, a.name) for s, a in zip(scores, arts)]
            heapq.heapify(heap)
            self._heaps[idx] = heap
            self._heap_keys[idx] = key
        if not self._heaps[idx]:
            return None
        s, _, name = self._heaps[idx][0]
        return name, s

    # -- background promotion ------------------------------------------------
    def promote(self) -> Dict[str, int]:
        """Re-rank all visible artifacts by the policy's promotion score
        and greedily re-pack them into the tiers (best first, highest tier
        with room). Shared-tier artifacts are never displaced by this pass:
        they are only *copied* up (when hot and used here) — the remote
        replica stays for other clusters. Returns {'promoted': n, ...}."""
        moved = {"promoted": 0, "demoted": 0, "copied_up": 0}
        with self._lock:
            self._sync_workflow_versions()
            self._m["promote_passes"].inc()
            entries: List[Tuple[CachedArtifact, int, float]] = []
            private_names = set()
            for i, t in enumerate(self.tiers):
                if not t.shared:
                    private_names.update(t.items)
            t0 = time.perf_counter()
            for i, t in enumerate(self.tiers):
                pool = t.snapshot_items() if t.shared else t.items
                arts = [a for a in pool.values()
                        if not t.shared
                        # shared replicas: only rank copy-up candidates —
                        # served to THIS store and not already private
                        or (a.name in self._shared_uses
                            and a.name not in private_names)]
                if not arts:
                    continue
                scores = self.policy.promotion_scores(arts, self._view(i))
                entries.extend(zip(arts, [i] * len(arts), scores))
            self._m["score_time_s"].inc(time.perf_counter() - t0)

            # plan capacity: each tier's free space plus whatever this
            # store's ranked PRIVATE entries currently occupy in it (shared
            # replicas never leave their tier, so they free nothing)
            plan_free = [t.capacity_bytes - t.used_bytes for t in self.tiers]
            for art, i, _ in entries:
                if not self.tiers[i].shared:
                    plan_free[i] += art.bytes
            entries.sort(key=lambda e: (-e[2], e[0].insertion))

            assign: List[Tuple[CachedArtifact, int, int]] = []
            for art, cur, _ in entries:
                if self.tiers[cur].shared:
                    # copy-up candidate: only tiers above the replica
                    tgt = next((i for i in range(cur)
                                if plan_free[i] >= art.bytes), cur)
                    if tgt != cur:
                        plan_free[tgt] -= art.bytes
                else:
                    tgt = next((i for i in range(len(self.tiers))
                                if plan_free[i] >= art.bytes), cur)
                    plan_free[tgt] -= art.bytes
                assign.append((art, cur, tgt))

            # execute downward moves first so upward moves land in freed
            # space; a move whose target is still full at execution time
            # (plan fragmentation: an artifact that fit nowhere stayed put)
            # is skipped — the pass is a heuristic, capacity is a contract
            assign = ([m for m in assign if m[2] > m[1]]
                      + [m for m in assign if m[2] < m[1]])
            for art, cur, tgt in assign:
                src = self.tiers[cur]
                dst = self.tiers[tgt]
                if src.shared:
                    if (tgt < cur and art.name not in dst.items
                            and dst.used_bytes + art.bytes
                            <= dst.capacity_bytes):
                        dst.put(art, "promoted")   # copy up, keep replica
                        self._m["promotions"].inc()
                        moved["copied_up"] += 1
                    continue                       # shared replicas never sink
                if dst.shared:
                    # demotion into the shared tier: fit-check + put are
                    # atomic (siblings race); replacing an existing replica
                    # of the same key is a net-zero byte refresh
                    ok, _old = dst.put_if_fits(art, "demoted")
                    if not ok:
                        continue
                    src.remove(art.name, "demoted")
                    self._m["demotions"].inc()
                    moved["demoted"] += 1
                    continue
                if dst.used_bytes + art.bytes > dst.capacity_bytes:
                    continue
                kind = "promoted" if tgt < cur else "demoted"
                src.remove(art.name, kind)
                dst.put(art, kind)
                self._m["promotions" if tgt < cur else "demotions"].inc()
                moved[kind] += 1
            if any(m for m in moved.values()):
                self._epoch += 1
        return moved

    # -- invariants ----------------------------------------------------------
    def check_invariants(self) -> None:
        """Tier-consistency assertions: per-tier byte ledgers balance
        (bytes are conserved across demotions/promotions), capacity is
        respected, and a key lives in at most one private tier (plus at
        most one shared replica)."""
        with self._lock:
            seen: Dict[str, str] = {}
            for t in self.tiers:
                t.check_ledger()
                if t.shared:
                    continue
                for n in t.items:
                    assert n not in seen, \
                        (n, "duplicated in private tiers", seen[n], t.name)
                    seen[n] = t.name

    def tier_stats(self) -> Dict[str, Dict[str, int]]:
        return {t.name: dict(t.stats) for t in self.tiers}


def default_tiers(mem_bytes: int = 64 << 20, ssd_bytes: int = 512 << 20,
                  remote: Optional[CacheTier] = None) -> List[CacheTier]:
    """MEM + SSD + REMOTE; pass a SharedRemoteTier as `remote` to share the
    last tier across stores."""
    return [CacheTier(mem_spec(mem_bytes)), CacheTier(ssd_spec(ssd_bytes)),
            remote if remote is not None else CacheTier(remote_spec())]


class CacheStore(TieredCacheStore):
    """Single-tier facade (models the Alluxio tier, §IV.A.1) — the legacy
    ``repro.core.caching.CacheStore`` API over the tiered machinery. With
    one tier there is nowhere to demote: displaced occupants are evicted
    and losing newcomers rejected, exactly Algorithm 2 as before."""

    def __init__(self, capacity_bytes: int = 1 << 30,
                 policy: Optional[CachePolicy] = None):
        tier = CacheTier(TierSpec("MEM", capacity_bytes, 8e9, 2e-6))
        super().__init__(tiers=[tier], policy=policy)

    # direct (live-dict) views: tests index store.items after mutations
    @property
    def items(self) -> Dict[str, CachedArtifact]:
        return self.tiers[0].items

    @property
    def used_bytes(self) -> int:
        return self.tiers[0].used_bytes

    @property
    def capacity_bytes(self) -> int:
        return self.tiers[0].capacity_bytes
