"""Eq. 3-6 scoring primitives for the artifact cache (paper §IV.A).

The *caching importance factor* of artifact u:

    I(u) = alpha * log(1 + L(u)) + beta * F(u)^2 - e^(-V(u))        (Eq. 6)

  L(u)  reconstruction cost over the n-layer predecessor subgraph G_p,
        truncated at already-cached artifacts:
            L(u) = sum_ij A_ij * (w_i + d_i * d_j)                  (Eq. 3)
  F(u)  reuse value over the successor subgraph G_s:
            F(u) = sum_i r / kappa_ui * (zeta_ui + 1)               (Eq. 4)
        with zeta = diag(d) - A (graph Laplacian)                   (Eq. 5)
  V(u)  cache (memory) cost of u, normalized to the holding tier's
        capacity (single-tier stores normalize to the store capacity).

Eq. 4 literal-vs-deviation
--------------------------
Taken literally, zeta_ui = -A_ui makes every DIRECT successor contribute
(zeta + 1) = 0 to F(u), which contradicts Eq. 4's stated intent (F measures
the value of reuse by successors — direct dependents should count *most*).
``reuse_value`` therefore defaults to ``literal_eq4=False``: it keeps the
Laplacian structure but weights by |zeta_ui| so direct dependents dominate.
Pass ``literal_eq4=True`` (or ``CoulerPolicy(literal_eq4=True)``) for the
equation exactly as printed. Both behaviors are pinned by
``tests/test_cache_tiers.py::test_reuse_value_literal_vs_deviation``; the
deviation is the project default until a reference trace says otherwise.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List

import numpy as np

from repro.core.ir import WorkflowIR


def sizeof(value: Any) -> int:
    try:
        import numpy as _np
        if isinstance(value, _np.ndarray):
            return int(value.nbytes)
    except Exception:
        pass
    if hasattr(value, "nbytes"):
        try:
            return int(value.nbytes)
        except Exception:
            pass
    if isinstance(value, (bytes, bytearray, str)):
        return len(value)
    if isinstance(value, (list, tuple)):
        return 64 + sum(sizeof(v) for v in value)
    if isinstance(value, dict):
        return 64 + sum(sizeof(k) + sizeof(v) for k, v in value.items())
    return 64


@dataclass
class CachedArtifact:
    name: str
    value: Any
    bytes: int
    compute_time_s: float
    producer: str                      # job name
    created: float = field(default_factory=time.time)
    last_used: float = field(default_factory=time.time)
    uses: int = 0
    insertion: int = 0                 # FIFO order
    # weakref to the producing WorkflowIR (set when the engine offers with
    # workflow=...): scoring resolves the producer in THIS DAG instead of
    # whichever workflow was attached last — the per-artifact scoring
    # context that makes concurrent workflows sharing a store stop
    # invalidating each other. None falls back to store.workflow.
    wf_ref: Any = None


def predecessor_subgraph(wf: WorkflowIR, job: str, n_layers: int,
                         cached_producers: set) -> List[str]:
    """G_p: preceding n layers from u's producer; truncated at cached jobs
    (paper §IV.A.2 properties (a),(b))."""
    frontier = [job]
    seen = {job}
    for _ in range(n_layers):
        nxt = []
        for j in frontier:
            for p in wf.predecessors(j):
                if p in seen:
                    continue
                seen.add(p)
                if p in cached_producers:
                    continue            # truncate at cached artifact
                nxt.append(p)
        frontier = nxt
        if not frontier:
            break
    return list(seen)


def successor_subgraph(wf: WorkflowIR, job: str, n_layers: int) -> Dict[str, int]:
    """G_s with hop distance kappa from u's producer."""
    dist = {job: 0}
    frontier = [job]
    for k in range(1, n_layers + 1):
        nxt = []
        for j in frontier:
            for s in wf.successors(j):
                if s not in dist:
                    dist[s] = k
                    nxt.append(s)
        frontier = nxt
        if not frontier:
            break
    return dist


def reconstruction_cost(wf: WorkflowIR, job: str, cached_producers: set,
                        n_layers: int = 3) -> float:
    """Eq. 3: L(u) = sum_ij A_ij (w_i + d_i d_j) over G_p."""
    nodes = predecessor_subgraph(wf, job, n_layers, cached_producers)
    A = wf.adjacency(nodes)
    d = A.sum(0) + A.sum(1)
    w = np.array([wf.jobs[n].est_time_s * max(1.0, wf.jobs[n].resources.cpu)
                  for n in nodes])
    # A_ij * (w_i + d_i*d_j), vectorized
    cost = float((A * (w[:, None] + np.outer(d, d))).sum())
    return cost


def reuse_value(wf: WorkflowIR, job: str, n_layers: int = 3,
                literal_eq4: bool = False) -> float:
    """Eq. 4/5: F(u) = sum_i r/kappa_ui * (zeta_ui + 1), zeta = diag(d) - A.

    ``literal_eq4=False`` (default) weights by |zeta_ui| instead of zeta_ui
    so direct successors count most — see the module docstring for why the
    literal equation zeroes them out."""
    dist = successor_subgraph(wf, job, n_layers)
    nodes = list(dist)
    if len(nodes) <= 1:
        return 0.0
    A = wf.adjacency(nodes)
    d = A.sum(0) + A.sum(1)
    zeta = np.diag(d) - A
    u = nodes.index(job)
    total = 0.0
    for i, n in enumerate(nodes):
        if n == job:
            continue
        kappa = dist[n]
        r = 1.0                           # reuse event indicator
        z = zeta[u, i] if literal_eq4 else abs(zeta[u, i])
        total += (r / max(kappa, 1)) * (z + 1.0)
    return float(total)


def importance(l: float, f: float, v: float, alpha: float = 1.5,
               beta: float = 1.0) -> float:
    """Eq. 6 (alpha=1.5, beta=1 per paper §VI.C)."""
    return alpha * math.log1p(max(l, 0.0)) + beta * f * f - math.exp(-v)
