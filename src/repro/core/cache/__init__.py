"""Tiered artifact cache subsystem (paper §IV.A, Eq. 3-6, Algorithm 2).

Layout:
  scoring.py   Eq. 3-6 math (+ the documented Eq. 4 literal/deviation flag)
               and the ``CachedArtifact`` record
  policies.py  NONE/ALL/FIFO/LRU/COULER admission+eviction policies and the
               ``promotion_scores`` ranking hook
  tiers.py     ``CacheTier`` capacity/bandwidth/latency cost models and the
               cross-cluster ``SharedRemoteTier``
  store.py     ``TieredCacheStore`` (MEM→SSD→REMOTE cascade, Eq. 6-driven
               background promotion) and the single-tier ``CacheStore``
               facade

``repro.core.caching`` re-exports this package's public names for backward
compatibility; new code should import from here.
"""
from repro.core.cache.scoring import (CachedArtifact, importance,
                                      predecessor_subgraph,
                                      reconstruction_cost, reuse_value,
                                      sizeof, successor_subgraph)
from repro.core.cache.policies import (POLICIES, CacheAll, CachePolicy,
                                       CoulerPolicy, FIFOPolicy, LRUPolicy,
                                       NoCache)
from repro.core.cache.tiers import (CacheTier, SharedRemoteTier, TierSpec,
                                    mem_spec, remote_spec, ssd_spec)
from repro.core.cache.store import (CacheStore, TieredCacheStore,
                                    default_tiers)

__all__ = [
    "CachedArtifact", "importance", "predecessor_subgraph",
    "reconstruction_cost", "reuse_value", "sizeof", "successor_subgraph",
    "POLICIES", "CacheAll", "CachePolicy", "CoulerPolicy", "FIFOPolicy",
    "LRUPolicy", "NoCache",
    "CacheTier", "SharedRemoteTier", "TierSpec", "mem_spec", "remote_spec",
    "ssd_spec",
    "CacheStore", "TieredCacheStore", "default_tiers",
]
