"""Cache admission/eviction policies (paper Algorithm 2 + RQ2 baselines).

Baselines for the paper's RQ2 comparison: NONE, ALL, FIFO, LRU; the paper
policy is ``CoulerPolicy`` (score = Eq. 6 importance factor).

Policies are store-agnostic: ``score``/``score_many`` receive any object
with the legacy ``CacheStore`` surface — ``items`` (name → CachedArtifact),
``capacity_bytes`` and ``workflow`` — which is either a single-tier store
or a per-tier view of a ``TieredCacheStore`` (tier capacity, store-wide
contents so Eq. 3's cached frontier spans tiers).

``promotion_scores`` is the background-promotion hook: the default reuses
``score_many``, while ``CoulerPolicy`` extends Eq. 6 with the observed
reuse events — each cache hit is one of Eq. 4's ``r`` events, so the
re-rank uses (F(u) + uses)² in the beta term and hot artifacts climb back
toward MEM even when their structural reuse value is modest.
"""
from __future__ import annotations

import logging
import weakref
from collections import OrderedDict
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.cache.scoring import (CachedArtifact, importance,
                                      reconstruction_cost, reuse_value)
from repro.core.ir import WorkflowIR


class CachePolicy:
    name = "base"

    def admit(self, art: CachedArtifact) -> bool:
        return True

    def score(self, art: CachedArtifact, store) -> float:
        raise NotImplementedError

    def score_many(self, arts: Sequence[CachedArtifact],
                   store) -> List[float]:
        """Batch scoring hook; policies with shared per-batch state
        (CoulerPolicy's frontier) override this."""
        return [self.score(a, store) for a in arts]

    def promotion_scores(self, arts: Sequence[CachedArtifact],
                         store) -> List[float]:
        """Ranking used by TieredCacheStore.promote(); defaults to the
        eviction score (higher = keep closer to MEM)."""
        return self.score_many(arts, store)

    def invalidate(self, wf: Optional[WorkflowIR]) -> None:
        """Called when the store's attached workflow changes."""


class NoCache(CachePolicy):
    name = "none"

    def admit(self, art):
        return False

    def score(self, art, store):
        return 0.0


class CacheAll(CachePolicy):
    """Admit everything; evict nothing until forced, then oldest-first."""
    name = "all"

    def score(self, art, store):
        return -art.insertion        # forced eviction: oldest first

    def promotion_scores(self, arts, store):
        # oldest-first eviction, but promotion should still favor recency
        return [a.last_used for a in arts]


class FIFOPolicy(CachePolicy):
    name = "fifo"

    def score(self, art, store):
        return art.insertion          # lowest = first in = evicted first


class LRUPolicy(CachePolicy):
    name = "lru"

    def score(self, art, store):
        return art.last_used


class _WfScoringCtx:
    """Eq. 3/4 memo state for ONE workflow: per-producer predecessor reach,
    reuse value, and frontier-keyed reconstruction cost. Holding these per
    workflow (instead of for the single attached one) is what lets
    concurrent runs share a store without dropping each other's memos."""

    __slots__ = ("ref", "struct_v", "weights_v", "pred_reach", "reuse",
                 "recon")

    def __init__(self, wf: WorkflowIR):
        self.ref = weakref.ref(wf)    # weak: dead ids may be reused
        self.struct_v = wf.structure_version
        self.weights_v = wf.weights_version
        self.pred_reach: Dict[str, FrozenSet[str]] = {}
        self.reuse: Dict[str, float] = {}
        self.recon: Dict[Tuple[str, FrozenSet[str]], float] = {}


class CoulerPolicy(CachePolicy):
    """Paper Algorithm 2: score = caching importance factor I(u).

    Eq. 3/4 are memoized per producer within a per-workflow context
    (LRU-bounded): F(u) depends only on workflow structure, and L(u)
    additionally on est_time_s weights plus the part of the cached
    frontier that falls inside u's untruncated n-layer predecessor reach —
    so re-scoring after an unrelated eviction is a dict lookup instead of
    a BFS + adjacency-matrix rebuild. Each artifact scores against its own
    DAG (``CachedArtifact.wf_ref``, falling back to ``store.workflow``),
    and the Eq. 3 frontier only counts cached items of the SAME workflow,
    so concurrent runs neither thrash the memos nor leak producers into
    each other's frontiers."""
    name = "couler"

    # distinct live workflows whose memos we keep; LRU past this
    _MAX_CONTEXTS = 16

    def __init__(self, alpha: float = 1.5, beta: float = 1.0,
                 n_layers: int = 3, literal_eq4: bool = False):
        self.alpha, self.beta, self.n_layers = alpha, beta, n_layers
        self.literal_eq4 = literal_eq4
        self._ctxs: "OrderedDict[int, _WfScoringCtx]" = OrderedDict()
        # rotations that evicted a LIVE workflow's memos — each one means
        # more than _MAX_CONTEXTS workflows share this policy and Eq. 3/4
        # will be recomputed from scratch on that workflow's next score
        self.ctx_rotations_live = 0
        self._m_rotations = None

    def bind_metrics(self, registry) -> None:
        """Attach registry instruments (``TieredCacheStore`` calls this):
        a live-eviction counter plus a scoring-context occupancy gauge."""
        self._m_rotations = registry.counter("cache_ctx_rotated_live_total")
        registry.gauge_fn("cache_scoring_ctxs", lambda: len(self._ctxs))

    def invalidate(self, wf: Optional[WorkflowIR]) -> None:
        self._ctxs.clear()

    def _ctx_for(self, wf: WorkflowIR) -> _WfScoringCtx:
        key = id(wf)
        ctx = self._ctxs.get(key)
        if ctx is None or ctx.ref() is not wf \
                or wf.structure_version != ctx.struct_v:
            ctx = _WfScoringCtx(wf)
            self._ctxs[key] = ctx
        elif wf.weights_version != ctx.weights_v:
            ctx.weights_v = wf.weights_version
            ctx.recon.clear()                        # Eq. 3 reads w_i
        self._ctxs.move_to_end(key)
        while len(self._ctxs) > self._MAX_CONTEXTS:
            _, evicted = self._ctxs.popitem(last=False)
            live = evicted.ref()
            if live is not None:
                # the workflow is still alive — its memos will be rebuilt
                # from scratch next time it scores (O(V+E) per producer
                # instead of O(1)); sustained rotation is a working-set
                # smell worth surfacing, not just a silent slowdown
                self.ctx_rotations_live += 1
                if self._m_rotations is not None:
                    self._m_rotations.inc()
                logging.getLogger(__name__).warning(
                    "CoulerPolicy: rotated out scoring context for live "
                    "workflow %r (>%d concurrent workflows share this "
                    "policy; Eq. 3/4 memos for it will be recomputed)",
                    getattr(live, "name", "?"), self._MAX_CONTEXTS)
        return ctx

    def _reach(self, ctx: _WfScoringCtx, wf: WorkflowIR,
               producer: str) -> FrozenSet[str]:
        """Untruncated n-layer predecessor reach of `producer` — the only
        nodes whose cached-status can alter Eq. 3's truncated BFS."""
        s = ctx.pred_reach.get(producer)
        if s is None:
            frontier = [producer]
            seen = {producer}
            for _ in range(self.n_layers):
                nxt = []
                for j in frontier:
                    for p in wf.predecessors(j):
                        if p not in seen:
                            seen.add(p)
                            nxt.append(p)
                frontier = nxt
                if not frontier:
                    break
            s = frozenset(seen)
            ctx.pred_reach[producer] = s
        return s

    # frontier-sig entries accumulate as the cached set churns even when
    # the workflow never changes; past this bound a wholesale reset is
    # cheaper than unbounded growth (misses just recompute)
    _RECON_MEMO_CAP = 4096

    def _lf(self, ctx: _WfScoringCtx, wf: WorkflowIR, art: CachedArtifact,
            frontier_sig: FrozenSet[str]) -> Tuple[float, float]:
        """Memoized (L(u), F(u)) for art's producer under the frontier."""
        key = (art.producer, frontier_sig)
        l = ctx.recon.get(key)
        if l is None:
            if len(ctx.recon) >= self._RECON_MEMO_CAP:
                ctx.recon.clear()
            l = reconstruction_cost(wf, art.producer, frontier_sig,
                                    self.n_layers)
            ctx.recon[key] = l
        f = ctx.reuse.get(art.producer)
        if f is None:
            f = reuse_value(wf, art.producer, self.n_layers,
                            literal_eq4=self.literal_eq4)
            ctx.reuse[art.producer] = f
        return l, f

    def score(self, art: CachedArtifact, store) -> float:
        return self.score_many([art], store)[0]

    def _batch(self, arts: Sequence[CachedArtifact], store,
               reuse_boost: bool) -> List[float]:
        default_wf = store.workflow
        items = store.items
        # per-workflow cached-producer counts: Eq. 3's frontier must not
        # mix producers of unrelated concurrent workflows
        prod_count: Dict[int, Dict[str, int]] = {}
        wf_of: Dict[str, Optional[WorkflowIR]] = {}
        for a in items.values():
            w = a.wf_ref() if a.wf_ref is not None else None
            if w is None:
                w = default_wf
            wf_of[a.name] = w
            if w is None:
                continue
            d = prod_count.setdefault(id(w), {})
            d[a.producer] = d.get(a.producer, 0) + 1
        out = []
        for art in arts:
            wf = art.wf_ref() if art.wf_ref is not None else None
            if wf is None:
                wf = default_wf
            if wf is None:
                out.append(art.last_used)
                continue
            if art.producer not in wf.jobs:
                # orphaned producer (workflow edited since caching). For
                # EVICTION keep the legacy LRU-style fallback; for the
                # promotion re-rank a raw epoch timestamp would dwarf every
                # Eq. 6 score and pin dead artifacts into MEM — rank
                # orphans below everything so they sink instead
                out.append(float("-inf") if reuse_boost else art.last_used)
                continue
            ctx = self._ctx_for(wf)
            pc = prod_count.get(id(wf), {})
            # cached frontier = producers of stored items of THIS workflow
            # minus the item stored under this artifact's own key
            # (Algorithm 2's k != u), restricted to the predecessor reach
            # (the rest cannot matter)
            own = items.get(art.name)
            own_producer = (own.producer
                            if own is not None and wf_of.get(art.name) is wf
                            else None)
            sig = frozenset(
                p for p in self._reach(ctx, wf, art.producer)
                if pc.get(p, 0) - (1 if p == own_producer else 0) > 0)
            l, f = self._lf(ctx, wf, art, sig)
            if reuse_boost:
                f = f + art.uses       # observed hits are Eq. 4's r events
            v = art.bytes / max(store.capacity_bytes, 1)
            out.append(importance(l, f, v, self.alpha, self.beta))
        return out

    def score_many(self, arts: Sequence[CachedArtifact],
                   store) -> List[float]:
        return self._batch(arts, store, reuse_boost=False)

    def promotion_scores(self, arts: Sequence[CachedArtifact],
                         store) -> List[float]:
        return self._batch(arts, store, reuse_boost=True)


POLICIES = {"none": NoCache, "all": CacheAll, "fifo": FIFOPolicy,
            "lru": LRUPolicy, "couler": CoulerPolicy}
