"""Cache tiers: capacity + bandwidth + access-latency cost models.

A ``CacheTier`` is one level of the tiered artifact store (paper §IV.A
generalized beyond the single Alluxio tier): it holds artifacts up to
``capacity_bytes`` and charges ``access_time_s(nbytes) = latency_s +
nbytes / bandwidth_bytes_s`` per fetch. Default specs model a node-local
memory tier, a node-local NVMe tier and a remote object/Alluxio tier.

``SharedRemoteTier`` is a ``CacheTier`` that may be attached as the last
tier of *multiple* ``TieredCacheStore``s (one per engine/cluster): demoted
artifacts become visible to every attached store, and hits are accounted
per client so cross-cluster reuse is measurable. All tier mutations go
through ``put``/``remove`` which keep a byte ledger (``bytes_in`` /
``bytes_out``) — ``TieredCacheStore.check_invariants`` asserts the ledger
matches ``used_bytes`` so demotions conserve bytes.
"""
from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.cache.scoring import CachedArtifact


@dataclass(frozen=True)
class TierSpec:
    name: str
    capacity_bytes: int
    bandwidth_bytes_s: float = 8e9
    latency_s: float = 0.0
    shared: bool = False


def mem_spec(capacity_bytes: int = 64 << 20) -> TierSpec:
    """Node-local memory: ~8 GB/s effective, microsecond latency."""
    return TierSpec("MEM", capacity_bytes, 8e9, 2e-6)


def ssd_spec(capacity_bytes: int = 512 << 20) -> TierSpec:
    """Node-local NVMe: ~1.2 GB/s, sub-millisecond latency."""
    return TierSpec("SSD", capacity_bytes, 1.2e9, 2.5e-4)


def remote_spec(capacity_bytes: int = 4 << 30) -> TierSpec:
    """Remote object store / Alluxio master: ~120 MB/s, 20 ms RTT."""
    return TierSpec("REMOTE", capacity_bytes, 1.2e8, 2e-2, shared=True)


# put/remove reasons -> tier stat counters
_IN_KEYS = {"admitted": "admissions", "demoted": "demotions_in",
            "promoted": "promotions_in"}
_OUT_KEYS = {"evicted": "evictions", "demoted": "demotions_out",
             "promoted": "promotions_out", "stale": "stale_drops"}


class CacheTier:
    """One capacity-bounded level of a tiered store.

    ``version`` is bumped on every mutation (including hit bookkeeping,
    which moves ``last_used`` and therefore LRU scores) so stores can
    lazily invalidate their per-tier eviction heaps — required for shared
    tiers, where another store's mutations are otherwise invisible.
    """

    def __init__(self, spec: TierSpec):
        self.spec = spec
        self.items: Dict[str, CachedArtifact] = {}
        self.used_bytes = 0
        self.version = 0
        self._lock = threading.RLock()
        self.stats = {"hits": 0, "admissions": 0, "demotions_in": 0,
                      "demotions_out": 0, "promotions_in": 0,
                      "promotions_out": 0, "evictions": 0, "stale_drops": 0,
                      "replaced": 0, "bytes_in": 0, "bytes_out": 0}

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def capacity_bytes(self) -> int:
        return self.spec.capacity_bytes

    @property
    def shared(self) -> bool:
        return self.spec.shared

    def access_time_s(self, nbytes: int) -> float:
        return self.spec.latency_s + nbytes / self.spec.bandwidth_bytes_s

    def fits(self, nbytes: int) -> bool:
        return nbytes <= self.spec.capacity_bytes

    def put(self, art: CachedArtifact, reason: str) -> Optional[CachedArtifact]:
        """Insert (replacing any same-key occupant); returns the replaced
        artifact so the store can count a refresh."""
        with self._lock:
            old = self.items.pop(art.name, None)
            if old is not None:
                self.used_bytes -= old.bytes
                self.stats["bytes_out"] += old.bytes
                self.stats["replaced"] += 1
            self.items[art.name] = art
            self.used_bytes += art.bytes
            self.stats["bytes_in"] += art.bytes
            self.stats[_IN_KEYS[reason]] += 1
            self.version += 1
            return old

    def put_if_fits(self, art: CachedArtifact,
                    reason: str) -> Tuple[bool, Optional[CachedArtifact]]:
        """Atomic capacity-check + insert — required for shared tiers,
        where another store may fill the tier between a caller's fit check
        and its put. Returns (inserted, replaced_occupant)."""
        with self._lock:
            old = self.items.get(art.name)
            freed = old.bytes if old is not None else 0
            if self.used_bytes - freed + art.bytes > self.spec.capacity_bytes:
                return False, None
            return True, self.put(art, reason)

    def snapshot_items(self) -> Dict[str, CachedArtifact]:
        """Point-in-time copy taken under the tier lock; iterate THIS, not
        ``items``, when the tier may be shared with other stores."""
        with self._lock:
            return dict(self.items)

    def remove(self, name: str, reason: str) -> Optional[CachedArtifact]:
        with self._lock:
            art = self.items.pop(name, None)
            if art is None:
                return None
            self.used_bytes -= art.bytes
            self.stats["bytes_out"] += art.bytes
            self.stats[_OUT_KEYS[reason]] += 1
            self.version += 1
            return art

    def record_hit(self, client: Optional[str] = None) -> None:
        with self._lock:
            self.stats["hits"] += 1
            self.version += 1          # hit moved last_used (LRU scores)

    def check_ledger(self) -> None:
        with self._lock:
            s = sum(a.bytes for a in self.items.values())
            assert s == self.used_bytes, \
                (self.name, "item bytes != used_bytes", s, self.used_bytes)
            net = self.stats["bytes_in"] - self.stats["bytes_out"]
            assert net == self.used_bytes, \
                (self.name, "byte ledger leak", net, self.used_bytes)
            assert self.used_bytes <= self.capacity_bytes, \
                (self.name, "over capacity", self.used_bytes,
                 self.capacity_bytes)


class SharedRemoteTier(CacheTier):
    """REMOTE tier shareable across engines/clusters.

    Attach the same instance as the last tier of several stores (one per
    cluster); ``hits_by_client`` records which cluster's store served each
    hit so cross-cluster artifact reuse is visible in benchmarks.
    """

    def __init__(self, spec: Optional[TierSpec] = None):
        spec = spec or remote_spec()
        if not spec.shared:            # normalize: sharing implies shared
            spec = dataclasses.replace(spec, shared=True)
        super().__init__(spec)
        self.hits_by_client: Dict[str, int] = {}

    def record_hit(self, client: Optional[str] = None) -> None:
        with self._lock:
            super().record_hit(client)
            c = client or "?"
            self.hits_by_client[c] = self.hits_by_client.get(c, 0) + 1
