"""Span derivation from the gateway's typed event stream.

``ObsCollector`` subscribes to each run's publish path (the same
single-hook slot the ``TraceChecker`` sanitizer uses — handles now fan
out to any number of observers) and folds the ordered event stream into a
**span tree** per run:

* one workflow span (``WORKFLOW_ADMITTED`` → ``WORKFLOW_DONE``), carrying
  workflow-scope segments — ``readmission-backoff`` windows opened by
  ``WORKFLOW_REQUEUED`` and closed by the next event of the new epoch;
* one step span per ``STEP_STARTED`` → terminal pair, subdivided into
  segments: ``retry`` (attempt start → ``STEP_RETRY``, cause
  ``STEP_RETRY`` or ``WORKER_LOST``), ``compute`` (last attempt →
  terminal), ``cache-fetch`` (span of a ``STEP_CACHED`` terminal),
  ``skipped``, and a synthetic duration-only ``stream-stall`` segment fed
  by the producer's channel backpressure accounting;
* ``queue-wait`` segments derived at finalize time from the DAG: a step's
  ready instant is the max of its predecessors' terminal timestamps and
  its epoch start — the gap to ``STEP_STARTED`` is time spent waiting on
  the admission pump / in-flight-steps semaphore.

The derivation honours the taxonomy's cancel-scoping exception: a step
cancelled mid-stream reverts to ``Pending`` with no terminal event, so
its span is closed as ``Reverted`` when the workflow's ``WORKFLOW_DONE``
arrives — ``open_run_ids`` is the leak check (empty once every observed
run finished).

Exports: ``export_jsonl`` (one span-tree object per line, loadable with
``load_jsonl`` for offline reports) and ``export_chrome`` (Chrome
trace-event JSON, loadable in Perfetto / ``chrome://tracing``;
``validate_chrome_trace`` is the schema check the test suite pins).
"""
from __future__ import annotations

import json
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.gateway.events import EventType, WorkflowEvent
from repro.core.obs.metrics import MetricsRegistry

__all__ = ["Segment", "StepSpan", "SpanTree", "ObsCollector",
           "chrome_trace", "validate_chrome_trace", "load_jsonl"]

#: step terminal statuses that satisfy successors
SATISFIED = ("Succeeded", "Cached", "Skipped")

#: segment taxonomy (docs/observability.md)
SEGMENT_KINDS = ("queue-wait", "cache-fetch", "compute", "retry",
                 "readmission-backoff", "stream-stall", "skipped",
                 "overhead")


@dataclass
class Segment:
    """One attributed slice of a span. ``synthetic`` marks duration-only
    segments (``stream-stall``) that overlap real timeline slices and are
    therefore excluded from makespan partitioning."""

    kind: str
    start: float
    end: float
    cause: str = ""
    synthetic: bool = False

    @property
    def dur(self) -> float:
        return max(0.0, self.end - self.start)

    def to_dict(self) -> Dict[str, Any]:
        d = {"kind": self.kind, "start": self.start, "end": self.end}
        if self.cause:
            d["cause"] = self.cause
        if self.synthetic:
            d["synthetic"] = True
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Segment":
        return cls(kind=d["kind"], start=d["start"], end=d["end"],
                   cause=d.get("cause", ""),
                   synthetic=bool(d.get("synthetic")))


@dataclass
class StepSpan:
    step: str
    epoch: int
    start: float
    end: Optional[float] = None
    status: str = "Running"
    attempts: int = 1
    chunks: int = 0
    segments: List[Segment] = field(default_factory=list)
    annotations: Dict[str, Any] = field(default_factory=dict)

    @property
    def closed(self) -> bool:
        return self.end is not None

    @property
    def dur(self) -> float:
        return max(0.0, (self.end or self.start) - self.start)

    def seg_total(self, kind: str) -> float:
        return sum(s.dur for s in self.segments if s.kind == kind)

    def to_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "epoch": self.epoch, "start": self.start,
                "end": self.end, "status": self.status,
                "attempts": self.attempts, "chunks": self.chunks,
                "segments": [s.to_dict() for s in self.segments],
                "annotations": self.annotations}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "StepSpan":
        return cls(step=d["step"], epoch=d.get("epoch", 0),
                   start=d["start"], end=d.get("end"),
                   status=d.get("status", "Running"),
                   attempts=d.get("attempts", 1), chunks=d.get("chunks", 0),
                   segments=[Segment.from_dict(s)
                             for s in d.get("segments", ())],
                   annotations=dict(d.get("annotations", {})))


class SpanTree:
    """One finalized run: workflow span + ordered step spans + the DAG
    edges needed to attribute the critical path offline.

    A ``__slots__`` class (not a dataclass): one tree is built per run on
    the collector hot path, and the generated-``__init__`` +
    ``default_factory`` overhead is measurable at bench scale.

    Fields: ``steps`` — ordered step spans; ``segments`` —
    workflow-scope segments (readmission-backoff windows); ``causes`` —
    annotated causes in arrival order (STEP_RETRY / WORKER_LOST /
    CLUSTER_PREEMPTED / WORKFLOW_REQUEUED).
    """

    __slots__ = ("workflow", "run_id", "tenant", "start", "end", "status",
                 "steps", "segments", "causes", "edges", "counts",
                 "events_total")

    def __init__(self, workflow: str, run_id: str, tenant: str = "default",
                 start: float = 0.0, end: float = 0.0,
                 status: str = "Running",
                 steps: Optional[List[StepSpan]] = None,
                 segments: Optional[List[Segment]] = None,
                 causes: Optional[List[Dict[str, Any]]] = None,
                 edges: Optional[List[Tuple[str, str]]] = None,
                 counts: Optional[Dict[str, int]] = None,
                 events_total: int = 0):
        self.workflow = workflow
        self.run_id = run_id
        self.tenant = tenant
        self.start = start
        self.end = end
        self.status = status
        self.steps = steps if steps is not None else []
        self.segments = segments if segments is not None else []
        self.causes = causes if causes is not None else []
        self.edges = edges if edges is not None else []
        self.counts = counts if counts is not None else {}
        self.events_total = events_total

    @property
    def makespan_s(self) -> float:
        return max(0.0, self.end - self.start)

    def latest_spans(self) -> Dict[str, StepSpan]:
        """Latest closed span per step (re-run steps keep every span in
        ``steps``; attribution wants the one that finally counted)."""
        out: Dict[str, StepSpan] = {}
        for sp in self.steps:
            if not sp.closed:
                continue
            cur = out.get(sp.step)
            if cur is None or sp.end >= cur.end:
                out[sp.step] = sp
        return out

    def seg_total(self, kind: str) -> float:
        tot = sum(s.dur for s in self.segments if s.kind == kind)
        for sp in self.steps:
            tot += sp.seg_total(kind)
        return tot

    @property
    def retry_segments(self) -> List[Tuple[Segment, str]]:
        """Every retry segment paired with its step name, in span order —
        the chaos tests compare this 1:1 against the STEP_RETRY events."""
        return [(s, sp.step) for sp in self.steps
                for s in sp.segments if s.kind == "retry"]

    def to_dict(self) -> Dict[str, Any]:
        return {"workflow": self.workflow, "run_id": self.run_id,
                "tenant": self.tenant, "start": self.start, "end": self.end,
                "status": self.status,
                "steps": [s.to_dict() for s in self.steps],
                "segments": [s.to_dict() for s in self.segments],
                "causes": self.causes,
                "edges": [list(e) for e in self.edges],
                "counts": self.counts, "events_total": self.events_total}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SpanTree":
        return cls(workflow=d["workflow"], run_id=d["run_id"],
                   tenant=d.get("tenant", "default"),
                   start=d.get("start", 0.0), end=d.get("end", 0.0),
                   status=d.get("status", "Running"),
                   steps=[StepSpan.from_dict(s) for s in d.get("steps", ())],
                   segments=[Segment.from_dict(s)
                             for s in d.get("segments", ())],
                   causes=list(d.get("causes", ())),
                   edges=[tuple(e) for e in d.get("edges", ())],
                   counts=dict(d.get("counts", {})),
                   events_total=d.get("events_total", 0))


class _RunBuilder:
    """Mutable per-run accumulator; becomes a ``SpanTree`` at
    ``WORKFLOW_DONE``. Mutated only under the collector lock."""

    __slots__ = ("tree", "open_spans", "epoch", "epoch_starts",
                 "open_backoff", "pending_cause", "saw_admitted")

    def __init__(self, workflow: str, run_id: str, tenant: str,
                 edges: List[Tuple[str, str]]):
        self.tree = SpanTree(workflow=workflow, run_id=run_id, tenant=tenant,
                             edges=edges)
        self.open_spans: Dict[str, StepSpan] = {}
        self.epoch = 0
        self.epoch_starts: List[float] = []
        self.open_backoff: Optional[Segment] = None
        self.pending_cause: Dict[str, str] = {}   # step -> WORKER_LOST etc.
        self.saw_admitted = False


_FINAL_SEGMENT = {EventType.STEP_SUCCEEDED: "compute",
                  EventType.STEP_FAILED: "compute",
                  EventType.STEP_CACHED: "cache-fetch",
                  EventType.STEP_SKIPPED: "skipped"}

# enum .name is a DynamicClassAttribute (a function call per access);
# resolved once here — _apply runs per event on the publish path
_TYPE_NAME = {et: et.name for et in EventType}


class ObsCollector:
    """Derives span trees from run event streams; thread-safe.

    Attach via ``couler.observe(engine)`` (every subsequent run is
    registered by the gateway) or feed a recorded stream directly with
    ``ingest``. Finished trees are kept in an LRU of ``max_runs``;
    ``report(run_id)`` runs the critical-path attribution
    (``repro.core.obs.attribution``) over a finished tree.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 max_runs: int = 256):
        self.registry = registry or MetricsRegistry("obs")
        self.max_runs = max_runs
        # plain Lock (cheaper acquire than RLock) — no method here calls
        # back into another locked method while holding it
        self._lock = threading.Lock()
        self._open: Dict[str, _RunBuilder] = {}
        self._done: "OrderedDict[str, SpanTree]" = OrderedDict()
        self._anomalies = self.registry.counter("obs_stream_anomalies_total")
        # hot-path instruments, pre-resolved once: the per-event registry
        # lookup (label sort + lock) dominated ingest cost at n=2000
        reg = self.registry
        self._m_event = {et: reg.counter("obs_events_total", type=et.name)
                         for et in EventType}
        self._m_retries = reg.counter("obs_retries_total")
        self._m_chunks = reg.counter("obs_chunks_total")
        self._m_readmissions = reg.counter("obs_readmissions_total")
        self._m_alerts = reg.counter("obs_alerts_total")
        self._m_step_status: Dict[str, Any] = {}
        self._m_run_status: Dict[str, Any] = {}
        self._h_step_dur = reg.histogram("obs_step_duration_s")
        self._h_queue_wait = reg.histogram("obs_step_queue_wait_s")
        self._h_makespan = reg.histogram("obs_run_makespan_s")

    def _step_status_counter(self, status: str):
        c = self._m_step_status.get(status)
        if c is None:
            c = self.registry.counter("obs_steps_total", status=status)
            self._m_step_status[status] = c
        return c

    # -- registration ------------------------------------------------------
    def register_run(self, run_id: str, wf=None, tenant: str = "default",
                     workflow: str = "") -> None:
        """Start (or restart — resume/readmission re-submission) the
        builder for ``run_id``. The DAG edges are copied now (elements are
        already immutable ``(src, dst)`` tuples per the IR contract) so
        offline reports never depend on the workflow object staying
        alive."""
        edges = list(getattr(wf, "edges", ()))
        name = workflow or getattr(wf, "name", "") or run_id
        with self._lock:
            prev = self._open.pop(run_id, None)
            if prev is not None:
                # a re-registered unfinished stream replaces the old one;
                # count it so leak hunts notice silent restarts
                self._anomalies.inc()
            self._open[run_id] = _RunBuilder(name, run_id, tenant, edges)

    def ingest(self, events: Iterable[WorkflowEvent], wf=None,
               run_id: str = "", tenant: str = "default") -> Optional[str]:
        """Feed a recorded event stream (e.g. ``handle.events_so_far()``
        from a backend without a live publish hook). Returns the run id
        the stream was registered under."""
        if type(events) is not list:
            events = list(events)
        if not events:
            return None
        rid = run_id or events[0].run_id or "anon"
        name = (getattr(wf, "name", "") or events[0].workflow or rid)
        edges = list(getattr(wf, "edges", ()))
        batch_counts: Dict[Any, int] = {}
        apply_, type_name = self._apply, _TYPE_NAME
        with self._lock:                   # one acquire for the batch
            if self._open.pop(rid, None) is not None:
                self._anomalies.inc()      # silent restart — see register_run
            b = _RunBuilder(name, rid, tenant, edges)
            self._open[rid] = b
            for ev in events:
                batch_counts[ev.type] = batch_counts.get(ev.type, 0) + 1
                apply_(b, ev)
            # per-type totals folded into the tree once, not per event
            t, n_total = b.tree, 0
            for et, n in batch_counts.items():
                tname = type_name[et]
                t.counts[tname] = t.counts.get(tname, 0) + n
                n_total += n
            t.events_total += n_total
        for et, n in batch_counts.items():  # one inc per type, not per event
            self._m_event[et].inc(n)
        return rid

    # -- live observation --------------------------------------------------
    def observe(self, ev: WorkflowEvent) -> None:
        """Publish-path hook (``AsyncWorkflowRun.add_observer``); called
        under the handle's publish lock, so events of one run arrive in
        seq order. Never raises into the publish path."""
        self._observe_for(ev.run_id or "anon", ev)

    def _observe_for(self, run_id: str, ev: WorkflowEvent) -> None:
        with self._lock:
            b = self._open.get(run_id)
            if b is None:
                # stream started before the collector attached (coarse
                # backends): synthesize a builder from what the event has
                b = _RunBuilder(ev.workflow or run_id, run_id, ev.tenant, [])
                self._open[run_id] = b
            self._m_event[ev.type].inc()
            t, tname = b.tree, _TYPE_NAME[ev.type]
            t.events_total += 1
            t.counts[tname] = t.counts.get(tname, 0) + 1
            self._apply(b, ev)

    def _apply(self, b: _RunBuilder, ev: WorkflowEvent) -> None:
        # NOTE: per-type counts / events_total are folded in by the two
        # callers (batched in ``ingest``, per event in ``_observe_for``)
        t = b.tree
        if t.start == 0.0:
            t.start = ev.ts
        if b.open_backoff is not None and ev.type not in (
                EventType.WORKFLOW_REQUEUED, EventType.ALERT):
            # ALERT is advisory (a readmission-storm alert lands right
            # after WORKFLOW_REQUEUED) — it must not close the window
            # first event of the new epoch closes the backoff window
            b.open_backoff.end = ev.ts
            b.open_backoff = None
            if b.epoch >= len(b.epoch_starts):
                b.epoch_starts.append(ev.ts)
        et = ev.type
        if et is EventType.WORKFLOW_ADMITTED:
            b.saw_admitted = True
            if not b.epoch_starts:
                b.epoch_starts.append(ev.ts)
        elif et is EventType.WORKFLOW_DONE:
            # checked early: every stream ends with one, and coarse
            # (admit/done only) streams are the high-volume ingest case
            t.end = ev.ts
            t.status = ev.status or "Succeeded"
            if ev.error:
                t.causes.append({"type": "WORKFLOW_DONE", "ts": ev.ts,
                                 "error": ev.error})
            # cancel-scoping exception: mid-stream cancelled steps revert
            # to Pending with no terminal event — close them here
            if b.open_spans:
                self._close_open(b, ev.ts, "Reverted", "WORKFLOW_DONE")
            self._finalize(b)
        elif et is EventType.STEP_STARTED:
            if ev.step in b.open_spans:
                self._anomalies.inc()
            b.open_spans[ev.step] = StepSpan(
                step=ev.step, epoch=b.epoch, start=ev.ts,
                attempts=max(1, ev.attempt + 1))
        elif et is EventType.WORKER_LOST:
            b.pending_cause[ev.step] = "WORKER_LOST"
            t.causes.append({"type": "WORKER_LOST", "step": ev.step,
                             "attempt": ev.attempt, "ts": ev.ts,
                             "error": ev.error})
        elif et is EventType.STEP_RETRY:
            sp = b.open_spans.get(ev.step)
            cause = b.pending_cause.pop(ev.step, "STEP_RETRY")
            t.causes.append({"type": "STEP_RETRY", "step": ev.step,
                             "attempt": ev.attempt, "ts": ev.ts,
                             "cause": cause, "error": ev.error})
            self._m_retries.inc()
            if sp is None:
                self._anomalies.inc()
            else:
                boundary = sp.segments[-1].end if sp.segments else sp.start
                sp.segments.append(Segment("retry", boundary, ev.ts,
                                           cause=cause))
                sp.attempts += 1
        elif et is EventType.STEP_STREAMING:
            sp = b.open_spans.get(ev.step)
            if sp is not None:
                sp.annotations["streaming_ts"] = ev.ts
        elif et is EventType.STEP_CHUNK:
            self._m_chunks.inc()
            sp = b.open_spans.get(ev.step)
            if sp is not None:
                sp.chunks += 1
                sp.annotations["last_chunk_ts"] = ev.ts
        elif et in _FINAL_SEGMENT:
            sp = b.open_spans.pop(ev.step, None)
            b.pending_cause.pop(ev.step, None)
            if sp is None:
                self._anomalies.inc()
                return
            sp.end = ev.ts
            sp.status = ev.status or et.name.replace("STEP_", "").title()
            if ev.error:
                sp.annotations["error"] = ev.error
            boundary = sp.segments[-1].end if sp.segments else sp.start
            sp.segments.append(Segment(_FINAL_SEGMENT[et], boundary, ev.ts,
                                       cause=ev.error if et is
                                       EventType.STEP_FAILED else ""))
            t.steps.append(sp)
            self._step_status_counter(sp.status).inc()
            self._h_step_dur.observe(sp.dur)
        elif et is EventType.CLUSTER_PREEMPTED:
            t.causes.append({"type": "CLUSTER_PREEMPTED", "step": ev.step,
                             "attempt": ev.attempt, "ts": ev.ts,
                             "error": ev.error})
        elif et is EventType.WORKFLOW_REQUEUED:
            t.causes.append({"type": "WORKFLOW_REQUEUED",
                             "attempt": ev.attempt, "ts": ev.ts,
                             "error": ev.error})
            self._m_readmissions.inc()
            # steps still open at requeue were reverted by the failure
            if b.open_spans:
                self._close_open(b, ev.ts, "Reverted", "WORKFLOW_REQUEUED")
            b.epoch += 1
            seg = Segment("readmission-backoff", ev.ts, ev.ts,
                          cause="WORKFLOW_REQUEUED")
            t.segments.append(seg)
            b.open_backoff = seg
        elif et is EventType.ALERT:
            t.causes.append({"type": "ALERT", "detector": ev.status,
                             "step": ev.step, "ts": ev.ts,
                             "error": ev.error})
            self._m_alerts.inc()

    def _close_open(self, b: _RunBuilder, ts: float, status: str,
                    cause: str) -> None:
        for step, sp in list(b.open_spans.items()):
            sp.end = ts
            sp.status = status
            boundary = sp.segments[-1].end if sp.segments else sp.start
            sp.segments.append(Segment("compute", boundary, ts, cause=cause))
            b.tree.steps.append(sp)
            self._step_status_counter(status).inc()
        b.open_spans.clear()

    # -- finalize: DAG-derived queue-wait + bookkeeping --------------------
    def _finalize(self, b: _RunBuilder) -> None:
        t = b.tree
        if t.steps:                   # coarse streams: nothing to wait on
            preds: Dict[str, List[str]] = {}
            for src, dst in t.edges:
                preds.setdefault(dst, []).append(src)
            # latest SATISFYING terminal per step gates successors; epoch
            # starts bound readiness for steps re-run after a requeue
            done_at: Dict[str, float] = {}
            for sp in t.steps:
                if sp.status in SATISFIED:
                    done_at[sp.step] = max(done_at.get(sp.step, 0.0), sp.end)
            qw_hist = self._h_queue_wait
            for sp in t.steps:
                epoch_start = (b.epoch_starts[sp.epoch]
                               if sp.epoch < len(b.epoch_starts) else t.start)
                ready = max([epoch_start] +
                            [done_at[p] for p in preds.get(sp.step, ())
                             if p in done_at and done_at[p] <= sp.start])
                ready = min(ready, sp.start)
                if sp.start > ready:
                    sp.segments.insert(0, Segment("queue-wait", ready,
                                                  sp.start))
                qw_hist.observe(max(0.0, sp.start - ready))
        c = self._m_run_status.get(t.status)
        if c is None:
            c = self.registry.counter("obs_runs_total", status=t.status)
            self._m_run_status[t.status] = c
        c.inc()
        self._h_makespan.observe(t.end - t.start if t.end > t.start else 0.0)
        rid, done = t.run_id, self._done
        self._open.pop(rid, None)
        refresh = rid in done              # re-finalized: bump LRU recency
        done[rid] = t                      # fresh keys insert at the end
        if refresh:
            done.move_to_end(rid)
        while len(done) > self.max_runs:
            done.popitem(last=False)

    # -- post-hoc annotation (gateway channel accounting) ------------------
    def annotate_step(self, run_id: str, step: str,
                      stream_stall_s: float = 0.0,
                      **attrs: Any) -> None:
        """Attach channel-level measurements to a step's span (producer
        backpressure stalls are not observable from events alone). Works
        on open or finished runs; stalls become a synthetic duration-only
        ``stream-stall`` segment."""
        with self._lock:
            spans: List[StepSpan] = []
            b = self._open.get(run_id)
            if b is not None:
                sp = b.open_spans.get(step)
                if sp is not None:
                    spans.append(sp)
                spans += [s for s in b.tree.steps if s.step == step]
            t = self._done.get(run_id)
            if t is not None:
                spans += [s for s in t.steps if s.step == step]
            if not spans:
                return
            sp = spans[-1]
            sp.annotations.update(attrs)
            if stream_stall_s > 0:
                end = sp.end if sp.end is not None else sp.start
                sp.segments.append(Segment(
                    "stream-stall", end - stream_stall_s, end,
                    cause="backpressure", synthetic=True))
                sp.annotations["stream_stall_s"] = stream_stall_s

    # -- introspection -----------------------------------------------------
    @property
    def open_run_ids(self) -> List[str]:
        with self._lock:
            return list(self._open)

    def tree(self, run_id: str) -> Optional[SpanTree]:
        with self._lock:
            return self._done.get(run_id)

    def trees(self) -> List[SpanTree]:
        with self._lock:
            return list(self._done.values())

    def report(self, run_id: str):
        """Critical-path makespan breakdown for a finished run."""
        t = self.tree(run_id)
        if t is None:
            raise RuntimeError(
                f"run {run_id!r} has no finished span tree (still "
                "running, never observed, or rotated out of the LRU)")
        from repro.core.obs.attribution import build_report
        return build_report(t)

    # -- export ------------------------------------------------------------
    def export_jsonl(self, path: Optional[str] = None,
                     run_id: Optional[str] = None) -> str:
        trees = [self.tree(run_id)] if run_id else self.trees()
        lines = [json.dumps(t.to_dict(), sort_keys=True)
                 for t in trees if t is not None]
        text = "\n".join(lines) + ("\n" if lines else "")
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def export_chrome(self, run_id: Optional[str] = None) -> Dict[str, Any]:
        trees = [self.tree(run_id)] if run_id else self.trees()
        return chrome_trace([t for t in trees if t is not None])


def load_jsonl(text: str) -> List[SpanTree]:
    """Inverse of ``export_jsonl`` (accepts the text or a file's
    contents); blank lines are skipped."""
    return [SpanTree.from_dict(json.loads(line))
            for line in text.splitlines() if line.strip()]


# -- Chrome trace-event export ---------------------------------------------

def chrome_trace(trees: List[SpanTree]) -> Dict[str, Any]:
    """Render span trees as Chrome trace-event JSON (the ``traceEvents``
    object form Perfetto and ``chrome://tracing`` load). One process per
    run, thread 0 is the workflow lane, one thread per step; every
    segment is a complete ("X") slice with its cause in ``args``.
    Timestamps are microseconds relative to the earliest run start."""
    events: List[Dict[str, Any]] = []
    t0 = min((t.start for t in trees if t.start), default=0.0)

    def us(ts: float) -> float:
        return round((ts - t0) * 1e6, 1)

    for pid, t in enumerate(trees, start=1):
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name",
                       "args": {"name": f"{t.workflow} run {t.run_id}"}})
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "thread_name",
                       "args": {"name": "workflow"}})
        events.append({"ph": "X", "pid": pid, "tid": 0,
                       "name": f"workflow:{t.status}", "cat": "workflow",
                       "ts": us(t.start),
                       "dur": max(0.0, round(t.makespan_s * 1e6, 1)),
                       "args": {"run_id": t.run_id, "tenant": t.tenant,
                                "status": t.status,
                                "events": t.events_total}})
        for seg in t.segments:
            events.append({"ph": "X", "pid": pid, "tid": 0,
                           "name": seg.kind, "cat": seg.kind,
                           "ts": us(seg.start),
                           "dur": max(0.0, round(seg.dur * 1e6, 1)),
                           "args": {"cause": seg.cause}})
        tids = {s: i for i, s in enumerate(
            sorted({sp.step for sp in t.steps}), start=1)}
        for step, tid in tids.items():
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name", "args": {"name": step}})
        for sp in t.steps:
            tid = tids[sp.step]
            args = {"status": sp.status, "attempts": sp.attempts,
                    "epoch": sp.epoch}
            if sp.chunks:
                args["chunks"] = sp.chunks
            args.update({k: v for k, v in sp.annotations.items()
                         if isinstance(v, (str, int, float, bool))})
            events.append({"ph": "X", "pid": pid, "tid": tid,
                           "name": f"{sp.step}:{sp.status}", "cat": "step",
                           "ts": us(sp.start),
                           "dur": max(0.0, round(sp.dur * 1e6, 1)),
                           "args": args})
            for seg in sp.segments:
                events.append({"ph": "X", "pid": pid, "tid": tid,
                               "name": seg.kind, "cat": seg.kind,
                               "ts": us(seg.start),
                               "dur": max(0.0, round(seg.dur * 1e6, 1)),
                               "args": {"cause": seg.cause,
                                        "synthetic": seg.synthetic}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.core.obs",
                          "runs": len(trees)}}


_VALID_PH = {"B", "E", "X", "I", "i", "M", "C", "b", "e", "n", "s", "t",
             "f", "P", "N", "O", "D"}


def validate_chrome_trace(trace: Any) -> List[str]:
    """Schema check against the trace-event format Perfetto consumes.
    Returns a list of problems; empty means the export is loadable."""
    problems: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a 'traceEvents' array"]
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be an array"]
    for i, ev in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            problems.append(f"{where}: invalid ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing/empty name")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                problems.append(f"{where}: {k} must be an int")
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)) or ts < 0:
                problems.append(f"{where}: X event needs ts >= 0")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: X event needs dur >= 0")
        if ph == "M" and not isinstance(ev.get("args"), dict):
            problems.append(f"{where}: metadata event needs args")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"{where}: args must be an object")
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as e:
        problems.append(f"not JSON-serializable: {e}")
    return problems
