"""Streaming anomaly detection over fleet telemetry.

Four detector families, matching the failure modes the fault-tolerance
and admission layers actually produce:

* ``StragglerDetector`` — per-site (``workflow/step``) step durations
  through a streaming **robust z-score**: the site's trailing window
  yields a median and MAD (median absolute deviation), and a new
  duration fires when ``0.6745 * (d - median) / MAD`` exceeds the
  threshold. Robust statistics survive the odd slow sample that would
  wreck a mean/stddev detector; two extra guards (an absolute duration
  floor and a multiple-of-median floor) keep micro-jitter on
  millisecond-scale steps from ever firing — the clean-corpus
  zero-false-positive pin in ``tests/test_telemetry.py`` holds because
  of them.
* ``ReadmissionStormDetector`` — ``WORKFLOW_REQUEUED`` arrivals in a
  sliding window; crossing the count threshold fires once (hysteresis:
  re-arms only after the window drains), so sustained chaos yields one
  storm alert per episode, not one per requeue.
* ``CacheHitDriftDetector`` — per-store hit ratio over a short window
  vs a long window (from the ``cache_{hits,misses}_total{store=}``
  series in a ``TimeSeriesDB``); a drop beyond the threshold fires.
* ``AdmissionSaturationDetector`` — shed spikes (``admission_shed_total``
  increase over the window) and queue-depth saturation against a known
  capacity.

``AnomalyMonitor`` aggregates them behind two feeds:

* **event-driven** (``note_step_duration`` / ``note_requeue``) — called
  by the gateway on its loop thread as step terminals and requeues are
  published. Run-scoped alerts from these are *also* published in-band
  as typed ``ALERT`` events on the run's handle (the gateway does the
  publish), so ``TraceChecker`` (invariant 9) and ``ObsCollector`` see
  them in stream order.
* **series-driven** (``evaluate(tsdb)``) — called on each telemetry
  sampling tick for the fleet-scope detectors.

Every alert carries ``value``, ``threshold``, and the raw ``context``
that produced it, so the sanity fuzz can independently re-derive the
crossing (``scripts/sanity.py::telemetry_sanity``).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.obs.metrics import MetricsRegistry

__all__ = ["Alert", "AnomalyMonitor", "StragglerDetector",
           "ReadmissionStormDetector", "CacheHitDriftDetector",
           "AdmissionSaturationDetector"]


@dataclass(frozen=True)
class Alert:
    """One detector firing. ``value`` and ``threshold`` are the measured
    quantity and the bound it crossed (``value`` >= / > ``threshold``
    depending on the detector); ``context`` holds the raw inputs so the
    crossing can be re-derived independently."""

    detector: str                 # straggler | readmission_storm | ...
    reason: str                   # human-readable, rides in ALERT .error
    value: float
    threshold: float
    ts: float
    scope: str = ""               # site / tenant / store the alert is about
    severity: str = "warning"
    context: Dict[str, float] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        return {"detector": self.detector, "reason": self.reason,
                "value": self.value, "threshold": self.threshold,
                "ts": self.ts, "scope": self.scope,
                "severity": self.severity, "context": dict(self.context)}


def _median(vals: List[float]) -> float:
    s = sorted(vals)
    n = len(s)
    if n == 0:
        return 0.0
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


class StragglerDetector:
    """Per-site robust z-score over step durations.

    A duration fires only when ALL of:

    * the site has ``min_samples`` prior durations (cold sites never fire);
    * ``d`` > ``min_duration_s`` (absolute floor, checked FIRST: sub-jitter
      steps are never stragglers no matter how skewed, and the robust
      statistics are skipped entirely for them — this keeps the per-step
      cost flat on the gateway's terminal path);
    * ``z = 0.6745 * (d - median) / max(MAD, mad_floor)`` > ``z_threshold``;
    * ``d`` > ``median_ratio`` x the site median (scale-free floor).

    The median/MAD pair is cached per site and recomputed only every
    ``stats_refresh`` appends (amortized O(1) per note instead of an
    O(window log window) sort per step terminal); an alert's ``context``
    always carries the exact statistics it was judged against. The
    outlier is appended to the history *after* evaluation, so a
    straggler cannot mask itself — and a stale-by-a-few-samples cache
    only makes masking harder.
    """

    name = "straggler"

    def __init__(self, z_threshold: float = 4.0, min_samples: int = 8,
                 min_duration_s: float = 0.05, median_ratio: float = 2.0,
                 history: int = 128, mad_floor_s: float = 1e-4,
                 stats_refresh: int = 8):
        self.z_threshold = z_threshold
        self.min_samples = min_samples
        self.min_duration_s = min_duration_s
        self.median_ratio = median_ratio
        self.history = history
        self.mad_floor_s = mad_floor_s
        self.stats_refresh = max(1, stats_refresh)
        self._hist: Dict[str, List[float]] = {}
        # site -> [median, mad, scale, appends since compute]
        self._stats: Dict[str, List[float]] = {}

    def note(self, site: str, duration_s: float,
             ts: Optional[float] = None) -> Optional[Alert]:
        hist = self._hist.get(site)
        if hist is None:
            hist = []
            self._hist[site] = hist
        alert = None
        if duration_s > self.min_duration_s \
                and len(hist) >= self.min_samples:
            st = self._stats.get(site)
            if st is None or st[3] >= self.stats_refresh:
                med = _median(hist)
                mad = _median([abs(v - med) for v in hist])
                st = [med, mad, max(mad, self.mad_floor_s), 0.0]
                self._stats[site] = st
            med, mad, scale = st[0], st[1], st[2]
            z = 0.6745 * (duration_s - med) / scale
            if z > self.z_threshold and duration_s > self.median_ratio * med:
                ts = time.time() if ts is None else ts
                alert = Alert(
                    detector=self.name,
                    reason=(f"step duration {duration_s:.3f}s at {site} is "
                            f"z={z:.1f} above the site median "
                            f"{med:.3f}s (MAD {mad:.4f}s)"),
                    value=z, threshold=self.z_threshold, ts=ts, scope=site,
                    context={"duration_s": duration_s, "median_s": med,
                             "mad_s": mad, "scale_s": scale,
                             "n_samples": float(len(hist))})
        hist.append(duration_s)
        if len(hist) > self.history:
            del hist[0]
        if len(hist) > self.min_samples:        # sites below it have no stats
            st = self._stats.get(site)
            if st is not None:
                st[3] += 1.0
        return alert

    def site_history(self, site: str) -> List[float]:
        return list(self._hist.get(site, ()))


class ReadmissionStormDetector:
    """Sliding-window count of workflow requeues; fires once per episode
    (re-arms after the window drains below the threshold)."""

    name = "readmission_storm"

    def __init__(self, window_s: float = 30.0, threshold: int = 3):
        self.window_s = window_s
        self.threshold = threshold
        self._times: Deque[float] = deque()
        self._active = False

    def note(self, workflow: str, tenant: str, ts: float) -> Optional[Alert]:
        self._times.append(ts)
        lo = ts - self.window_s
        while self._times and self._times[0] < lo:
            self._times.popleft()
        n = len(self._times)
        if n < self.threshold:
            self._active = False
            return None
        if self._active:
            return None
        self._active = True
        return Alert(
            detector=self.name,
            reason=(f"{n} workflow requeues within {self.window_s:.0f}s "
                    f"(threshold {self.threshold}); latest: {workflow} "
                    f"(tenant {tenant})"),
            value=float(n), threshold=float(self.threshold), ts=ts,
            scope=tenant, severity="critical",
            context={"window_s": self.window_s, "count": float(n)})

    def recent_times(self) -> List[float]:
        return list(self._times)


class CacheHitDriftDetector:
    """Short-vs-long window hit-ratio drift per cache store (series-fed)."""

    name = "cache_hit_drift"

    def __init__(self, short_s: float = 30.0, long_s: float = 300.0,
                 drop_threshold: float = 0.2, min_requests: int = 50):
        self.short_s = short_s
        self.long_s = long_s
        self.drop_threshold = drop_threshold
        self.min_requests = min_requests

    def evaluate(self, tsdb, now: float) -> List[Alert]:
        out: List[Alert] = []
        for name in tsdb.names():
            if not name.startswith("cache_hits_total"):
                continue
            suffix = name[len("cache_hits_total"):]     # "{store=...}" or ""
            misses = f"cache_misses_total{suffix}"
            h_s = tsdb.delta(name, self.short_s, now=now)
            m_s = tsdb.delta(misses, self.short_s, now=now)
            h_l = tsdb.delta(name, self.long_s, now=now)
            m_l = tsdb.delta(misses, self.long_s, now=now)
            n_s, n_l = h_s + m_s, h_l + m_l
            if n_s < self.min_requests or n_l < self.min_requests:
                continue
            r_s, r_l = h_s / n_s, h_l / n_l
            drop = r_l - r_s
            if drop > self.drop_threshold:
                out.append(Alert(
                    detector=self.name,
                    reason=(f"cache hit ratio {suffix or '(aggregate)'} "
                            f"dropped {drop:.2f}: {r_l:.2f} over "
                            f"{self.long_s:.0f}s vs {r_s:.2f} over "
                            f"{self.short_s:.0f}s"),
                    value=drop, threshold=self.drop_threshold, ts=now,
                    scope=suffix.strip("{}"),
                    context={"ratio_short": r_s, "ratio_long": r_l,
                             "n_short": n_s, "n_long": n_l}))
        return out


class AdmissionSaturationDetector:
    """Shed spikes + queue-depth saturation (series-fed)."""

    name = "admission_saturation"

    def __init__(self, window_s: float = 30.0, shed_threshold: int = 5,
                 depth_capacity: Optional[int] = None,
                 depth_ratio: float = 0.9):
        self.window_s = window_s
        self.shed_threshold = shed_threshold
        self.depth_capacity = depth_capacity
        self.depth_ratio = depth_ratio

    def evaluate(self, tsdb, now: float) -> List[Alert]:
        out: List[Alert] = []
        shed = tsdb.delta("admission_shed_total", self.window_s, now=now)
        if shed >= self.shed_threshold:
            out.append(Alert(
                detector=self.name,
                reason=(f"admission shed {shed:.0f} submissions in the "
                        f"last {self.window_s:.0f}s "
                        f"(threshold {self.shed_threshold})"),
                value=shed, threshold=float(self.shed_threshold), ts=now,
                scope="shed", severity="critical",
                context={"window_s": self.window_s}))
        if self.depth_capacity:
            depth = tsdb.latest("admission_depth") or 0.0
            ratio = depth / self.depth_capacity
            if ratio >= self.depth_ratio:
                out.append(Alert(
                    detector=self.name,
                    reason=(f"admission queue depth {depth:.0f} is at "
                            f"{100 * ratio:.0f}% of capacity "
                            f"{self.depth_capacity}"),
                    value=ratio, threshold=self.depth_ratio, ts=now,
                    scope="depth",
                    context={"depth": depth,
                             "capacity": float(self.depth_capacity)}))
        return out


class AnomalyMonitor:
    """Detector aggregate: event feeds + per-tick series evaluation.

    Alerts land in a bounded log (``alerts``) and bump
    ``alerts_total{detector=}`` in the bound registry. The gateway binds
    its own registry (``bind``) so alert counters appear in the same
    snapshot the telemetry loop samples.
    """

    def __init__(self,
                 straggler: Optional[StragglerDetector] = None,
                 readmission: Optional[ReadmissionStormDetector] = None,
                 series_detectors: Optional[List[object]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 max_alerts: int = 1024):
        self.straggler = straggler if straggler is not None \
            else StragglerDetector()
        self.readmission_storm = readmission if readmission is not None \
            else ReadmissionStormDetector()
        self.series_detectors = list(series_detectors) \
            if series_detectors is not None \
            else [CacheHitDriftDetector(), AdmissionSaturationDetector()]
        self._lock = threading.Lock()
        self.alerts: Deque[Alert] = deque(maxlen=max_alerts)
        self._registry = registry

    def bind(self, registry: MetricsRegistry) -> "AnomalyMonitor":
        self._registry = registry
        return self

    # -- event-driven feeds (single writer: the gateway loop thread; the
    # detectors themselves are not locked — only the shared alert log is)
    def note_step_duration(self, workflow: str, step: str,
                           duration_s: float, tenant: str = "default",
                           ts: Optional[float] = None) -> Optional[Alert]:
        # ts stays lazy: the detector only needs a timestamp when it
        # actually fires, and this is the gateway's per-step hot path
        alert = self.straggler.note(f"{workflow}/{step}", duration_s, ts)
        if alert is not None:
            self.record(alert)
        return alert

    def note_requeue(self, workflow: str, tenant: str = "default",
                     ts: Optional[float] = None) -> Optional[Alert]:
        ts = time.time() if ts is None else ts
        alert = self.readmission_storm.note(workflow, tenant, ts)
        if alert is not None:
            self.record(alert)
        return alert

    # -- series-driven feed (telemetry tick) -------------------------------
    def evaluate(self, tsdb, now: Optional[float] = None) -> List[Alert]:
        now = time.time() if now is None else now
        fired: List[Alert] = []
        with self._lock:
            for det in self.series_detectors:
                try:
                    fired.extend(det.evaluate(tsdb, now))
                except Exception:   # noqa: BLE001 — detection is advisory
                    pass
            for a in fired:
                self._record_locked(a)
        return fired

    # -- bookkeeping -------------------------------------------------------
    def record(self, alert: Alert) -> None:
        """Record an externally produced alert (e.g. SLO burn) in the same
        log/counters."""
        with self._lock:
            self._record_locked(alert)

    def _record_locked(self, alert: Alert) -> None:
        self.alerts.append(alert)
        if self._registry is not None:
            self._registry.counter("alerts_total",
                                   detector=alert.detector).inc()

    def firing(self, within_s: float = 60.0,
               now: Optional[float] = None) -> List[Alert]:
        """Alerts raised within the trailing window (dashboard view)."""
        now = time.time() if now is None else now
        lo = now - within_s
        with self._lock:
            return [a for a in self.alerts if a.ts >= lo]

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for a in self.alerts:
                out[a.detector] = out.get(a.detector, 0) + 1
            return out
