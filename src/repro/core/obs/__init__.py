"""Unified observability fabric: spans, metrics, critical-path attribution.

Zero-dependency instrumentation substrate for the workflow stack
(the measurement layer behind the paper's production claims — +15%
utilization / +17% completion rate are only observable if the system can
say where workflow time goes). Three pillars:

* ``metrics`` — thread-safe ``MetricsRegistry`` (counters / gauges /
  fixed-bucket histograms). Every component ``stats`` dict
  (``WorkflowGateway``, ``AdmissionQueue``, ``TieredCacheStore``,
  ``ChaosInjector``, ``MultiClusterEngine``) is now a compatibility view
  over registry instruments; stable metric names are catalogued in
  ``docs/observability.md``.
* ``spans`` — ``ObsCollector`` derives a span tree per run from the
  gateway's typed event stream: workflow span → step spans with
  queue-wait / cache-fetch / compute / retry / readmission-backoff /
  stream-stall segments, annotated with ``STEP_RETRY`` / ``WORKER_LOST``
  / ``CLUSTER_PREEMPTED`` / ``WORKFLOW_REQUEUED`` causes. Exports JSONL
  and Chrome trace-event JSON (Perfetto-loadable).
* ``attribution`` — critical-path analyzer turning a finished tree into
  a ``MakespanReport`` ("62% compute on train, 21% queue wait, ...")
  whose segments partition the makespan exactly.

Continuous-telemetry pillars on top of those (PR 10):

* ``timeseries`` — bounded ring-buffer ``TimeSeriesDB`` sampling
  registry snapshots on the gateway daemon loop (windowed rate /
  percentile queries, JSONL persistence);
* ``anomaly`` — streaming detectors (per-site straggler robust z-score,
  readmission storms, cache-hit drift, admission saturation) emitting
  typed ``ALERT`` events in-band on run streams;
* ``slo`` — per-tenant SLO objectives with multi-window burn-rate
  evaluation and an optional admission-queue priority nudge;
* ``exposition`` — OpenMetrics text rendering of any snapshot.

Entry points: ``couler.observe(engine)`` attaches a collector to an
engine (every subsequent run is traced; ``run.report()`` then renders the
breakdown), ``couler.telemetry(engine)`` turns on continuous sampling +
anomaly detection, ``scripts/obs_report.py`` is the offline CLI over
JSONL exports, and ``scripts/obs_dashboard.py`` renders the live fleet
view.
"""
from repro.core.obs.metrics import (Counter, Gauge, Histogram,
                                    MetricsRegistry, StatsView)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
    "ObsCollector", "Segment", "SpanTree", "StepSpan", "chrome_trace",
    "load_jsonl", "validate_chrome_trace",
    "MakespanReport", "build_report", "critical_path", "observe",
    "TimeSeriesDB",
    "Alert", "AnomalyMonitor", "StragglerDetector",
    "ReadmissionStormDetector", "CacheHitDriftDetector",
    "AdmissionSaturationDetector",
    "SLO", "SLOMonitor",
    "render_openmetrics", "parse_openmetrics",
]

# spans/attribution import the gateway event taxonomy, while the gateway
# stack imports obs.metrics — loading those pillars lazily (PEP 562) keeps
# ``from repro.core.obs.metrics import ...`` cycle-free for every entry
# point into the package graph
_LAZY = {
    "ObsCollector": "spans", "Segment": "spans", "SpanTree": "spans",
    "StepSpan": "spans", "chrome_trace": "spans", "load_jsonl": "spans",
    "validate_chrome_trace": "spans",
    "MakespanReport": "attribution", "build_report": "attribution",
    "critical_path": "attribution",
    "TimeSeriesDB": "timeseries",
    "Alert": "anomaly", "AnomalyMonitor": "anomaly",
    "StragglerDetector": "anomaly", "ReadmissionStormDetector": "anomaly",
    "CacheHitDriftDetector": "anomaly",
    "AdmissionSaturationDetector": "anomaly",
    "SLO": "slo", "SLOMonitor": "slo",
    "render_openmetrics": "exposition", "parse_openmetrics": "exposition",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f"{__name__}.{mod}"), name)


def observe(engine, collector=None):
    """Attach an ``ObsCollector`` to ``engine`` so every subsequent run
    gets a span tree and ``run.report()`` works. Gateway-native engines
    (``LocalEngine``) trace at full step granularity; ``MultiClusterEngine``
    ingests the coarse admitted-batch streams via ``attach_collector``.
    Returns the collector (pass an existing one to share it)."""
    from repro.core.obs.spans import ObsCollector
    c = collector or ObsCollector()
    gw = getattr(engine, "gateway", None)
    if gw is not None and hasattr(gw, "attach_collector"):
        gw.attach_collector(c)
    elif hasattr(engine, "attach_collector"):
        engine.attach_collector(c)
    else:
        raise TypeError(
            f"engine {type(engine).__name__} has no gateway or "
            "attach_collector — nothing to observe")
    return c
