"""Per-tenant SLO objectives with multi-window burn-rate alerting.

An ``SLO`` names a tenant's objectives; three are supported, matching
what the gateway can actually measure per finished run:

* ``completion_rate`` — fraction of runs ending ``Succeeded``. The error
  budget is ``1 - completion_rate``; a window's **burn rate** is its
  observed failure fraction divided by that budget (burn 1.0 = exactly
  spending budget, >1 = over-spending).
* ``p99_queue_wait_s`` — admission-to-first-processing latency bound.
  Latency SLOs burn against a fixed violation budget: at p99 the budget
  is 1% of runs, so burn = (fraction of runs waiting longer) / 0.01.
* ``makespan_budget_s`` — per-run wall-clock budget, evaluated at p95
  (violation budget 5%).

Evaluation uses the classic **multi-window** rule: an objective fires
only when BOTH the short window (fast signal) and the long window
(sustained evidence) burn above ``burn_threshold`` — a lone hiccup in
the short window or stale history in the long one cannot fire alone.
Each firing yields an ``Alert`` (detector ``slo_burn``) carrying both
burns and the window sizes in its context.

``nudge(queue)`` is the optional control-loop half: tenants currently
burning get their ``AdmissionQueue`` weighted-round-robin weight
multiplied by ``nudge_factor`` (capped), and recover their base weight
once the burn clears — SLO pressure translates into scheduling priority
without touching the queue's fairness machinery.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Iterable, List, Optional, Tuple

from repro.core.obs.anomaly import Alert
from repro.core.obs.metrics import MetricsRegistry

__all__ = ["SLO", "SLOMonitor"]

#: latency objectives burn against a fixed violation-fraction budget
_P99_BUDGET = 0.01
_P95_BUDGET = 0.05


@dataclass(frozen=True)
class SLO:
    """One tenant's objectives (None disables an objective)."""

    tenant: str = "default"
    completion_rate: Optional[float] = 0.95
    p99_queue_wait_s: Optional[float] = None
    makespan_budget_s: Optional[float] = None

    def __post_init__(self):
        if self.completion_rate is not None \
                and not 0.0 < self.completion_rate < 1.0:
            raise ValueError("completion_rate must be in (0, 1)")


# one finished run: (ts, succeeded, makespan_s, queue_wait_s)
_RunPoint = Tuple[float, bool, float, float]


class SLOMonitor:
    """Rolling per-tenant run records + multi-window burn evaluation."""

    def __init__(self, objectives: Iterable[SLO],
                 short_window_s: float = 60.0,
                 long_window_s: float = 300.0,
                 burn_threshold: float = 2.0,
                 min_runs: int = 5,
                 nudge_factor: int = 2,
                 max_weight: int = 8,
                 history: int = 4096,
                 registry: Optional[MetricsRegistry] = None):
        self.objectives: Dict[str, SLO] = {}
        for slo in objectives:
            if slo.tenant in self.objectives:
                raise ValueError(f"duplicate SLO for tenant {slo.tenant!r}")
            self.objectives[slo.tenant] = slo
        self.short_window_s = short_window_s
        self.long_window_s = long_window_s
        self.burn_threshold = burn_threshold
        self.min_runs = min_runs
        self.nudge_factor = max(1, nudge_factor)
        self.max_weight = max_weight
        self.history = history
        self._lock = threading.Lock()
        self._runs: Dict[str, Deque[_RunPoint]] = {}
        self.alerts: Deque[Alert] = deque(maxlen=1024)
        self._registry = registry
        # tenants currently burning (per last evaluate) and the base
        # weights nudge() overrode, for restoration on recovery
        self._burning: Dict[str, List[str]] = {}
        self._base_weights: Dict[str, int] = {}

    def bind(self, registry: MetricsRegistry) -> "SLOMonitor":
        self._registry = registry
        return self

    # -- feed (gateway loop thread, at WORKFLOW_DONE) ----------------------
    def note_run(self, tenant: str, ok: bool, makespan_s: float = 0.0,
                 queue_wait_s: float = 0.0,
                 ts: Optional[float] = None) -> None:
        ts = time.time() if ts is None else ts
        with self._lock:
            dq = self._runs.get(tenant)
            if dq is None:
                dq = deque(maxlen=self.history)
                self._runs[tenant] = dq
            dq.append((ts, ok, makespan_s, queue_wait_s))

    # -- evaluation --------------------------------------------------------
    def _objective_burns(self, slo: SLO, now: float
                         ) -> List[Tuple[str, float, float, float, int, int]]:
        """Per enabled objective: (name, budget, burn_short, burn_long,
        n_short, n_long). One fused pass over the tenant's run ring
        counts both windows and every violation kind at once — this runs
        for every tenant on every telemetry tick, so it must not build
        per-window lists per objective."""
        lo_s = now - self.short_window_s
        lo_l = now - self.long_window_s
        qbound = slo.p99_queue_wait_s
        mbound = slo.makespan_budget_s
        n_s = n_l = 0
        fail_s = fail_l = qw_s = qw_l = mk_s = mk_l = 0
        for ts, ok, mk, qw in self._runs.get(slo.tenant, ()):
            in_s, in_l = ts >= lo_s, ts >= lo_l
            if not (in_s or in_l):
                continue
            if in_s:
                n_s += 1
            if in_l:
                n_l += 1
            if not ok:
                fail_s += in_s
                fail_l += in_l
            if qbound is not None and qw > qbound:
                qw_s += in_s
                qw_l += in_l
            if mbound is not None and mk > mbound:
                mk_s += in_s
                mk_l += in_l

        def burn(n_bad: int, n: int, budget: float) -> float:
            return (n_bad / n) / budget if n and budget > 0 else 0.0

        out = []
        if slo.completion_rate is not None:
            budget = 1.0 - slo.completion_rate
            out.append(("completion_rate", budget,
                        burn(fail_s, n_s, budget), burn(fail_l, n_l, budget),
                        n_s, n_l))
        if qbound is not None:
            out.append(("p99_queue_wait_s", _P99_BUDGET,
                        burn(qw_s, n_s, _P99_BUDGET),
                        burn(qw_l, n_l, _P99_BUDGET), n_s, n_l))
        if mbound is not None:
            out.append(("makespan_budget_s", _P95_BUDGET,
                        burn(mk_s, n_s, _P95_BUDGET),
                        burn(mk_l, n_l, _P95_BUDGET), n_s, n_l))
        return out

    def evaluate(self, now: Optional[float] = None) -> List[Alert]:
        """Multi-window burn evaluation for every tenant; returns (and
        logs) the alerts fired this pass. Objectives with fewer than
        ``min_runs`` runs in the short window never fire."""
        now = time.time() if now is None else now
        fired: List[Alert] = []
        with self._lock:
            burning: Dict[str, List[str]] = {}
            for tenant, slo in self.objectives.items():
                for (name, budget, b_s, b_l, n_s, n_l) \
                        in self._objective_burns(slo, now):
                    if n_s < self.min_runs:
                        continue
                    if b_s > self.burn_threshold \
                            and b_l > self.burn_threshold:
                        burning.setdefault(tenant, []).append(name)
                        fired.append(Alert(
                            detector="slo_burn",
                            reason=(f"tenant {tenant!r} burning {name} "
                                    f"error budget at {b_s:.1f}x (short "
                                    f"{self.short_window_s:.0f}s) / "
                                    f"{b_l:.1f}x (long "
                                    f"{self.long_window_s:.0f}s); "
                                    f"threshold {self.burn_threshold:.1f}x"),
                            value=min(b_s, b_l),
                            threshold=self.burn_threshold,
                            ts=now, scope=tenant, severity="critical",
                            context={"burn_short": b_s, "burn_long": b_l,
                                     "budget": budget,
                                     "n_short": float(n_s),
                                     "n_long": float(n_l),
                                     "short_window_s": self.short_window_s,
                                     "long_window_s": self.long_window_s}))
            self._burning = burning
            for a in fired:
                self.alerts.append(a)
                if self._registry is not None:
                    self._registry.counter("alerts_total",
                                           detector="slo_burn").inc()
        return fired

    # -- dashboard view ----------------------------------------------------
    def status(self, now: Optional[float] = None) -> Dict[str, Dict]:
        """Per-tenant compliance snapshot (the dashboard's SLO table)."""
        now = time.time() if now is None else now
        out: Dict[str, Dict] = {}
        with self._lock:
            for tenant, slo in self.objectives.items():
                objs = {}
                for (name, budget, b_s, b_l, n_s, n_l) \
                        in self._objective_burns(slo, now):
                    objs[name] = {"burn_short": b_s, "burn_long": b_l,
                                  "n_short": n_s, "n_long": n_l,
                                  "burning": (n_s >= self.min_runs
                                              and b_s > self.burn_threshold
                                              and b_l > self.burn_threshold)}
                out[tenant] = {
                    "objectives": objs,
                    "burning": tenant in self._burning,
                    "runs_seen": len(self._runs.get(tenant, ())),
                }
        return out

    # -- admission priority nudge ------------------------------------------
    def nudge(self, queue) -> Dict[str, int]:
        """Translate burn into WRR priority: burning tenants get their
        queue weight multiplied by ``nudge_factor`` (capped at
        ``max_weight``); recovered tenants get their base weight back.
        Returns the weights changed this call."""
        changed: Dict[str, int] = {}
        with self._lock:
            burning = set(self._burning)
            for tenant in burning:
                base = self._base_weights.get(tenant)
                if base is None:
                    base = int(queue.weights.get(tenant,
                                                 queue.default_weight))
                    self._base_weights[tenant] = base
                w = min(self.max_weight, base * self.nudge_factor)
                if queue.weights.get(tenant) != w:
                    queue.weights[tenant] = w
                    changed[tenant] = w
            for tenant in list(self._base_weights):
                if tenant in burning:
                    continue
                base = self._base_weights.pop(tenant)
                if queue.weights.get(tenant) != base:
                    queue.weights[tenant] = base
                    changed[tenant] = base
        return changed
