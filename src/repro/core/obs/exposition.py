"""OpenMetrics / Prometheus text exposition of registry snapshots.

``render_openmetrics`` turns any ``MetricsRegistry`` (or a flat
``snapshot()`` dict, or a merge of several) into the OpenMetrics text
format — the lingua franca every scrape pipeline understands — with zero
dependencies:

* flat snapshot keys (``name{k=v,...}``, see ``metrics.format_series``)
  are parsed back into metric family + label set;
* families ending in ``_total`` render as ``counter`` (the OpenMetrics
  family name drops the suffix; samples keep it), histogram values
  (dicts with ``buckets``) render as ``histogram`` with cumulative
  ``_bucket{le=...}`` samples plus ``_sum``/``_count``, everything else
  is a ``gauge``;
* label values are escaped per the spec (backslash, quote, newline) and
  the exposition ends with the mandatory ``# EOF``.

``parse_openmetrics`` is the minimal inverse used by the tests and the
sanity fuzz: it validates line structure and returns the flat
``{sample_name{labels}: value}`` dict, so round-tripping a snapshot is
an executable check that the output actually parses.
"""
from __future__ import annotations

import re
from typing import Dict, List, Mapping, Tuple, Union

__all__ = ["render_openmetrics", "parse_openmetrics"]

_LABEL_ESCAPES = {"\\": "\\\\", '"': '\\"', "\n": "\\n"}
_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")
_SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'                  # sample name
    r'(?:\{((?:[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*",?)*)\})?'
    r'\s+(-?(?:[0-9]*\.)?[0-9]+(?:[eE][+-]?[0-9]+)?|[+-]?Inf|NaN)'
    r'(?:\s+-?[0-9.eE+]+)?$')                       # optional timestamp
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def _escape(v: str) -> str:
    return "".join(_LABEL_ESCAPES.get(c, c) for c in str(v))


def _sanitize_name(name: str) -> str:
    """Metric names must match the OpenMetrics charset; the registry's
    names already do, but flattened series from other sources may not."""
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not out or not _NAME_RE.match(out):
        out = "_" + out
    return out

def _parse_flat(flat: str) -> Tuple[str, List[Tuple[str, str]]]:
    """Split a snapshot key ``name{k=v,...}`` into (name, labels)."""
    if "{" not in flat or not flat.endswith("}"):
        return flat, []
    name, _, inner = flat.partition("{")
    labels = []
    for part in inner[:-1].split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        labels.append((k, v))
    return name, labels


def _fmt_labels(labels: List[Tuple[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels)
    return "{" + inner + "}"


def render_openmetrics(
        source: Union[Mapping[str, object], object]) -> str:
    """Render a registry (anything with ``snapshot()``) or a flat
    snapshot mapping as OpenMetrics text (terminated by ``# EOF``)."""
    snap = source.snapshot() if hasattr(source, "snapshot") else dict(source)
    # group series by family, preserving first-seen order
    families: "Dict[str, List[Tuple[List[Tuple[str, str]], object]]]" = {}
    for flat, value in snap.items():
        name, labels = _parse_flat(flat)
        families.setdefault(_sanitize_name(name), []).append((labels, value))

    lines: List[str] = []
    for name, series in families.items():
        first = series[0][1]
        if isinstance(first, Mapping) and "buckets" in first:
            lines.append(f"# TYPE {name} histogram")
            for labels, value in series:
                if not (isinstance(value, Mapping) and "buckets" in value):
                    continue
                for le, cum in value["buckets"].items():
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(labels + [('le', str(le))])}"
                        f" {float(cum):g}")
                lines.append(f"{name}_sum{_fmt_labels(labels)}"
                             f" {float(value['sum']):g}")
                lines.append(f"{name}_count{_fmt_labels(labels)}"
                             f" {float(value['count']):g}")
        elif name.endswith("_total"):
            family = name[:-len("_total")]
            lines.append(f"# TYPE {family} counter")
            for labels, value in series:
                if isinstance(value, (int, float)) \
                        and not isinstance(value, bool):
                    lines.append(f"{family}_total{_fmt_labels(labels)}"
                                 f" {float(value):g}")
        else:
            lines.append(f"# TYPE {name} gauge")
            for labels, value in series:
                if isinstance(value, (int, float)) \
                        and not isinstance(value, bool):
                    lines.append(f"{name}{_fmt_labels(labels)}"
                                 f" {float(value):g}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def parse_openmetrics(text: str) -> Dict[str, float]:
    """Minimal OpenMetrics parser: validates structure, returns the flat
    ``{sample{labels}: value}`` dict. Raises ``ValueError`` on malformed
    lines or a missing ``# EOF`` terminator."""
    samples: Dict[str, float] = {}
    saw_eof = False
    for i, line in enumerate(text.splitlines(), start=1):
        line = line.rstrip()
        if not line:
            continue
        if saw_eof:
            raise ValueError(f"line {i}: content after # EOF")
        if line == "# EOF":
            saw_eof = True
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) < 4 or parts[1] not in ("TYPE", "HELP", "UNIT"):
                raise ValueError(f"line {i}: malformed comment {line!r}")
            if parts[1] == "TYPE" and parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "info",
                    "stateset", "unknown"):
                raise ValueError(f"line {i}: unknown type {parts[3]!r}")
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {i}: malformed sample {line!r}")
        name, raw_labels, raw_value = m.group(1), m.group(2), m.group(3)
        labels = _LABEL_RE.findall(raw_labels) if raw_labels else []
        key = name + _fmt_labels([(k, v) for k, v in labels])
        if raw_value in ("+Inf", "Inf"):
            value = float("inf")
        elif raw_value == "-Inf":
            value = float("-inf")
        else:
            value = float(raw_value)
        samples[key] = value
    if not saw_eof:
        raise ValueError("missing # EOF terminator")
    return samples
