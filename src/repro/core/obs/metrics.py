"""Thread-safe metrics registry — the counters/gauges/histograms pillar.

Every hand-rolled ``stats`` dict in the gateway stack (``WorkflowGateway``,
``AdmissionQueue``, ``TieredCacheStore``, ``ChaosInjector``,
``MultiClusterEngine``) is now backed by instruments from a
``MetricsRegistry``; the old dict surface survives as a read-compatible
``StatsView`` so ``gateway.stats["submitted"]`` keeps working unchanged.

Design constraints, in order:

* **Correct under concurrency.** ``Counter.inc`` / ``Gauge.set`` take a
  per-instrument lock — increments from the gateway's step pool, the
  asyncio loop thread, and caller threads never lose updates (the old
  ``dict[key] += 1`` read-modify-write did).
* **Cheap.** One uncontended lock acquire per update (~0.3 µs); the
  ``observability_overhead`` benchmark pins the whole fabric below 2% of
  the n=2000 event-driven submit path.
* **Zero dependencies.** Plain ``threading``; export is a plain dict
  (``MetricsRegistry.snapshot``) in stable, documented names — see
  ``docs/observability.md`` for the catalog.

Labels: instruments are keyed by ``(name, sorted(label items))`` so
``registry.counter("admission_shed_total", tenant="a")`` and the same name
with ``tenant="b"`` are distinct series, like Prometheus label sets.
"""
from __future__ import annotations

import bisect
import threading
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
           "DEFAULT_BUCKETS"]

# fixed histogram buckets (seconds): sub-ms dispatch up to minute-scale
# training steps; +Inf is implicit
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
    10.0, 30.0, 60.0)


def _series_key(name: str, labels: Mapping[str, str]
                ) -> Tuple[str, Tuple[Tuple[str, str], ...]]:
    return name, tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def format_series(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    """Stable flat spelling of a series: ``name{k=v,...}`` (no labels:
    just ``name``) — the snapshot/export key format."""
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic (float-friendly) counter. ``inc`` only; ``set`` exists
    solely for the dict-compat write path (``StatsView.__setitem__``)."""

    __slots__ = ("name", "labels", "_lock", "_v")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, v: float = 1) -> None:
        with self._lock:
            self._v += v

    def set(self, v: float) -> None:
        with self._lock:
            self._v = v

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Gauge(Counter):
    """Point-in-time value; adds ``dec`` and max-tracking ``set_max``."""

    __slots__ = ()

    def dec(self, v: float = 1) -> None:
        self.inc(-v)

    def add(self, v: float) -> float:
        """Atomic add-and-read (in-flight accounting wants the new value
        to feed a peak gauge without a second race window)."""
        with self._lock:
            self._v += v
            return self._v

    def set_max(self, v: float) -> None:
        """Monotonic high-water mark (``peak_inflight_steps``)."""
        with self._lock:
            if v > self._v:
                self._v = v


class Histogram:
    """Fixed-bucket histogram: cumulative counts per upper bound, plus
    ``sum``/``count`` for mean derivation. Buckets never change after
    construction, so concurrent observes only touch the counts array."""

    __slots__ = ("name", "labels", "buckets", "_lock", "_counts",
                 "_sum", "_count")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = (),
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.buckets = tuple(sorted(buckets))
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.buckets) + 1)   # last = +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def value(self) -> Dict[str, object]:
        """Snapshot: ``{"count", "sum", "buckets": {le: cumulative}}``."""
        with self._lock:
            counts = list(self._counts)
            s, n = self._sum, self._count
        out: Dict[str, object] = {"count": n, "sum": s}
        cum, buckets = 0, {}
        for ub, c in zip(self.buckets, counts):
            cum += c
            buckets[str(ub)] = cum
        buckets["+Inf"] = n
        out["buckets"] = buckets
        return out

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper bound of the bucket
        holding the q-th observation); +Inf bucket reports the largest
        finite bound."""
        with self._lock:
            counts = list(self._counts)
            n = self._count
        if n == 0:
            return 0.0
        target = max(1, int(q * n + 0.5))
        cum = 0
        for ub, c in zip(self.buckets, counts):
            cum += c
            if cum >= target:
                return ub
        return self.buckets[-1]


class MetricsRegistry:
    """Get-or-create instrument registry; every accessor is thread-safe
    and idempotent (same name+labels → same instrument)."""

    def __init__(self, namespace: str = ""):
        self.namespace = namespace
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple, object] = {}
        # lazy gauges: evaluated at snapshot() time (per-tier cache bytes,
        # queue depths — anything already tracked elsewhere)
        self._callbacks: Dict[str, Callable[[], float]] = {}

    def _get(self, cls, name: str, labels: Mapping[str, str],
             **kw) -> object:
        key = _series_key(name, labels)
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, key[1], **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels: str) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    def gauge_fn(self, name: str, fn: Callable[[], float]) -> None:
        """Register a callback gauge, sampled at ``snapshot()``."""
        with self._lock:
            self._callbacks[name] = fn

    # -- series removal (label GC) -----------------------------------------
    def drop(self, name: str, **labels: str) -> bool:
        """Remove one series (or, for a bare name with no instrument, a
        ``gauge_fn`` callback). Returns False when absent. The instrument
        object itself stays valid for holders of a stale reference — it
        just no longer appears in snapshots."""
        key = _series_key(name, labels)
        with self._lock:
            if self._instruments.pop(key, None) is not None:
                return True
            return self._callbacks.pop(name, None) is not None

    def drop_labeled(self, label: str, value: str) -> int:
        """Remove every series carrying ``label == value`` (per-tenant
        label GC for departed tenants). Returns the number dropped."""
        pair = (str(label), str(value))
        with self._lock:
            doomed = [k for k in self._instruments if pair in k[1]]
            for k in doomed:
                del self._instruments[k]
        return len(doomed)

    # -- export ------------------------------------------------------------
    def series(self) -> List[Tuple[str, object]]:
        with self._lock:
            insts = list(self._instruments.values())
            cbs = list(self._callbacks.items())
        extra: List[Tuple[str, object]] = []
        errors = 0
        for name, fn in cbs:
            try:
                extra.append((name, fn()))
            except Exception:   # noqa: BLE001 — sampling is best-effort
                errors += 1
        if errors:
            # a raising gauge_fn must not poison the snapshot — count it
            # (``gauge_fn_errors_total``) and keep sampling the rest
            c = self.counter("gauge_fn_errors_total")
            c.inc(errors)
            if all(i is not c for i in insts):
                insts.append(c)
        out: List[Tuple[str, object]] = [
            (format_series(i.name, i.labels), i.value) for i in insts]
        return out + extra

    def snapshot(self) -> Dict[str, object]:
        """Flat ``{series_name: value}`` dict (histograms nest their
        bucket dict). Stable names: see ``docs/observability.md``."""
        return dict(self.series())

    def get_value(self, name: str, **labels: str) -> float:
        """Read one series without creating it (0 if absent)."""
        key = _series_key(name, labels)
        with self._lock:
            inst = self._instruments.get(key)
        return inst.value if inst is not None else 0


class StatsView:
    """Read/write dict-compatible facade over registry instruments.

    Legacy code and tests address component telemetry as plain dicts
    (``gateway.stats["submitted"]``, ``eng.metrics["cluster_busy_s"]``);
    this view maps each legacy key to a live instrument — or to a callable
    for composite values like the per-cluster busy-seconds dict — so those
    call sites keep working verbatim while mutations flow through the
    thread-safe instruments. Supports the Mapping protocol plus
    ``__setitem__`` (hard-set, used by a few legacy writers); ``+=``
    through the view is only as atomic as the caller's own locking, which
    is why internal hot paths call ``Counter.inc`` directly instead.
    """

    __slots__ = ("_fields",)

    def __init__(self, fields: Mapping[str, object]):
        # value per key: Counter/Gauge instrument, or zero-arg callable
        self._fields = dict(fields)

    def _read(self, key: str):
        f = self._fields[key]
        if isinstance(f, (Counter, Histogram)):
            return f.value
        return f()

    # -- Mapping protocol --------------------------------------------------
    def __getitem__(self, key: str):
        return self._read(key)

    def __setitem__(self, key: str, value) -> None:
        f = self._fields[key]
        if not isinstance(f, Counter):
            raise TypeError(f"stats field {key!r} is derived; cannot set")
        f.set(value)

    def get(self, key: str, default=None):
        return self._read(key) if key in self._fields else default

    def __contains__(self, key: str) -> bool:
        return key in self._fields

    def __iter__(self) -> Iterable[str]:
        return iter(self._fields)

    def __len__(self) -> int:
        return len(self._fields)

    def keys(self):
        return self._fields.keys()

    def values(self):
        return [self._read(k) for k in self._fields]

    def items(self):
        return [(k, self._read(k)) for k in self._fields]

    def copy(self) -> Dict[str, object]:
        return dict(self.items())

    def __eq__(self, other) -> bool:
        if isinstance(other, StatsView):
            other = other.copy()
        if isinstance(other, Mapping) or isinstance(other, dict):
            return self.copy() == dict(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __repr__(self) -> str:
        return f"StatsView({self.copy()!r})"
