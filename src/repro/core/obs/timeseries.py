"""Bounded ring-buffer time-series store over ``MetricsRegistry`` snapshots.

The PR-9 fabric answers per-run questions; fleet health (the paper's
+15% utilization / +17% completion claims are *trends*) needs the same
metrics **over time**. ``TimeSeriesDB`` is deliberately tiny: one bounded
ring per labeled series, fed by periodically calling ``sample`` with a
registry snapshot — the gateway does this from a daemon-loop task at
``telemetry_interval_s`` cadence (``couler.telemetry(engine)``), and any
offline consumer can do the same with a recorded JSONL file.

* **Label-aware**: series keep the flat snapshot spelling
  (``name{k=v,...}``, see ``metrics.format_series``) so admission's
  per-tenant depth and the cache's per-store hit counters stay distinct.
* **Bounded**: each ring holds the last ``capacity`` points; memory is
  O(series x capacity) regardless of gateway uptime.
* **Histogram flattening**: histogram snapshots (dicts) are stored as two
  scalar series ``name:count`` / ``name:sum`` — enough for windowed rate
  and mean queries without per-bucket rings.
* **Windowed queries**: ``delta``/``rate`` treat a series as a monotonic
  counter (increase over the trailing window); ``quantile`` treats the
  ring's point *values* as a gauge distribution.
* **JSONL persistence**: pass ``path=`` to append one
  ``{"ts": ..., "series": {...}}`` line per sample; ``load_jsonl``
  rebuilds a ``TimeSeriesDB`` from such a file for offline dashboards.

Zero dependencies, thread-safe (one lock; samplers and readers may live
on different threads).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Mapping, Optional, Tuple

__all__ = ["TimeSeriesDB", "Point"]

#: one sample: (unix timestamp, value)
Point = Tuple[float, float]


class TimeSeriesDB:
    """Bounded per-series rings of ``(ts, value)`` points."""

    def __init__(self, capacity: int = 512, path: Optional[str] = None):
        if capacity < 2:
            raise ValueError("capacity must be >= 2")
        self.capacity = capacity
        self.path = path
        self._lock = threading.Lock()
        self._series: Dict[str, Deque[Point]] = {}
        self._samples = 0

    # -- ingest ------------------------------------------------------------
    def sample(self, snapshot: Mapping[str, object],
               ts: Optional[float] = None) -> None:
        """Fold one registry snapshot (``MetricsRegistry.snapshot()`` or a
        merge of several) into the rings. Non-numeric values are skipped;
        histogram dicts flatten to ``name:count`` / ``name:sum``."""
        ts = time.time() if ts is None else ts
        flat: Dict[str, float] = {}
        for name, v in snapshot.items():
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                flat[name] = float(v)
            elif isinstance(v, Mapping) and "count" in v and "sum" in v:
                flat[f"{name}:count"] = float(v["count"])
                flat[f"{name}:sum"] = float(v["sum"])
        with self._lock:
            for name, v in flat.items():
                ring = self._series.get(name)
                if ring is None:
                    ring = deque(maxlen=self.capacity)
                    self._series[name] = ring
                ring.append((ts, v))
            self._samples += 1
        if self.path:
            line = json.dumps({"ts": ts, "series": flat}, sort_keys=True)
            with open(self.path, "a") as f:
                f.write(line + "\n")

    # -- introspection -----------------------------------------------------
    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._series)

    def __len__(self) -> int:
        return len(self._series)

    @property
    def samples_taken(self) -> int:
        return self._samples

    def latest(self, name: str) -> Optional[float]:
        with self._lock:
            ring = self._series.get(name)
            return ring[-1][1] if ring else None

    def latest_ts(self) -> float:
        """Most recent sample timestamp across every series (0 if empty)."""
        with self._lock:
            return max((r[-1][0] for r in self._series.values() if r),
                       default=0.0)

    # -- windowed queries --------------------------------------------------
    def window(self, name: str, seconds: float,
               now: Optional[float] = None) -> List[Point]:
        """Points of ``name`` within the trailing ``seconds`` window."""
        now = time.time() if now is None else now
        lo = now - seconds
        with self._lock:
            ring = self._series.get(name)
            if not ring:
                return []
            return [p for p in ring if p[0] >= lo]

    def delta(self, name: str, seconds: float,
              now: Optional[float] = None) -> float:
        """Increase of a (monotonic) counter series over the window:
        ``last - first`` of the windowed points (0 with < 2 points)."""
        pts = self.window(name, seconds, now=now)
        if len(pts) < 2:
            return 0.0
        return pts[-1][1] - pts[0][1]

    def rate(self, name: str, seconds: float,
             now: Optional[float] = None) -> float:
        """Per-second increase of a counter series over the window."""
        pts = self.window(name, seconds, now=now)
        if len(pts) < 2:
            return 0.0
        dt = pts[-1][0] - pts[0][0]
        if dt <= 0:
            return 0.0
        return (pts[-1][1] - pts[0][1]) / dt

    def quantile(self, name: str, q: float,
                 seconds: Optional[float] = None,
                 now: Optional[float] = None) -> float:
        """q-th percentile of the point *values* (gauge semantics) over
        the window (the whole ring when ``seconds`` is None)."""
        if seconds is None:
            with self._lock:
                vals = [v for _, v in self._series.get(name, ())]
        else:
            vals = [v for _, v in self.window(name, seconds, now=now)]
        if not vals:
            return 0.0
        vals.sort()
        i = min(len(vals) - 1, max(0, int(q * len(vals))))
        return vals[i]

    def mean(self, name: str, seconds: float,
             now: Optional[float] = None) -> float:
        vals = [v for _, v in self.window(name, seconds, now=now)]
        return sum(vals) / len(vals) if vals else 0.0

    # -- persistence -------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        """Write the full ring contents as sample lines (grouped by
        timestamp, in time order). Returns the number of lines."""
        by_ts: Dict[float, Dict[str, float]] = {}
        with self._lock:
            for name, ring in self._series.items():
                for ts, v in ring:
                    by_ts.setdefault(ts, {})[name] = v
        with open(path, "w") as f:
            for ts in sorted(by_ts):
                f.write(json.dumps({"ts": ts, "series": by_ts[ts]},
                                   sort_keys=True) + "\n")
        return len(by_ts)

    @classmethod
    def load_jsonl(cls, path: str, capacity: int = 512) -> "TimeSeriesDB":
        """Rebuild a database from ``export_jsonl`` / live-append output."""
        db = cls(capacity=capacity)
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                db.sample(d.get("series", {}), ts=d.get("ts"))
        return db
