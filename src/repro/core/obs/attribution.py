"""Critical-path attribution: where did the makespan go?

Walks a finalized ``SpanTree`` plus the run's DAG edges (captured into the
tree at finalize) to produce a ``MakespanReport``: the chain of step spans
that gated completion, with every microsecond of ``WORKFLOW_ADMITTED`` →
``WORKFLOW_DONE`` attributed to exactly one segment kind —

* step-internal time on the critical path: ``compute``, ``retry``
  (failed-attempt time, with its ``STEP_RETRY``/``WORKER_LOST`` cause),
  ``cache-fetch`` (terminal ``STEP_CACHED``), ``skipped``;
* gaps between critical-path spans: ``readmission-backoff`` where they
  overlap a ``WORKFLOW_REQUEUED`` backoff window, ``queue-wait``
  otherwise (admission pump, in-flight-steps semaphore, scheduling);
* the tail after the last step terminal (persist + bookkeeping):
  ``overhead``.

The pieces partition the makespan **by construction** — their sum equals
``end - start`` exactly — so ``reconciles(measured_wall_s)`` is a real
cross-check against an externally measured wall clock, not an identity.

The chain itself is chosen backwards: start from the span with the
latest terminal, repeatedly hop to the predecessor whose terminal was
latest (the dependency that actually gated readiness), stopping when a
span has no predecessor span in the tree (entry step, or a frontier
satisfied before this run).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.obs.spans import SpanTree, StepSpan

__all__ = ["MakespanReport", "build_report", "critical_path"]

#: partitioning segment kinds in render order
_KIND_ORDER = ("compute", "queue-wait", "cache-fetch", "retry",
               "readmission-backoff", "stream-stall", "skipped", "overhead")


def critical_path(tree: SpanTree) -> List[StepSpan]:
    """Chronological chain of step spans that gated the makespan."""
    latest = tree.latest_spans()
    if not latest:
        return []
    preds: Dict[str, List[str]] = {}
    for src, dst in tree.edges:
        preds.setdefault(dst, []).append(src)
    cur = max(latest.values(), key=lambda sp: sp.end)
    chain = [cur]
    seen = {cur.step}
    while True:
        best: Optional[StepSpan] = None
        for p in preds.get(cur.step, ()):
            sp = latest.get(p)
            if sp is None or sp.step in seen:
                continue
            if sp.end <= cur.start + 1e-9 and \
                    (best is None or sp.end > best.end):
                best = sp
        if best is None:
            break
        chain.append(best)
        seen.add(best.step)
        cur = best
    chain.reverse()
    return chain


@dataclass
class MakespanReport:
    """Attributed makespan breakdown for one finished run."""

    workflow: str
    run_id: str
    status: str
    makespan_s: float
    critical_path: List[str] = field(default_factory=list)
    # ordered timeline pieces: {"kind", "step" (or ""), "start", "end",
    # "dur", "cause"} — partition of [tree.start, tree.end]
    segments: List[Dict] = field(default_factory=list)
    totals: Dict[str, float] = field(default_factory=dict)
    # informational (synthetic, overlaps compute): producer backpressure
    stream_stall_s: float = 0.0

    @property
    def attributed_s(self) -> float:
        return sum(self.totals.values())

    def pct(self, kind: str) -> float:
        if self.makespan_s <= 0:
            return 0.0
        return 100.0 * self.totals.get(kind, 0.0) / self.makespan_s

    def reconciles(self, measured_wall_s: float, tol: float = 0.05) -> bool:
        """Does the attributed total agree with an externally measured
        wall clock within ``tol`` (relative)?"""
        if measured_wall_s <= 0:
            return self.attributed_s <= tol
        return abs(self.attributed_s - measured_wall_s) \
            <= tol * measured_wall_s

    def to_dict(self) -> Dict:
        return {"workflow": self.workflow, "run_id": self.run_id,
                "status": self.status, "makespan_s": self.makespan_s,
                "critical_path": self.critical_path,
                "segments": self.segments, "totals": self.totals,
                "stream_stall_s": self.stream_stall_s}

    def render(self) -> str:
        """Human-readable breakdown, biggest buckets first."""
        lines = [f"run {self.run_id} workflow {self.workflow}: "
                 f"{self.status}, makespan {self.makespan_s:.3f}s"]
        by_step: Dict[str, Dict[str, float]] = {}
        for seg in self.segments:
            if seg["step"]:
                by_step.setdefault(seg["kind"], {})
                by_step[seg["kind"]][seg["step"]] = \
                    by_step[seg["kind"]].get(seg["step"], 0.0) + seg["dur"]
        kinds = sorted((k for k, v in self.totals.items() if v > 0),
                       key=lambda k: -self.totals[k])
        for kind in kinds:
            tot = self.totals[kind]
            detail = ""
            steps = by_step.get(kind)
            if steps:
                top = sorted(steps.items(), key=lambda kv: -kv[1])[:3]
                detail = "  (" + ", ".join(
                    f"{s} {d:.3f}s" for s, d in top) + ")"
            lines.append(f"  {self.pct(kind):5.1f}% {kind:<20s}"
                         f"{tot:9.3f}s{detail}")
        if self.stream_stall_s > 0:
            lines.append(f"  [stream-stall {self.stream_stall_s:.3f}s "
                         "of backpressure inside compute]")
        if self.critical_path:
            lines.append("critical path: "
                         + " -> ".join(self.critical_path))
        return "\n".join(lines)


def _classify_gap(start: float, end: float,
                  backoffs: List) -> List[Dict]:
    """Split an inter-span gap into readmission-backoff pieces (where it
    overlaps a WORKFLOW_REQUEUED window) and queue-wait for the rest."""
    pieces: List[Dict] = []
    cur = start
    for b in sorted(backoffs, key=lambda s: s.start):
        lo, hi = max(b.start, cur), min(b.end, end)
        if hi <= lo:
            continue
        if lo > cur:
            pieces.append({"kind": "queue-wait", "step": "", "start": cur,
                           "end": lo, "dur": lo - cur, "cause": ""})
        pieces.append({"kind": "readmission-backoff", "step": "",
                       "start": lo, "end": hi, "dur": hi - lo,
                       "cause": b.cause})
        cur = hi
    if end > cur:
        pieces.append({"kind": "queue-wait", "step": "", "start": cur,
                       "end": end, "dur": end - cur, "cause": ""})
    return pieces


def build_report(tree: SpanTree) -> MakespanReport:
    chain = critical_path(tree)
    backoffs = [s for s in tree.segments
                if s.kind == "readmission-backoff"]
    segments: List[Dict] = []
    cursor = tree.start
    for sp in chain:
        if sp.start > cursor + 1e-12:
            segments.extend(_classify_gap(cursor, sp.start, backoffs))
            cursor = sp.start
        for seg in sp.segments:
            if seg.synthetic or seg.kind == "queue-wait":
                continue
            lo = max(seg.start, cursor)
            if seg.end > lo:
                segments.append({"kind": seg.kind, "step": sp.step,
                                 "start": lo, "end": seg.end,
                                 "dur": seg.end - lo, "cause": seg.cause})
                cursor = seg.end
        cursor = max(cursor, sp.end)
    if tree.end > cursor + 1e-12:
        # post-chain tail: persist / requeue rounds that out-lasted the
        # last critical step, bookkeeping before WORKFLOW_DONE
        segments.extend(_classify_gap(cursor, tree.end, backoffs))
        if segments and segments[-1]["kind"] == "queue-wait":
            segments[-1]["kind"] = "overhead"
    totals: Dict[str, float] = {}
    for seg in segments:
        totals[seg["kind"]] = totals.get(seg["kind"], 0.0) + seg["dur"]
    return MakespanReport(
        workflow=tree.workflow, run_id=tree.run_id, status=tree.status,
        makespan_s=tree.makespan_s,
        critical_path=[sp.step for sp in chain],
        segments=segments, totals=totals,
        stream_stall_s=tree.seg_total("stream-stall"))
