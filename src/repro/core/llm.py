"""LLM interface + deterministic surrogates (DESIGN.md §2.4).

No network access in this container, so ChatGPT-3.5/4 are replaced by a
pluggable ``LLM`` interface with two seeded surrogates:

* ``TemplateLLM`` — code generation by retrieval + template filling over the
  Code Lake, with a temperature-controlled error model (drops lines, picks
  the 2nd-best template, corrupts an argument). The error rates differ per
  simulated model tier ("gpt-3.5" noisier than "gpt-4"). pass@k numbers
  measured against the executable grader are therefore *real measurements of
  this error model*, not transcribed paper numbers.

* ``SurrogateLLM`` — hyperparameter -> predicted-training-log oracle
  (paper Alg. 4 "Predicted Training Log") built from scaling-law heuristics:
  loss(step) = L_inf + A * step^-0.3, penalized by distance of lr from a
  size-derived optimum and by batch/warmup mismatches.
"""
from __future__ import annotations

import hashlib
import math
import random
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


def _seed_from(*parts) -> int:
    h = hashlib.sha256("|".join(str(p) for p in parts).encode()).hexdigest()
    return int(h[:12], 16)


class LLM:
    name = "llm"

    def complete(self, prompt: str, temperature: float = 0.2,
                 seed: int = 0) -> str:
        raise NotImplementedError

    def score(self, prompt: str, code: str) -> float:
        raise NotImplementedError

    def count_tokens(self, text: str) -> int:
        return max(1, len(text) // 4)


# ---------------------------------------------------------------------------
# retrieval + template generation with an explicit error model
# ---------------------------------------------------------------------------

def _bow(text: str) -> Dict[str, float]:
    words = re.findall(r"[a-zA-Z_]+", text.lower())
    d: Dict[str, float] = {}
    for w in words:
        d[w] = d.get(w, 0.0) + 1.0
    return d


def cosine(a: Dict[str, float], b: Dict[str, float]) -> float:
    num = sum(v * b.get(k, 0.0) for k, v in a.items())
    na = math.sqrt(sum(v * v for v in a.values()))
    nb = math.sqrt(sum(v * v for v in b.values()))
    return num / (na * nb) if na and nb else 0.0


@dataclass
class ModelTier:
    name: str
    miss_rate: float          # chance of picking a worse template
    corrupt_rate: float       # chance of corrupting a filled argument
    drop_rate: float          # chance of dropping a code line
    cost_per_1k_tokens: float


TIERS = {
    "gpt-3.5": ModelTier("gpt-3.5", miss_rate=0.38, corrupt_rate=0.22,
                         drop_rate=0.12, cost_per_1k_tokens=0.0015),
    "gpt-4": ModelTier("gpt-4", miss_rate=0.25, corrupt_rate=0.14,
                       drop_rate=0.07, cost_per_1k_tokens=0.036),
}


class TemplateLLM(LLM):
    """Generation = nearest-template retrieval + slot filling + noise."""

    def __init__(self, tier: str = "gpt-4",
                 codelake: Optional[Sequence[Tuple[str, str]]] = None,
                 use_references: bool = True):
        self.tier = TIERS[tier]
        self.name = tier
        from repro.core.codelake import SNIPPETS
        self.lake = list(codelake) if codelake is not None else list(SNIPPETS)
        self.use_references = use_references
        self.tokens_used = 0

    def _retrieve(self, query: str, k: int = 3) -> List[Tuple[float, str, str]]:
        q = _bow(query.split("|||")[0])   # retrieval ignores fill-context
        scored = sorted(((cosine(q, _bow(desc + " " + code)), desc, code)
                         for desc, code in self.lake), reverse=True)
        return scored[:k]

    def complete(self, prompt: str, temperature: float = 0.2,
                 seed: int = 0) -> str:
        rng = random.Random(_seed_from(prompt, temperature, seed, self.name))
        self.tokens_used += self.count_tokens(prompt)
        cands = self._retrieve(prompt, k=3)
        if not cands:
            return "# no reference found\n"
        # error model: temperature and tier drive template misses
        idx = 0
        p_miss = self.tier.miss_rate * (0.5 + temperature)
        if not self.use_references:
            p_miss = min(0.95, p_miss * 2.2)   # no Code Lake -> blind guess
        if len(cands) > 1 and rng.random() < p_miss:
            idx = rng.randint(1, len(cands) - 1)
        code = cands[idx][2]
        code = self._fill(code, prompt, rng)
        lines = code.splitlines()
        out_lines = []
        for ln in lines:
            if (ln.strip() and not ln.strip().startswith("#")
                    and rng.random() < self.tier.drop_rate * (0.4 + temperature)):
                continue                        # dropped line
            out_lines.append(ln)
        out = "\n".join(out_lines) + "\n"
        self.tokens_used += self.count_tokens(out)
        return out

    def _fill(self, code: str, prompt: str, rng: random.Random) -> str:
        """Fill {slot} placeholders from entities found in the prompt."""
        from repro.core.nl2wf import extract_entities
        ents = extract_entities(prompt)
        def sub(m):
            slot = m.group(1)
            val = ents.get(slot)
            if val is None:
                val = {"models": "['model-a']", "dataset": "'data'",
                       "count": "2", "metric": "'accuracy'",
                       "name": "'step'"}.get(slot, "'x'")
            if rng.random() < self.tier.corrupt_rate * 0.5:
                val = "'???'"                   # corrupted argument
            return str(val)
        return re.sub(r"\{(\w+)\}", sub, code)

    def score(self, prompt: str, code: str) -> float:
        """Self-calibration scorer (paper step 3): template compliance +
        syntactic validity. Compliance compares the step-zoo calls in the
        generated code against the best-matching reference template —
        sharper than raw token cosine (templates share most surface tokens)."""
        self.tokens_used += self.count_tokens(prompt + code)
        try:
            compile(code, "<gen>", "exec")
            syn = 1.0
        except SyntaxError:
            syn = 0.0
        best = self._retrieve(prompt, k=1)
        if best:
            want = set(re.findall(r"steps\.(\w+)|couler\.(\w+)", best[0][2]))
            got = set(re.findall(r"steps\.(\w+)|couler\.(\w+)", code))
            union = want | got
            sim = len(want & got) / len(union) if union else 0.0
        else:
            sim = 0.0
        bad = 1.0 if "'???'" in code else 0.0
        return max(0.0, 0.4 * syn + 0.6 * sim - 0.4 * bad)

    def cost_usd(self) -> float:
        return self.tokens_used / 1000.0 * self.tier.cost_per_1k_tokens


# ---------------------------------------------------------------------------
# hyperparameter -> predicted training log (Alg. 4)
# ---------------------------------------------------------------------------

class SurrogateLLM(LLM):
    """Predicts a training log for (DataCard, ModelCard, hyperparams)."""

    name = "surrogate"

    def predict_training_log(self, data_card: Dict, model_card: Dict,
                             hparams: Dict, steps: int = 200) -> Dict:
        n_params = float(model_card.get("n_params", 1e8))
        n_data = float(data_card.get("n_examples", 1e5))
        lr = float(hparams.get("learning_rate", 3e-4))
        bs = float(hparams.get("batch_size", 32))
        wd = float(hparams.get("weight_decay", 0.1))

        lr_opt = 0.003 * (n_params / 1e8) ** -0.25
        bs_opt = 32.0 * (n_data / 1e5) ** 0.5
        lr_pen = math.exp(0.45 * (math.log(lr / lr_opt)) ** 2) - 1.0
        bs_pen = 0.08 * abs(math.log(bs / bs_opt))
        wd_pen = 0.05 * abs(math.log(max(wd, 1e-4) / 0.1))
        l_inf = 1.8 + 0.25 * math.log10(1e9 / n_params)

        log_lines, losses = [], []
        for s in range(1, steps + 1):
            base = l_inf + 4.0 * s ** -0.3
            loss = base * (1.0 + 0.15 * lr_pen + bs_pen + wd_pen)
            if lr > 8 * lr_opt:                     # divergence regime
                loss = base * (1.0 + 0.05 * s * lr / lr_opt * 0.01)
            losses.append(loss)
            if s % max(1, steps // 10) == 0:
                log_lines.append(f"step {s} loss {loss:.4f} lr {lr:.2e}")
        acc = max(0.0, min(0.97, 1.25 - 0.18 * losses[-1]))
        return {"hparams": dict(hparams), "final_loss": losses[-1],
                "final_accuracy": acc, "losses": losses,
                "log": "\n".join(log_lines)}

    def complete(self, prompt: str, temperature: float = 0.2, seed: int = 0):
        return "surrogate-llm"

    def score(self, prompt: str, code: str) -> float:
        return 1.0
