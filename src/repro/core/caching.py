"""Automatic artifact caching (paper §IV.A, Eq. 3-6, Algorithm 2) — facade.

The implementation lives in the ``repro.core.cache`` package:

  * Eq. 3-6 scoring (``cache/scoring.py``) — note ``reuse_value``'s
    documented Eq. 4 choice: the default weights by |zeta_ui| so direct
    successors count most; ``literal_eq4=True`` gives the equation exactly
    as printed (which zeroes direct successors). See that module's
    docstring; both behaviors are pinned by tests.
  * Policies NONE/ALL/FIFO/LRU/COULER (``cache/policies.py``) with the
    memoized Eq. 3/4 hot path described there.
  * ``TieredCacheStore`` — MEM/SSD/REMOTE tiers, demotion cascade, Eq. 6
    background promotion, cross-cluster ``SharedRemoteTier``
    (``cache/tiers.py`` + ``cache/store.py``).

``CacheStore`` here is the legacy single-tier API, now a facade over the
tiered machinery: one MEM-like tier, so Algorithm 2 behaves exactly as the
pre-tier implementation (engines call ``store.offer(...)`` when a job
finishes and ``store.get(...)`` before running one; eviction re-scores
remaining items through lazily invalidated heaps + policy memos).

This module re-exports every public name so existing imports keep working.
"""
from repro.core.cache import (  # noqa: F401
    POLICIES, CacheAll, CachePolicy, CacheStore, CacheTier, CachedArtifact,
    CoulerPolicy, FIFOPolicy, LRUPolicy, NoCache, SharedRemoteTier,
    TierSpec, TieredCacheStore, default_tiers, importance, mem_spec,
    predecessor_subgraph, reconstruction_cost, remote_spec, reuse_value,
    sizeof, ssd_spec, successor_subgraph,
)

__all__ = [
    "POLICIES", "CacheAll", "CachePolicy", "CacheStore", "CacheTier",
    "CachedArtifact", "CoulerPolicy", "FIFOPolicy", "LRUPolicy", "NoCache",
    "SharedRemoteTier", "TierSpec", "TieredCacheStore", "default_tiers",
    "importance", "mem_spec", "predecessor_subgraph", "reconstruction_cost",
    "remote_spec", "reuse_value", "sizeof", "ssd_spec",
    "successor_subgraph",
]
