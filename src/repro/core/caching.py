"""Automatic artifact caching (paper §IV.A, Eq. 3-6, Algorithm 2).

The *caching importance factor* of artifact u:

    I(u) = alpha * log(1 + L(u)) + beta * F(u)^2 - e^(-V(u))        (Eq. 6)

  L(u)  reconstruction cost over the n-layer predecessor subgraph G_p,
        truncated at already-cached artifacts:
            L(u) = sum_ij A_ij * (w_i + d_i * d_j)                  (Eq. 3)
  F(u)  reuse value over the successor subgraph G_s:
            F(u) = sum_i r / kappa_ui * (zeta_ui + 1)               (Eq. 4)
        with zeta = diag(d) - A (graph Laplacian)                   (Eq. 5)
  V(u)  cache (memory) cost of u, normalized to the store capacity.

Baselines implemented for the paper's RQ2 comparison: NONE, ALL, FIFO, LRU.

Capacity-bounded ``CacheStore`` + the Algorithm-2 exchange loop live here;
engines call ``store.offer(...)`` when a job finishes and ``store.get(...)``
before running one. Eviction re-scores remaining items after every removal
(paper: "recompute the caching importance factor of all remaining items").
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.ir import WorkflowIR


def sizeof(value: Any) -> int:
    try:
        import numpy as _np
        if isinstance(value, _np.ndarray):
            return int(value.nbytes)
    except Exception:
        pass
    if hasattr(value, "nbytes"):
        try:
            return int(value.nbytes)
        except Exception:
            pass
    if isinstance(value, (bytes, bytearray, str)):
        return len(value)
    if isinstance(value, (list, tuple)):
        return 64 + sum(sizeof(v) for v in value)
    if isinstance(value, dict):
        return 64 + sum(sizeof(k) + sizeof(v) for k, v in value.items())
    return 64


@dataclass
class CachedArtifact:
    name: str
    value: Any
    bytes: int
    compute_time_s: float
    producer: str                      # job name
    created: float = field(default_factory=time.time)
    last_used: float = field(default_factory=time.time)
    uses: int = 0
    insertion: int = 0                 # FIFO order


# ---------------------------------------------------------------------------
# Eq. 3-6
# ---------------------------------------------------------------------------

def predecessor_subgraph(wf: WorkflowIR, job: str, n_layers: int,
                         cached_producers: set) -> List[str]:
    """G_p: preceding n layers from u's producer; truncated at cached jobs
    (paper §IV.A.2 properties (a),(b))."""
    frontier = [job]
    seen = {job}
    for _ in range(n_layers):
        nxt = []
        for j in frontier:
            for p in wf.predecessors(j):
                if p in seen:
                    continue
                seen.add(p)
                if p in cached_producers:
                    continue            # truncate at cached artifact
                nxt.append(p)
        frontier = nxt
        if not frontier:
            break
    return list(seen)


def successor_subgraph(wf: WorkflowIR, job: str, n_layers: int) -> Dict[str, int]:
    """G_s with hop distance kappa from u's producer."""
    dist = {job: 0}
    frontier = [job]
    for k in range(1, n_layers + 1):
        nxt = []
        for j in frontier:
            for s in wf.successors(j):
                if s not in dist:
                    dist[s] = k
                    nxt.append(s)
        frontier = nxt
        if not frontier:
            break
    return dist


def reconstruction_cost(wf: WorkflowIR, job: str, cached_producers: set,
                        n_layers: int = 3) -> float:
    """Eq. 3: L(u) = sum_ij A_ij (w_i + d_i d_j) over G_p."""
    nodes = predecessor_subgraph(wf, job, n_layers, cached_producers)
    A = wf.adjacency(nodes)
    d = A.sum(0) + A.sum(1)
    w = np.array([wf.jobs[n].est_time_s * max(1.0, wf.jobs[n].resources.cpu)
                  for n in nodes])
    # A_ij * (w_i + d_i*d_j), vectorized
    cost = float((A * (w[:, None] + np.outer(d, d))).sum())
    return cost


def reuse_value(wf: WorkflowIR, job: str, n_layers: int = 3) -> float:
    """Eq. 4/5: F(u) = sum_i r/kappa_ui * (zeta_ui + 1), zeta = diag(d) - A."""
    dist = successor_subgraph(wf, job, n_layers)
    nodes = list(dist)
    if len(nodes) <= 1:
        return 0.0
    A = wf.adjacency(nodes)
    d = A.sum(0) + A.sum(1)
    zeta = np.diag(d) - A
    # NOTE: taken literally, zeta_ui = -A_ui makes every DIRECT successor
    # contribute (zeta+1) = 0, which contradicts Eq. 4's stated intent (F
    # measures the value of reuse by successors). We keep the Laplacian
    # structure but weight by |zeta_ui| so direct dependents count most.
    u = nodes.index(job)
    total = 0.0
    for i, n in enumerate(nodes):
        if n == job:
            continue
        kappa = dist[n]
        r = 1.0                           # reuse event indicator
        total += (r / max(kappa, 1)) * (abs(zeta[u, i]) + 1.0)
    return float(total)


def importance(l: float, f: float, v: float, alpha: float = 1.5,
               beta: float = 1.0) -> float:
    """Eq. 6 (alpha=1.5, beta=1 per paper §VI.C)."""
    return alpha * math.log1p(max(l, 0.0)) + beta * f * f - math.exp(-v)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

class CachePolicy:
    name = "base"

    def admit(self, art: CachedArtifact) -> bool:
        return True

    def score(self, art: CachedArtifact, store: "CacheStore") -> float:
        raise NotImplementedError


class NoCache(CachePolicy):
    name = "none"

    def admit(self, art):
        return False

    def score(self, art, store):
        return 0.0


class CacheAll(CachePolicy):
    """Admit everything; evict nothing until forced, then oldest-first."""
    name = "all"

    def score(self, art, store):
        return -art.insertion        # forced eviction: oldest first


class FIFOPolicy(CachePolicy):
    name = "fifo"

    def score(self, art, store):
        return art.insertion          # lowest = first in = evicted first


class LRUPolicy(CachePolicy):
    name = "lru"

    def score(self, art, store):
        return art.last_used


class CoulerPolicy(CachePolicy):
    """Paper Algorithm 2: score = caching importance factor I(u)."""
    name = "couler"

    def __init__(self, alpha: float = 1.5, beta: float = 1.0,
                 n_layers: int = 3):
        self.alpha, self.beta, self.n_layers = alpha, beta, n_layers

    def score(self, art: CachedArtifact, store: "CacheStore") -> float:
        wf = store.workflow
        if wf is None or art.producer not in wf.jobs:
            return art.last_used
        cached = {store.items[k].producer for k in store.items
                  if k != art.name}
        l = reconstruction_cost(wf, art.producer, cached, self.n_layers)
        f = reuse_value(wf, art.producer, self.n_layers)
        v = art.bytes / max(store.capacity_bytes, 1)
        return importance(l, f, v, self.alpha, self.beta)


POLICIES = {"none": NoCache, "all": CacheAll, "fifo": FIFOPolicy,
            "lru": LRUPolicy, "couler": CoulerPolicy}


# ---------------------------------------------------------------------------
# store + Algorithm 2
# ---------------------------------------------------------------------------

class CacheStore:
    """Capacity-bounded artifact store (models the Alluxio tier, §IV.A.1)."""

    def __init__(self, capacity_bytes: int = 1 << 30,
                 policy: Optional[CachePolicy] = None):
        import threading
        self.capacity_bytes = capacity_bytes
        self.policy = policy or CoulerPolicy()
        self.items: Dict[str, CachedArtifact] = {}
        self.used_bytes = 0
        self.workflow: Optional[WorkflowIR] = None
        self._insertions = 0
        self._lock = threading.RLock()      # engines offer() from workers
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "admitted": 0, "rejected": 0}

    def attach_workflow(self, wf: WorkflowIR) -> None:
        self.workflow = wf

    def get(self, name: str) -> Optional[CachedArtifact]:
        with self._lock:
            art = self.items.get(name)
            if art is None:
                self.stats["misses"] += 1
                return None
            art.last_used = time.time()
            art.uses += 1
            self.stats["hits"] += 1
            return art

    def contains(self, name: str) -> bool:
        return name in self.items

    def offer(self, name: str, value: Any, compute_time_s: float,
              producer: str, nbytes: Optional[int] = None) -> bool:
        """Algorithm 2: try to admit a newly produced artifact, evicting
        lower-importance items while capacity is exceeded."""
        b = nbytes if nbytes is not None else sizeof(value)
        with self._lock:
            art = CachedArtifact(name=name, value=value, bytes=b,
                                 compute_time_s=compute_time_s,
                                 producer=producer, insertion=self._insertions)
            self._insertions += 1

            if not self.policy.admit(art):
                self.stats["rejected"] += 1
                return False
            if b > self.capacity_bytes:
                self.stats["rejected"] += 1
                return False

            # lines 10-11: fits -> cache it
            if self.used_bytes + b <= self.capacity_bytes:
                self._insert(art)
                return True

            # lines 16-31 (NodeSelection): compare vs lowest-scored items
            new_score = self.policy.score(art, self)
            while self.used_bytes + b > self.capacity_bytes:
                if not self.items:
                    break
                scores = {k: self.policy.score(a, self)
                          for k, a in self.items.items()}
                k_min = min(scores, key=scores.get)
                if scores[k_min] >= new_score:
                    self.stats["rejected"] += 1
                    return False               # new artifact loses
                self._evict(k_min)
                # paper: re-evaluate remaining items after every removal
            self._insert(art)
            return True

    def _insert(self, art: CachedArtifact) -> None:
        if art.name in self.items:
            self._evict(art.name)
        self.items[art.name] = art
        self.used_bytes += art.bytes
        self.stats["admitted"] += 1

    def _evict(self, name: str) -> None:
        art = self.items.pop(name)
        self.used_bytes -= art.bytes
        self.stats["evictions"] += 1

    def hit_ratio(self) -> float:
        tot = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / tot if tot else 0.0
