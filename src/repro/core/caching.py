"""Automatic artifact caching (paper §IV.A, Eq. 3-6, Algorithm 2).

The *caching importance factor* of artifact u:

    I(u) = alpha * log(1 + L(u)) + beta * F(u)^2 - e^(-V(u))        (Eq. 6)

  L(u)  reconstruction cost over the n-layer predecessor subgraph G_p,
        truncated at already-cached artifacts:
            L(u) = sum_ij A_ij * (w_i + d_i * d_j)                  (Eq. 3)
  F(u)  reuse value over the successor subgraph G_s:
            F(u) = sum_i r / kappa_ui * (zeta_ui + 1)               (Eq. 4)
        with zeta = diag(d) - A (graph Laplacian)                   (Eq. 5)
  V(u)  cache (memory) cost of u, normalized to the store capacity.

Baselines implemented for the paper's RQ2 comparison: NONE, ALL, FIFO, LRU.

Capacity-bounded ``CacheStore`` + the Algorithm-2 exchange loop live here;
engines call ``store.offer(...)`` when a job finishes and ``store.get(...)``
before running one. Eviction re-scores remaining items after every removal
(paper: "recompute the caching importance factor of all remaining items").

Hot-path notes
--------------
``CoulerPolicy`` memoizes Eq. 3/4 per (workflow identity + structure
version [+ weights version for Eq. 3], producer, relevant cached frontier):
the cached frontier only matters through its intersection with the
producer's untruncated n-layer predecessor reach, so evictions elsewhere in
the DAG hit the memo. Engines that refine ``est_time_s`` must call
``WorkflowIR.note_weights_changed()`` so Eq. 3 memos are dropped (silent
attribute mutation would otherwise serve stale reconstruction costs).
``CacheStore`` keeps a lazily invalidated eviction min-heap: mutations only
bump an epoch counter, and the heap is re-validated (through the policy
memos, so unchanged items cost O(1)) the next time an eviction candidate is
needed — replacing the former full Eq.3/4 re-derivation of every stored
item on every eviction iteration.
"""
from __future__ import annotations

import heapq
import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ir import WorkflowIR


def sizeof(value: Any) -> int:
    try:
        import numpy as _np
        if isinstance(value, _np.ndarray):
            return int(value.nbytes)
    except Exception:
        pass
    if hasattr(value, "nbytes"):
        try:
            return int(value.nbytes)
        except Exception:
            pass
    if isinstance(value, (bytes, bytearray, str)):
        return len(value)
    if isinstance(value, (list, tuple)):
        return 64 + sum(sizeof(v) for v in value)
    if isinstance(value, dict):
        return 64 + sum(sizeof(k) + sizeof(v) for k, v in value.items())
    return 64


@dataclass
class CachedArtifact:
    name: str
    value: Any
    bytes: int
    compute_time_s: float
    producer: str                      # job name
    created: float = field(default_factory=time.time)
    last_used: float = field(default_factory=time.time)
    uses: int = 0
    insertion: int = 0                 # FIFO order


# ---------------------------------------------------------------------------
# Eq. 3-6
# ---------------------------------------------------------------------------

def predecessor_subgraph(wf: WorkflowIR, job: str, n_layers: int,
                         cached_producers: set) -> List[str]:
    """G_p: preceding n layers from u's producer; truncated at cached jobs
    (paper §IV.A.2 properties (a),(b))."""
    frontier = [job]
    seen = {job}
    for _ in range(n_layers):
        nxt = []
        for j in frontier:
            for p in wf.predecessors(j):
                if p in seen:
                    continue
                seen.add(p)
                if p in cached_producers:
                    continue            # truncate at cached artifact
                nxt.append(p)
        frontier = nxt
        if not frontier:
            break
    return list(seen)


def successor_subgraph(wf: WorkflowIR, job: str, n_layers: int) -> Dict[str, int]:
    """G_s with hop distance kappa from u's producer."""
    dist = {job: 0}
    frontier = [job]
    for k in range(1, n_layers + 1):
        nxt = []
        for j in frontier:
            for s in wf.successors(j):
                if s not in dist:
                    dist[s] = k
                    nxt.append(s)
        frontier = nxt
        if not frontier:
            break
    return dist


def reconstruction_cost(wf: WorkflowIR, job: str, cached_producers: set,
                        n_layers: int = 3) -> float:
    """Eq. 3: L(u) = sum_ij A_ij (w_i + d_i d_j) over G_p."""
    nodes = predecessor_subgraph(wf, job, n_layers, cached_producers)
    A = wf.adjacency(nodes)
    d = A.sum(0) + A.sum(1)
    w = np.array([wf.jobs[n].est_time_s * max(1.0, wf.jobs[n].resources.cpu)
                  for n in nodes])
    # A_ij * (w_i + d_i*d_j), vectorized
    cost = float((A * (w[:, None] + np.outer(d, d))).sum())
    return cost


def reuse_value(wf: WorkflowIR, job: str, n_layers: int = 3) -> float:
    """Eq. 4/5: F(u) = sum_i r/kappa_ui * (zeta_ui + 1), zeta = diag(d) - A."""
    dist = successor_subgraph(wf, job, n_layers)
    nodes = list(dist)
    if len(nodes) <= 1:
        return 0.0
    A = wf.adjacency(nodes)
    d = A.sum(0) + A.sum(1)
    zeta = np.diag(d) - A
    # NOTE: taken literally, zeta_ui = -A_ui makes every DIRECT successor
    # contribute (zeta+1) = 0, which contradicts Eq. 4's stated intent (F
    # measures the value of reuse by successors). We keep the Laplacian
    # structure but weight by |zeta_ui| so direct dependents count most.
    u = nodes.index(job)
    total = 0.0
    for i, n in enumerate(nodes):
        if n == job:
            continue
        kappa = dist[n]
        r = 1.0                           # reuse event indicator
        total += (r / max(kappa, 1)) * (abs(zeta[u, i]) + 1.0)
    return float(total)


def importance(l: float, f: float, v: float, alpha: float = 1.5,
               beta: float = 1.0) -> float:
    """Eq. 6 (alpha=1.5, beta=1 per paper §VI.C)."""
    return alpha * math.log1p(max(l, 0.0)) + beta * f * f - math.exp(-v)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

class CachePolicy:
    name = "base"

    def admit(self, art: CachedArtifact) -> bool:
        return True

    def score(self, art: CachedArtifact, store: "CacheStore") -> float:
        raise NotImplementedError

    def score_many(self, arts: Sequence[CachedArtifact],
                   store: "CacheStore") -> List[float]:
        """Batch scoring hook; policies with shared per-batch state
        (CoulerPolicy's frontier) override this."""
        return [self.score(a, store) for a in arts]

    def invalidate(self, wf: Optional[WorkflowIR]) -> None:
        """Called when the store's attached workflow changes."""


class NoCache(CachePolicy):
    name = "none"

    def admit(self, art):
        return False

    def score(self, art, store):
        return 0.0


class CacheAll(CachePolicy):
    """Admit everything; evict nothing until forced, then oldest-first."""
    name = "all"

    def score(self, art, store):
        return -art.insertion        # forced eviction: oldest first


class FIFOPolicy(CachePolicy):
    name = "fifo"

    def score(self, art, store):
        return art.insertion          # lowest = first in = evicted first


class LRUPolicy(CachePolicy):
    name = "lru"

    def score(self, art, store):
        return art.last_used


class CoulerPolicy(CachePolicy):
    """Paper Algorithm 2: score = caching importance factor I(u).

    Eq. 3/4 are memoized per producer: F(u) depends only on workflow
    structure, and L(u) additionally on est_time_s weights plus the part of
    the cached frontier that falls inside u's untruncated n-layer
    predecessor reach — so re-scoring after an unrelated eviction is a dict
    lookup instead of a BFS + adjacency-matrix rebuild."""
    name = "couler"

    def __init__(self, alpha: float = 1.5, beta: float = 1.0,
                 n_layers: int = 3):
        self.alpha, self.beta, self.n_layers = alpha, beta, n_layers
        self._wf: Optional[WorkflowIR] = None       # strong ref (id safety)
        self._struct_v = -1
        self._weights_v = -1
        self._pred_reach: Dict[str, FrozenSet[str]] = {}
        self._reuse: Dict[str, float] = {}
        self._recon: Dict[Tuple[str, FrozenSet[str]], float] = {}

    def invalidate(self, wf: Optional[WorkflowIR]) -> None:
        self._wf = None
        self._struct_v = -1

    def _sync(self, wf: WorkflowIR) -> None:
        if wf is not self._wf or wf.structure_version != self._struct_v:
            self._wf = wf
            self._struct_v = wf.structure_version
            self._weights_v = wf.weights_version
            self._pred_reach.clear()
            self._reuse.clear()
            self._recon.clear()
        elif wf.weights_version != self._weights_v:
            self._weights_v = wf.weights_version
            self._recon.clear()                      # Eq. 3 reads w_i

    def _reach(self, wf: WorkflowIR, producer: str) -> FrozenSet[str]:
        """Untruncated n-layer predecessor reach of `producer` — the only
        nodes whose cached-status can alter Eq. 3's truncated BFS."""
        s = self._pred_reach.get(producer)
        if s is None:
            frontier = [producer]
            seen = {producer}
            for _ in range(self.n_layers):
                nxt = []
                for j in frontier:
                    for p in wf.predecessors(j):
                        if p not in seen:
                            seen.add(p)
                            nxt.append(p)
                frontier = nxt
                if not frontier:
                    break
            s = frozenset(seen)
            self._pred_reach[producer] = s
        return s

    # frontier-sig entries accumulate as the cached set churns even when
    # the workflow never changes; past this bound a wholesale reset is
    # cheaper than unbounded growth (misses just recompute)
    _RECON_MEMO_CAP = 4096

    def _importance(self, wf: WorkflowIR, art: CachedArtifact,
                    frontier_sig: FrozenSet[str],
                    capacity_bytes: int) -> float:
        key = (art.producer, frontier_sig)
        l = self._recon.get(key)
        if l is None:
            if len(self._recon) >= self._RECON_MEMO_CAP:
                self._recon.clear()
            l = reconstruction_cost(wf, art.producer, frontier_sig,
                                    self.n_layers)
            self._recon[key] = l
        f = self._reuse.get(art.producer)
        if f is None:
            f = reuse_value(wf, art.producer, self.n_layers)
            self._reuse[art.producer] = f
        v = art.bytes / max(capacity_bytes, 1)
        return importance(l, f, v, self.alpha, self.beta)

    def score(self, art: CachedArtifact, store: "CacheStore") -> float:
        return self.score_many([art], store)[0]

    def score_many(self, arts: Sequence[CachedArtifact],
                   store: "CacheStore") -> List[float]:
        wf = store.workflow
        if wf is None:
            return [a.last_used for a in arts]
        self._sync(wf)
        prod_count: Dict[str, int] = {}
        for a in store.items.values():
            prod_count[a.producer] = prod_count.get(a.producer, 0) + 1
        out = []
        for art in arts:
            if art.producer not in wf.jobs:
                out.append(art.last_used)
                continue
            # cached frontier = producers of stored items minus the item
            # stored under this artifact's own key (Algorithm 2's k != u),
            # restricted to the predecessor reach (the rest cannot matter)
            own = store.items.get(art.name)
            own_producer = own.producer if own is not None else None
            sig = frozenset(
                p for p in self._reach(wf, art.producer)
                if prod_count.get(p, 0) - (1 if p == own_producer else 0) > 0)
            out.append(self._importance(wf, art, sig, store.capacity_bytes))
        return out


POLICIES = {"none": NoCache, "all": CacheAll, "fifo": FIFOPolicy,
            "lru": LRUPolicy, "couler": CoulerPolicy}


# ---------------------------------------------------------------------------
# store + Algorithm 2
# ---------------------------------------------------------------------------

class CacheStore:
    """Capacity-bounded artifact store (models the Alluxio tier, §IV.A.1).

    Eviction candidates come from a lazily invalidated min-heap of
    (score, insertion, name): any state change that may move a score
    (insert/evict/refresh, a cache hit touching ``last_used``, or the
    attached workflow's structure/weights versions advancing) only bumps
    ``_epoch``; the heap is rebuilt — through the policy memos, so
    unchanged items are dict lookups — the next time a candidate is
    actually needed. ``stats['score_time_s']`` accumulates the wall time
    spent inside policy scoring."""

    def __init__(self, capacity_bytes: int = 1 << 30,
                 policy: Optional[CachePolicy] = None):
        import threading
        self.capacity_bytes = capacity_bytes
        self.policy = policy or CoulerPolicy()
        self.items: Dict[str, CachedArtifact] = {}
        self.used_bytes = 0
        self.workflow: Optional[WorkflowIR] = None
        self._insertions = 0
        self._lock = threading.RLock()      # engines offer() from workers
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "admitted": 0, "rejected": 0, "refreshed": 0,
                      "score_time_s": 0.0}
        self._epoch = 0                     # bumped on score-moving changes
        self._heap: List[Tuple[float, int, str]] = []
        self._heap_epoch = -1
        self._wf_versions: Optional[Tuple[int, int]] = None

    def attach_workflow(self, wf: WorkflowIR) -> None:
        with self._lock:
            if wf is not self.workflow:
                self.workflow = wf
                self.policy.invalidate(wf)
                self._epoch += 1

    def get(self, name: str) -> Optional[CachedArtifact]:
        with self._lock:
            art = self.items.get(name)
            if art is None:
                self.stats["misses"] += 1
                return None
            art.last_used = time.time()
            art.uses += 1
            self.stats["hits"] += 1
            self._epoch += 1                # last_used moved (LRU scores)
            return art

    def contains(self, name: str) -> bool:
        return name in self.items

    def offer(self, name: str, value: Any, compute_time_s: float,
              producer: str, nbytes: Optional[int] = None) -> bool:
        """Algorithm 2: try to admit a newly produced artifact, evicting
        lower-importance items while capacity is exceeded."""
        b = nbytes if nbytes is not None else sizeof(value)
        with self._lock:
            art = CachedArtifact(name=name, value=value, bytes=b,
                                 compute_time_s=compute_time_s,
                                 producer=producer, insertion=self._insertions)
            self._insertions += 1

            if not self.policy.admit(art):
                self.stats["rejected"] += 1
                return False
            if b > self.capacity_bytes:
                self.stats["rejected"] += 1
                return False

            # lines 10-11: fits -> cache it
            if self.used_bytes + b <= self.capacity_bytes:
                self._insert(art)
                return True

            # lines 16-31 (NodeSelection): compare vs lowest-scored items
            self._sync_workflow_versions()
            t0 = time.perf_counter()
            new_score = self.policy.score(art, self)
            self.stats["score_time_s"] += time.perf_counter() - t0
            while self.used_bytes + b > self.capacity_bytes:
                if not self.items:
                    break
                k_min, s_min = self._min_scored()
                if s_min >= new_score:
                    self.stats["rejected"] += 1
                    return False               # new artifact loses
                self._evict(k_min)
                # paper: re-evaluate remaining items after every removal —
                # the epoch bump invalidates the heap; the rebuild is cheap
                # because untouched items hit the policy memos
            self._insert(art)
            return True

    def _sync_workflow_versions(self) -> None:
        wf = self.workflow
        v = (None if wf is None
             else (wf.structure_version, wf.weights_version))
        if v != self._wf_versions:
            self._wf_versions = v
            self._epoch += 1

    def _min_scored(self) -> Tuple[str, float]:
        """Current lowest-scored item; re-validates the heap if stale."""
        if self._heap_epoch != self._epoch:
            arts = list(self.items.values())
            t0 = time.perf_counter()
            scores = self.policy.score_many(arts, self)
            self.stats["score_time_s"] += time.perf_counter() - t0
            self._heap = [(s, a.insertion, a.name)
                          for s, a in zip(scores, arts)]
            heapq.heapify(self._heap)
            self._heap_epoch = self._epoch
        s, _, name = self._heap[0]
        return name, s

    def _insert(self, art: CachedArtifact) -> None:
        old = self.items.pop(art.name, None)
        if old is not None:
            # same-key refresh: replace in place — NOT an eviction (and not
            # a second admission), so policy stats stay comparable
            self.used_bytes -= old.bytes
            self.stats["refreshed"] += 1
        else:
            self.stats["admitted"] += 1
        self.items[art.name] = art
        self.used_bytes += art.bytes
        self._epoch += 1

    def _evict(self, name: str) -> None:
        art = self.items.pop(name)
        self.used_bytes -= art.bytes
        self.stats["evictions"] += 1
        self._epoch += 1

    def hit_ratio(self) -> float:
        tot = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / tot if tot else 0.0
