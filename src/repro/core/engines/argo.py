"""Argo Workflows backend: IR -> Argo ``Workflow`` YAML (paper §II.F).

The workflow generator converts the IR DAG to the executable format a
workflow engine consumes — "e.g., YAML format for Argo workflow". No
Kubernetes is needed to *generate*; this is the engine-agnosticism proof.
Emitted YAML validates the paper's CRD size constraint (2MB budget, §IV.B).
"""
from __future__ import annotations

from typing import List

from repro.core.engines.base import Engine, StepRecord, StepStatus, WorkflowRun
from repro.core.ir import Job, WorkflowIR


def _yaml_escape(s: str) -> str:
    if any(c in s for c in ":{}[]#&*!|>'\"%@`"):
        return '"' + s.replace("\\", "\\\\").replace('"', '\\"') + '"'
    return s or '""'


def job_to_template(job: Job) -> List[str]:
    lines = [f"  - name: {job.name}"]
    lines.append("    container:")
    lines.append(f"      image: {_yaml_escape(job.image or 'python:3.11')}")
    cmd = job.command or (["python", "-c", f"run('{job.name}')"]
                          if job.fn is not None else ["echo", job.name])
    lines.append("      command:")
    for c in cmd:
        lines.append(f"      - {_yaml_escape(str(c))}")
    lines.append("      resources:")
    lines.append("        requests:")
    lines.append(f"          cpu: {job.resources.cpu}")
    lines.append(f"          memory: {int(job.resources.mem_bytes / 2**20)}Mi")
    if job.retry_limit:
        lines.append("    retryStrategy:")
        lines.append(f"      limit: {job.retry_limit}")
        lines.append("      retryPolicy: OnTransientError")
    return lines


def to_argo_yaml(wf: WorkflowIR) -> str:
    """Emit an Argo Workflow manifest for the IR."""
    wf.validate()
    out: List[str] = [
        "apiVersion: argoproj.io/v1alpha1",
        "kind: Workflow",
        "metadata:",
        f"  generateName: {wf.name}-",
        "spec:",
        "  entrypoint: main",
        "  templates:",
        "  - name: main",
        "    dag:",
        "      tasks:",
    ]
    for name in wf.topo_order():
        job = wf.jobs[name]
        out.append(f"      - name: {name}")
        out.append(f"        template: {name}")
        deps = sorted(wf.predecessors(name))
        if deps:
            out.append(f"        dependencies: [{', '.join(deps)}]")
        if job.condition is not None:
            art = job.condition.artifact.replace(":", ".")
            out.append(f"        when: \"{{{{tasks.{art}}}}} == "
                       f"{job.condition.value}\"")
    for name in wf.topo_order():
        out.extend(job_to_template(wf.jobs[name]))
    return "\n".join(out) + "\n"


class ArgoSubmitter(Engine):
    """Generates the manifest; 'submission' returns it as the run artifact
    (no cluster in this container — the manifest is the deliverable)."""

    name = "argo"

    def __init__(self, crd_limit_bytes: int = 2 * 1024 * 1024):
        self.crd_limit_bytes = crd_limit_bytes

    def submit(self, wf: WorkflowIR, optimize: bool = True, **kw) -> WorkflowRun:
        from repro.core.autosplit import Budget, split_workflow
        parts = (split_workflow(wf, Budget(spec_bytes=self.crd_limit_bytes))
                 if optimize else [wf])
        run = WorkflowRun(workflow=wf)
        manifests = []
        for p in parts:
            y = to_argo_yaml(p)
            if len(y.encode()) > self.crd_limit_bytes:
                raise ValueError(
                    f"CRD for {p.name} is {len(y.encode())}B > "
                    f"{self.crd_limit_bytes}B limit even after split")
            manifests.append(y)
        run.artifacts["argo:manifests"] = manifests
        for n in wf.jobs:
            run.steps[n] = StepRecord(status=StepStatus.PENDING)
        run.status = "Generated"
        return run
