from repro.core.engines.base import Engine, StepStatus, WorkflowRun
from repro.core.engines.local import LocalEngine
from repro.core.engines.argo import ArgoSubmitter
from repro.core.engines.airflow import AirflowSubmitter
from repro.core.engines.cluster import Cluster, MultiClusterEngine
