"""Multi-cluster workflow scheduling (paper App. B.A).

Ant Group schedules workflows across heterogeneous clusters via a weighted
queue over: (a) workflow priority, (b) cluster CPU/memory capacity,
(c) user CPU/memory quota, (d) user GPU quota — keeping cluster loads
balanced. This module implements that scheduler over an event-driven
simulator (time advances to the next job completion; no sleeping), which is
what the RQ1-style throughput benchmark drives with 22k workflows/day-scale
loads.

Scheduling is fully event-driven: each workflow keeps a min-heap of ready
job indices fed by indegree decrements, and resource-blocked jobs park in
wake-on-cause retry sets — user-quota-blocked jobs are only re-tried when a
job of that same user completes (the only event that can lower the user's
usage), cluster-blocked jobs whenever any completion frees cluster capacity
— so each event touches O(woken + newly-ready) jobs, O((V+E)·log V) per
batch, instead of the former full rescan of every job of every active
workflow per event.

Artifact locality (tiered-cache integration)
--------------------------------------------
Pass ``caches`` (cluster name → ``TieredCacheStore``, ideally all sharing
one ``SharedRemoteTier``) to make placement locality-aware: a finished
job's artifact is offered to its cluster's store, and a consumer job is
placed on the fitting cluster minimizing its input materialization cost —
per input, ``min(fetch, recompute)`` where fetch is the holding tier's
``latency + bytes/bandwidth`` (or the cross-cluster transfer path when the
artifact is only resident elsewhere) and recompute is the Eq. 3-style
first-hop reconstruction cost (producer est_time_s). The winning cost is
added to the job's simulated duration, so makespans reflect data movement
instead of assuming uniform hit latency. With ``caches=None`` (default)
scheduling is bit-identical to the cache-oblivious behavior.
"""
from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.cache.store import TieredCacheStore
from repro.core.engines.base import Engine, StepRecord, StepStatus, WorkflowRun
from repro.core.faults.plan import FaultPlan
from repro.core.gateway.events import EventType
from repro.core.ir import WorkflowIR
from repro.core.obs.metrics import MetricsRegistry, StatsView


@dataclass
class Cluster:
    name: str
    cpu: float
    mem_bytes: float
    gpu: float = 0.0
    used_cpu: float = 0.0
    used_mem: float = 0.0
    used_gpu: float = 0.0
    # simulated preemption (FaultPlan): no placements while the sim clock
    # is before dark_until
    dark_until: float = 0.0

    def fits(self, job) -> bool:
        r = job.resources
        return (self.used_cpu + r.cpu <= self.cpu
                and self.used_mem + r.mem_bytes <= self.mem_bytes
                and self.used_gpu + r.gpu <= self.gpu + 1e-9)

    def load(self) -> float:
        return max(self.used_cpu / max(self.cpu, 1e-9),
                   self.used_mem / max(self.mem_bytes, 1e-9))


@dataclass
class UserQuota:
    cpu: float = 64.0
    mem_bytes: float = 64 * 2**30
    gpu: float = 4.0
    used_cpu: float = 0.0
    used_mem: float = 0.0
    used_gpu: float = 0.0

    def fits(self, job) -> bool:
        r = job.resources
        return (self.used_cpu + r.cpu <= self.cpu
                and self.used_mem + r.mem_bytes <= self.mem_bytes
                and self.used_gpu + r.gpu <= self.gpu + 1e-9)


@dataclass(order=True)
class _QItem:
    sort_key: Tuple                     # (-priority, seq): FIFO within a tier
    wf: WorkflowIR = field(compare=False)
    user: str = field(compare=False)
    priority: int = field(compare=False)
    submit_t: float = field(compare=False)


class _WfState:
    """Per-admitted-workflow scheduling state."""

    __slots__ = ("wf", "user", "run", "indeg", "remaining", "order", "jidx",
                 "ready", "idx")

    def __init__(self, wf: WorkflowIR, user: str, idx: int):
        self.wf = wf
        self.user = user
        self.idx = idx                      # admission order
        self.run = WorkflowRun(workflow=wf)
        self.order = list(wf.jobs)          # job insertion order
        self.jidx = {n: i for i, n in enumerate(self.order)}
        self.indeg = {n: wf.in_degree(n) for n in self.order}
        self.remaining = len(self.order)
        # min-heap of job indices whose deps are satisfied but not launched
        self.ready: List[int] = [i for i, n in enumerate(self.order)
                                 if self.indeg[n] == 0]
        heapq.heapify(self.ready)
        for n in self.order:
            self.run.steps[n] = StepRecord()


class MultiClusterEngine(Engine):
    """Event-driven simulation of the cross-cluster scheduling queue."""

    name = "cluster"

    def __init__(self, clusters: Optional[List[Cluster]] = None,
                 quotas: Optional[Dict[str, UserQuota]] = None,
                 caches: Optional[Dict[str, "TieredCacheStore"]] = None,
                 xfer_bandwidth_bytes_s: float = 1.2e8,
                 xfer_latency_s: float = 2e-2,
                 fault_plan: Optional[FaultPlan] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.clusters = clusters or [
            Cluster("gpu-cluster", cpu=512, mem_bytes=2048 * 2**30, gpu=64),
            Cluster("cpu-cluster", cpu=2048, mem_bytes=8192 * 2**30),
            Cluster("far-storage", cpu=1024, mem_bytes=4096 * 2**30),
        ]
        # precomputed candidate list: GPU jobs may only land on GPU clusters
        self._gpu_clusters = [c for c in self.clusters if c.gpu > 0]
        self.quotas = quotas or {}
        # per-cluster tiered artifact stores (None = cache-oblivious)
        self.caches = caches
        self.xfer_bandwidth_bytes_s = xfer_bandwidth_bytes_s
        self.xfer_latency_s = xfer_latency_s
        # simulated cluster preemption (FaultPlan.preemption_rate_per_s):
        # per-cluster Poisson process; a struck cluster goes dark for
        # preemption_dark_s, its in-flight jobs are evicted and re-enter
        # their ready queues (re-placed elsewhere or parked until recovery)
        self.fault_plan = fault_plan
        self._seq = itertools.count()
        # scheduler telemetry in registry instruments; ``metrics`` stays a
        # dict-compatible view (the equivalence suite compares it per-key
        # against a plain-dict reference, including the nested
        # ``cluster_busy_s`` map — float accumulation order is identical
        # because Counter.inc is the same ``+=`` under a lock)
        self.registry = registry if registry is not None \
            else MetricsRegistry("cluster")
        self._m = {
            "scheduled_jobs":
                self.registry.counter("cluster_scheduled_jobs_total"),
            "completed_workflows":
                self.registry.counter("cluster_completed_workflows_total"),
            "failed_admission":
                self.registry.counter("cluster_failed_admission_total"),
            "makespan_s": self.registry.gauge("cluster_makespan_s"),
            "fetch_wait_s": self.registry.counter("cluster_fetch_wait_s"),
            "recompute_wait_s":
                self.registry.counter("cluster_recompute_wait_s"),
            "preemptions": self.registry.counter("cluster_preemptions_total"),
            "preempted_jobs":
                self.registry.counter("cluster_preempted_jobs_total"),
        }
        # pre-created so every cluster reports a (possibly zero) series
        self._m_busy = {c.name: self.registry.counter("cluster_busy_cpu_s",
                                                      cluster=c.name)
                        for c in self.clusters}
        self._collector = None
        self._tsdb = None

    @property
    def metrics(self) -> StatsView:
        fields: Dict[str, object] = dict(self._m)
        fields["cluster_busy_s"] = \
            lambda: {n: c.value for n, c in self._m_busy.items()}
        return StatsView(fields)

    def attach_collector(self, collector) -> None:
        """Span-trace every subsequent ``submit_admitted`` batch: finished
        handles' event streams are ingested into ``collector`` and each
        returned run gets a ``report()``-able back-reference."""
        self._collector = collector

    def attach_telemetry(self, tsdb) -> None:
        """Sample this engine's registry into ``tsdb`` (a
        ``TimeSeriesDB``) at the end of every ``submit_many`` /
        ``submit_admitted`` batch — the batch simulator has no daemon
        loop, so batch boundaries are its sampling cadence."""
        self._tsdb = tsdb

    def _quota(self, user: str) -> UserQuota:
        if user not in self.quotas:
            self.quotas[user] = UserQuota()
        return self.quotas[user]

    def _pick_cluster(self, job, st: Optional["_WfState"] = None,
                      n: Optional[str] = None,
                      now: float = 0.0) -> Optional[Cluster]:
        """Weighted choice: prefer fitting cluster with the lowest load;
        GPU jobs must land on a GPU cluster. With per-cluster caches
        attached, artifact locality dominates: the fitting cluster with the
        cheapest input materialization wins, load breaks ties. Preempted
        (dark) clusters are excluded until they recover."""
        pool = self._gpu_clusters if job.resources.gpu > 0 else self.clusters
        if self.caches is None or st is None:
            best, best_load = None, float("inf")
            for c in pool:
                if c.dark_until <= now and c.fits(job):
                    l = c.load()
                    if l < best_load:
                        best, best_load = c, l
            return best
        best, best_key = None, None
        for c in pool:
            if c.dark_until <= now and c.fits(job):
                key = (round(self._input_cost_s(st, n, c), 9), c.load())
                if best_key is None or key < best_key:
                    best, best_key = c, key
        return best

    # -- artifact locality (tiered caches) ---------------------------------
    @staticmethod
    def _art_key(wf: WorkflowIR, job_name: str) -> str:
        return f"{wf.name}/{job_name}"

    def _input_fetch_s(self, wf: WorkflowIR, p: str,
                       cluster: Cluster) -> Tuple[float, float]:
        """(fetch_s, recompute_s) for predecessor p's artifact seen from
        `cluster`: fetch prices the holding tier (latency + bytes/bw) when
        locally resident (incl. a shared REMOTE tier), the cross-cluster
        transfer path when only a sibling cluster holds it, and infinity
        when it is cached nowhere (nothing to fetch — the consumer must
        recompute); recompute is the Eq. 3 first-hop reconstruction cost
        (the producer's est_time_s)."""
        job = wf.jobs[p]
        nbytes = max(1, job.est_mem_bytes)
        key = self._art_key(wf, p)
        store = self.caches.get(cluster.name)
        tier = store.find_tier(key) if store else None
        if tier is not None:
            fetch = tier.access_time_s(nbytes)
        elif any(c is not store and c.find_tier(key) is not None
                 for c in self.caches.values()):
            fetch = self.xfer_latency_s + nbytes / self.xfer_bandwidth_bytes_s
        else:
            fetch = float("inf")
        return fetch, job.est_time_s, nbytes

    def _input_cost_s(self, st: "_WfState", n: str,
                      cluster: Cluster) -> float:
        """Simulated time to materialize job n's inputs on `cluster`: per
        input, the consumer takes min(fetch, recompute)."""
        total = 0.0
        for p in st.wf.predecessors(n):
            fetch, recompute, _ = self._input_fetch_s(st.wf, p, cluster)
            total += min(fetch, recompute)
        return total

    def _charge_inputs_s(self, st: "_WfState", n: str,
                         cluster: Cluster) -> float:
        """Like _input_cost_s, but records the decision: a fetch goes
        through the SERVING store's get() (hit accounting + the promotion
        signal land on whichever cluster actually holds the artifact), a
        recompute re-offers the rebuilt artifact to the local store so
        later consumers on this cluster fetch instead of re-paying it, and
        the waits split into fetch vs recompute metrics."""
        store = self.caches.get(cluster.name)
        total = 0.0
        for p in st.wf.predecessors(n):
            fetch, recompute, nbytes = self._input_fetch_s(st.wf, p, cluster)
            key = self._art_key(st.wf, p)
            if fetch <= recompute:
                server = store if store is not None \
                    and store.find_tier(key) is not None else next(
                        (c for c in self.caches.values()
                         if c.find_tier(key) is not None), None)
                if server is not None:
                    server.get(key)
                    if server is not store and store is not None:
                        # cross-cluster pull: keep the fetched copy local
                        # so later consumers here skip the transfer
                        store.offer(key, None, compute_time_s=recompute,
                                    producer=p, nbytes=nbytes)
                total += fetch
                self._m["fetch_wait_s"].inc(fetch)
            else:
                total += recompute
                self._m["recompute_wait_s"].inc(recompute)
                if store is not None:
                    store.offer(key, None, compute_time_s=recompute,
                                producer=p, nbytes=nbytes)
        return total

    def lint_context(self):
        return {"clusters": self.clusters}

    def submit_many(self, workflows: List[Tuple[WorkflowIR, str, int]],
                    lint: str = "error",
                    handles: Optional[Dict[str, object]] = None
                    ) -> Dict[str, WorkflowRun]:
        """Simulate scheduling a batch of (workflow, user, priority).

        Each workflow is linted against this engine's clusters first: a
        job that fits NO cluster (CLR005) rejects its workflow up front
        instead of pinning it Pending in the queue forever
        (``lint="warn"|"off"`` restores the old behavior). Returns runs
        keyed by workflow name; self.metrics aggregates utilization &
        makespan.

        With a ``fault_plan`` whose ``preemption_rate_per_s > 0``, each
        cluster is struck by a seeded Poisson preemption process: the
        cluster goes dark for ``preemption_dark_s`` of simulated time, its
        in-flight jobs are evicted (freed, attempts bumped, re-readied for
        placement elsewhere or parked until recovery), and — when
        ``handles`` maps workflow names to async run handles —
        ``CLUSTER_PREEMPTED`` events are published per evicted job. With
        ``fault_plan=None`` scheduling is bit-identical to before."""
        if lint != "off":
            from repro.core.analysis import lint_gate
            for wf, _user, _prio in workflows:
                lint_gate(wf, mode=lint, clusters=self.clusters)
        queue: List[_QItem] = []
        for wf, user, prio in workflows:
            wf.validate()
            heapq.heappush(queue, _QItem((-prio, next(self._seq)),
                                         wf, user, prio, 0.0))
        runs: Dict[str, WorkflowRun] = {}
        active: List[_WfState] = []
        # (finish_time, seq, cluster, user, wf_state, job_name); chaos
        # markers reuse the tuple shape with wf_state=None and job_name in
        # {"__preempt__", "__recover__"}
        events: List[Tuple[float, int, Cluster, str,
                           Optional[_WfState], str]] = []
        now = 0.0
        last_t = 0.0
        # darkness never leaks across batches: the sim clock restarts at 0
        for c in self.clusters:
            c.dark_until = 0.0
        plan = self.fault_plan
        chaos = plan is not None and plan.preemption_rate_per_s > 0
        # seq -> (cluster, user, wf_state, job_name) of jobs currently
        # executing (eviction candidates); evicted completion events stay
        # in the heap and are lazily discarded via `dead`
        inflight: Dict[int, Tuple[Cluster, str, _WfState, str]] = {}
        dead: Set[int] = set()
        rngs: Dict[str, random.Random] = {}
        done_local = 0
        if chaos:
            for c in self.clusters:
                rngs[c.name] = random.Random(f"{plan.seed}:{c.name}")
                t = rngs[c.name].expovariate(plan.preemption_rate_per_s)
                heapq.heappush(events, (t, next(self._seq), c, "",
                                        None, "__preempt__"))
        # admission indices of workflows with launchable work, visited in
        # admission order each pass; workflows with nothing ready are
        # never touched
        armed: List[int] = []
        armed_set = set()
        # wake-on-cause retry sets of (admission_idx, job_idx): a job that
        # failed its user-quota check can only fit once that user's usage
        # drops, so it waits for that user's next completion; a job with no
        # fitting cluster retries whenever any completion frees capacity
        quota_waiters: Dict[str, List[Tuple[int, int]]] = {}
        cluster_waiters: List[Tuple[int, int]] = []

        def arm(st: _WfState) -> None:
            if st.idx not in armed_set:
                armed_set.add(st.idx)
                heapq.heappush(armed, st.idx)

        def admit_from_queue() -> None:
            # Admission is explicitly unconditional: workflow admission has
            # no capacity gate — quota/cluster capacity is enforced per job
            # at launch time, so the priority queue drains completely.
            while queue:
                item = heapq.heappop(queue)
                st = _WfState(item.wf, item.user, len(active))
                active.append(st)
                runs[item.wf.name] = st.run
                arm(st)

        def launch_pass() -> None:
            # drain armed workflows in admission order (heap pops ascend)
            batch: List[int] = []
            while armed:
                batch.append(heapq.heappop(armed))
            armed_set.clear()
            for ai in batch:
                st = active[ai]
                wf = st.wf
                while st.ready:
                    i = heapq.heappop(st.ready)
                    n = st.order[i]
                    job = wf.jobs[n]
                    q = self._quota(st.user)
                    if not q.fits(job):
                        quota_waiters.setdefault(st.user, []).append((ai, i))
                        continue
                    c = self._pick_cluster(job, st, n, now=now)
                    if c is None:
                        self._m["failed_admission"].inc()
                        cluster_waiters.append((ai, i))
                        continue
                    r = job.resources
                    c.used_cpu += r.cpu
                    c.used_mem += r.mem_bytes
                    c.used_gpu += r.gpu
                    q.used_cpu += r.cpu
                    q.used_mem += r.mem_bytes
                    q.used_gpu += r.gpu
                    st.run.steps[n].status = StepStatus.RUNNING
                    st.run.steps[n].start = now
                    self._m["scheduled_jobs"].inc()
                    dur = job.est_time_s
                    if self.caches is not None:
                        dur += self._charge_inputs_s(st, n, c)
                    ev_seq = next(self._seq)
                    heapq.heappush(events, (now + dur, ev_seq, c, st.user,
                                            st, n))
                    if chaos:
                        inflight[ev_seq] = (c, st.user, st, n)

        admit_from_queue()
        launch_pass()
        while events:
            now, seq, c, user, st, n = heapq.heappop(events)
            if st is None:                       # chaos marker, not a job
                if n == "__preempt__":
                    self._m["preemptions"].inc()
                    c.dark_until = now + plan.preemption_dark_s
                    # evict everything in flight on the struck cluster:
                    # free its resources, bump attempts, re-ready the job
                    victims = [s for s, (vc, _, _, _) in inflight.items()
                               if vc is c]
                    for vseq in victims:
                        _, vuser, vst, vn = inflight.pop(vseq)
                        dead.add(vseq)
                        vjob = vst.wf.jobs[vn]
                        vr = vjob.resources
                        c.used_cpu -= vr.cpu
                        c.used_mem -= vr.mem_bytes
                        c.used_gpu -= vr.gpu
                        vq = self._quota(vuser)
                        vq.used_cpu -= vr.cpu
                        vq.used_mem -= vr.mem_bytes
                        vq.used_gpu -= vr.gpu
                        rec = vst.run.steps[vn]
                        rec.status = StepStatus.PENDING
                        rec.attempts += 1
                        rec.error = (f"preempted on {c.name} "
                                     f"at t={now:.3f}")
                        self._m["preempted_jobs"].inc()
                        heapq.heappush(vst.ready, vst.jidx[vn])
                        arm(vst)
                        h = handles.get(vst.wf.name) if handles else None
                        if h is not None:
                            h._publish(EventType.CLUSTER_PREEMPTED,
                                       step=vn, attempt=rec.attempts,
                                       error=rec.error)
                    heapq.heappush(events, (now + plan.preemption_dark_s,
                                            next(self._seq), c, "",
                                            None, "__recover__"))
                    if done_local < len(active):
                        nxt = now + rngs[c.name].expovariate(
                            plan.preemption_rate_per_s)
                        heapq.heappush(events, (nxt, next(self._seq), c,
                                                "", None, "__preempt__"))
                    launch_pass()
                else:                            # __recover__
                    # the cluster is placeable again: wake parked jobs
                    for ai, i in cluster_waiters:
                        stw = active[ai]
                        heapq.heappush(stw.ready, i)
                        arm(stw)
                    cluster_waiters = []
                    launch_pass()
                continue
            if chaos:
                if seq in dead:                  # evicted before finishing
                    dead.discard(seq)
                    continue
                inflight.pop(seq, None)
            job = st.wf.jobs[n]
            r = job.resources
            c.used_cpu -= r.cpu
            c.used_mem -= r.mem_bytes
            c.used_gpu -= r.gpu
            q = self._quota(user)
            q.used_cpu -= r.cpu
            q.used_mem -= r.mem_bytes
            q.used_gpu -= r.gpu
            rec = st.run.steps[n]
            # with caches the job holds its resources for est_time_s PLUS
            # the charged input-materialization wait (now - start); without
            # caches keep the exact legacy expression (equivalence suite)
            busy = (job.est_time_s if self.caches is None
                    else now - rec.start)
            self._m_busy[c.name].inc(busy * r.cpu)
            rec.status = StepStatus.SUCCEEDED
            rec.end = now
            last_t = now
            if self.caches is not None:
                store = self.caches.get(c.name)
                if store is not None:
                    # the artifact materializes on the cluster that ran the
                    # producer; demotion may later push it to shared REMOTE
                    store.offer(self._art_key(st.wf, n), None,
                                compute_time_s=job.est_time_s, producer=n,
                                nbytes=max(1, job.est_mem_bytes))
            st.remaining -= 1
            newly_ready = False
            for s in st.wf.successors(n):
                st.indeg[s] -= 1
                if st.indeg[s] == 0:
                    heapq.heappush(st.ready, st.jidx[s])
                    newly_ready = True
            if st.remaining == 0:
                st.run.status = "Succeeded"
                st.run.wall_time_s = now
                self._m["completed_workflows"].inc()
                done_local += 1
            if newly_ready:
                arm(st)
            # wake exactly the jobs this completion could unblock: the
            # finishing user's quota-waiters, and (cluster capacity freed)
            # every cluster-waiter
            woken = quota_waiters.pop(user, [])
            if cluster_waiters:
                woken += cluster_waiters
                cluster_waiters = []
            for ai, i in woken:
                stw = active[ai]
                heapq.heappush(stw.ready, i)
                arm(stw)
            launch_pass()
        # the last *completion* time (recovery markers may outlive the work)
        self._m["makespan_s"].set(last_t)
        if self._tsdb is not None:
            try:
                self._tsdb.sample(self.registry.snapshot())
            except Exception:  # noqa: BLE001 — telemetry is advisory
                pass
        return runs

    def submit(self, wf: WorkflowIR, optimize: bool = True, user: str = "u0",
               priority: int = 0, lint: str = "error", **kw) -> WorkflowRun:
        return self.submit_many([(wf, user, priority)], lint=lint)[wf.name]

    def submit_admitted(self, queue, max_n: Optional[int] = None
                        ) -> Dict[str, WorkflowRun]:
        """Drain a gateway ``AdmissionQueue`` (weighted-round-robin tenant
        order) into one simulated batch: tenants map to scheduler users,
        priorities pass through to the weighted queue, and any attached
        async handles are finished with their runs (emitting the coarse
        ``WORKFLOW_DONE``). This is the batch-scheduler consumer of the
        same backpressured admission layer that feeds ``LocalEngine``.

        Workflow names must be unique within the drained batch
        (``submit_many`` keys its results by name); duplicates raise
        ``ValueError`` instead of silently handing two submitters the
        same run."""
        from repro.core.gateway.events import EventType
        items = queue.drain(max_n)
        seen: Dict[str, str] = {}
        for it in items:
            if it.wf.name in seen:
                raise ValueError(
                    f"duplicate workflow name {it.wf.name!r} in admitted "
                    f"batch (tenants {seen[it.wf.name]!r} and "
                    f"{it.tenant!r}); submit_many results are keyed by "
                    "name — rename or submit in separate batches")
            seen[it.wf.name] = it.tenant
        runs = self.submit_many(
            [(it.wf, it.tenant, it.priority) for it in items],
            handles={it.wf.name: it.handle for it in items
                     if it.handle is not None})
        for it in items:
            if it.handle is not None:
                run = runs[it.wf.name]
                it.handle.run = run
                it.handle._publish(EventType.WORKFLOW_DONE, status=run.status)
                it.handle._finish(run)
        c = self._collector
        if c is not None:
            import weakref
            for it in items:
                if it.handle is None:
                    continue
                run = runs[it.wf.name]
                c.ingest(it.handle.events_so_far(), wf=it.wf,
                         run_id=run.run_id, tenant=it.tenant)
                run._obs_collector = weakref.ref(c)
        return runs
