"""Multi-cluster workflow scheduling (paper App. B.A).

Ant Group schedules workflows across heterogeneous clusters via a weighted
queue over: (a) workflow priority, (b) cluster CPU/memory capacity,
(c) user CPU/memory quota, (d) user GPU quota — keeping cluster loads
balanced. This module implements that scheduler over an event-driven
simulator (time advances to the next job completion; no sleeping), which is
what the RQ1-style throughput benchmark drives with 22k workflows/day-scale
loads.

Scheduling is fully event-driven: each workflow keeps a min-heap of ready
job indices fed by indegree decrements, and resource-blocked jobs park in
wake-on-cause retry sets — user-quota-blocked jobs are only re-tried when a
job of that same user completes (the only event that can lower the user's
usage), cluster-blocked jobs whenever any completion frees cluster capacity
— so each event touches O(woken + newly-ready) jobs, O((V+E)·log V) per
batch, instead of the former full rescan of every job of every active
workflow per event.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.engines.base import Engine, StepRecord, StepStatus, WorkflowRun
from repro.core.ir import WorkflowIR


@dataclass
class Cluster:
    name: str
    cpu: float
    mem_bytes: float
    gpu: float = 0.0
    used_cpu: float = 0.0
    used_mem: float = 0.0
    used_gpu: float = 0.0

    def fits(self, job) -> bool:
        r = job.resources
        return (self.used_cpu + r.cpu <= self.cpu
                and self.used_mem + r.mem_bytes <= self.mem_bytes
                and self.used_gpu + r.gpu <= self.gpu + 1e-9)

    def load(self) -> float:
        return max(self.used_cpu / max(self.cpu, 1e-9),
                   self.used_mem / max(self.mem_bytes, 1e-9))


@dataclass
class UserQuota:
    cpu: float = 64.0
    mem_bytes: float = 64 * 2**30
    gpu: float = 4.0
    used_cpu: float = 0.0
    used_mem: float = 0.0
    used_gpu: float = 0.0

    def fits(self, job) -> bool:
        r = job.resources
        return (self.used_cpu + r.cpu <= self.cpu
                and self.used_mem + r.mem_bytes <= self.mem_bytes
                and self.used_gpu + r.gpu <= self.gpu + 1e-9)


@dataclass(order=True)
class _QItem:
    sort_key: Tuple                     # (-priority, seq): FIFO within a tier
    wf: WorkflowIR = field(compare=False)
    user: str = field(compare=False)
    priority: int = field(compare=False)
    submit_t: float = field(compare=False)


class _WfState:
    """Per-admitted-workflow scheduling state."""

    __slots__ = ("wf", "user", "run", "indeg", "remaining", "order", "jidx",
                 "ready", "idx")

    def __init__(self, wf: WorkflowIR, user: str, idx: int):
        self.wf = wf
        self.user = user
        self.idx = idx                      # admission order
        self.run = WorkflowRun(workflow=wf)
        self.order = list(wf.jobs)          # job insertion order
        self.jidx = {n: i for i, n in enumerate(self.order)}
        self.indeg = {n: wf.in_degree(n) for n in self.order}
        self.remaining = len(self.order)
        # min-heap of job indices whose deps are satisfied but not launched
        self.ready: List[int] = [i for i, n in enumerate(self.order)
                                 if self.indeg[n] == 0]
        heapq.heapify(self.ready)
        for n in self.order:
            self.run.steps[n] = StepRecord()


class MultiClusterEngine(Engine):
    """Event-driven simulation of the cross-cluster scheduling queue."""

    name = "cluster"

    def __init__(self, clusters: Optional[List[Cluster]] = None,
                 quotas: Optional[Dict[str, UserQuota]] = None):
        self.clusters = clusters or [
            Cluster("gpu-cluster", cpu=512, mem_bytes=2048 * 2**30, gpu=64),
            Cluster("cpu-cluster", cpu=2048, mem_bytes=8192 * 2**30),
            Cluster("far-storage", cpu=1024, mem_bytes=4096 * 2**30),
        ]
        # precomputed candidate list: GPU jobs may only land on GPU clusters
        self._gpu_clusters = [c for c in self.clusters if c.gpu > 0]
        self.quotas = quotas or {}
        self._seq = itertools.count()
        self.metrics = {"scheduled_jobs": 0, "completed_workflows": 0,
                        "failed_admission": 0, "makespan_s": 0.0,
                        "cluster_busy_s": {c.name: 0.0 for c in self.clusters}}

    def _quota(self, user: str) -> UserQuota:
        if user not in self.quotas:
            self.quotas[user] = UserQuota()
        return self.quotas[user]

    def _pick_cluster(self, job) -> Optional[Cluster]:
        """Weighted choice: prefer fitting cluster with the lowest load;
        GPU jobs must land on a GPU cluster."""
        pool = self._gpu_clusters if job.resources.gpu > 0 else self.clusters
        best, best_load = None, float("inf")
        for c in pool:
            if c.fits(job):
                l = c.load()
                if l < best_load:
                    best, best_load = c, l
        return best

    def submit_many(self, workflows: List[Tuple[WorkflowIR, str, int]]
                    ) -> Dict[str, WorkflowRun]:
        """Simulate scheduling a batch of (workflow, user, priority).

        Returns runs keyed by workflow name; self.metrics aggregates
        utilization & makespan."""
        queue: List[_QItem] = []
        for wf, user, prio in workflows:
            wf.validate()
            heapq.heappush(queue, _QItem((-prio, next(self._seq)),
                                         wf, user, prio, 0.0))
        runs: Dict[str, WorkflowRun] = {}
        active: List[_WfState] = []
        # (finish_time, seq, cluster, user, wf_state, job_name)
        events: List[Tuple[float, int, Cluster, str, _WfState, str]] = []
        now = 0.0
        # admission indices of workflows with launchable work, visited in
        # admission order each pass; workflows with nothing ready are
        # never touched
        armed: List[int] = []
        armed_set = set()
        # wake-on-cause retry sets of (admission_idx, job_idx): a job that
        # failed its user-quota check can only fit once that user's usage
        # drops, so it waits for that user's next completion; a job with no
        # fitting cluster retries whenever any completion frees capacity
        quota_waiters: Dict[str, List[Tuple[int, int]]] = {}
        cluster_waiters: List[Tuple[int, int]] = []

        def arm(st: _WfState) -> None:
            if st.idx not in armed_set:
                armed_set.add(st.idx)
                heapq.heappush(armed, st.idx)

        def admit_from_queue() -> None:
            # Admission is explicitly unconditional: workflow admission has
            # no capacity gate — quota/cluster capacity is enforced per job
            # at launch time, so the priority queue drains completely.
            while queue:
                item = heapq.heappop(queue)
                st = _WfState(item.wf, item.user, len(active))
                active.append(st)
                runs[item.wf.name] = st.run
                arm(st)

        def launch_pass() -> None:
            # drain armed workflows in admission order (heap pops ascend)
            batch: List[int] = []
            while armed:
                batch.append(heapq.heappop(armed))
            armed_set.clear()
            for ai in batch:
                st = active[ai]
                wf = st.wf
                while st.ready:
                    i = heapq.heappop(st.ready)
                    n = st.order[i]
                    job = wf.jobs[n]
                    q = self._quota(st.user)
                    if not q.fits(job):
                        quota_waiters.setdefault(st.user, []).append((ai, i))
                        continue
                    c = self._pick_cluster(job)
                    if c is None:
                        self.metrics["failed_admission"] += 1
                        cluster_waiters.append((ai, i))
                        continue
                    r = job.resources
                    c.used_cpu += r.cpu
                    c.used_mem += r.mem_bytes
                    c.used_gpu += r.gpu
                    q.used_cpu += r.cpu
                    q.used_mem += r.mem_bytes
                    q.used_gpu += r.gpu
                    st.run.steps[n].status = StepStatus.RUNNING
                    st.run.steps[n].start = now
                    self.metrics["scheduled_jobs"] += 1
                    heapq.heappush(events, (now + job.est_time_s,
                                            next(self._seq), c, st.user,
                                            st, n))

        admit_from_queue()
        launch_pass()
        while events:
            now, _, c, user, st, n = heapq.heappop(events)
            job = st.wf.jobs[n]
            r = job.resources
            c.used_cpu -= r.cpu
            c.used_mem -= r.mem_bytes
            c.used_gpu -= r.gpu
            q = self._quota(user)
            q.used_cpu -= r.cpu
            q.used_mem -= r.mem_bytes
            q.used_gpu -= r.gpu
            self.metrics["cluster_busy_s"][c.name] += job.est_time_s * r.cpu
            rec = st.run.steps[n]
            rec.status = StepStatus.SUCCEEDED
            rec.end = now
            st.remaining -= 1
            newly_ready = False
            for s in st.wf.successors(n):
                st.indeg[s] -= 1
                if st.indeg[s] == 0:
                    heapq.heappush(st.ready, st.jidx[s])
                    newly_ready = True
            if st.remaining == 0:
                st.run.status = "Succeeded"
                st.run.wall_time_s = now
                self.metrics["completed_workflows"] += 1
            if newly_ready:
                arm(st)
            # wake exactly the jobs this completion could unblock: the
            # finishing user's quota-waiters, and (cluster capacity freed)
            # every cluster-waiter
            woken = quota_waiters.pop(user, [])
            if cluster_waiters:
                woken += cluster_waiters
                cluster_waiters = []
            for ai, i in woken:
                stw = active[ai]
                heapq.heappush(stw.ready, i)
                arm(stw)
            launch_pass()
        self.metrics["makespan_s"] = now
        return runs

    def submit(self, wf: WorkflowIR, optimize: bool = True, user: str = "u0",
               priority: int = 0, **kw) -> WorkflowRun:
        return self.submit_many([(wf, user, priority)])[wf.name]
