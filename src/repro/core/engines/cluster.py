"""Multi-cluster workflow scheduling (paper App. B.A).

Ant Group schedules workflows across heterogeneous clusters via a weighted
queue over: (a) workflow priority, (b) cluster CPU/memory capacity,
(c) user CPU/memory quota, (d) user GPU quota — keeping cluster loads
balanced. This module implements that scheduler over an event-driven
simulator (time advances to the next job completion; no sleeping), which is
what the RQ1-style throughput benchmark drives with 22k workflows/day-scale
loads.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.engines.base import Engine, StepRecord, StepStatus, WorkflowRun
from repro.core.ir import WorkflowIR


@dataclass
class Cluster:
    name: str
    cpu: float
    mem_bytes: float
    gpu: float = 0.0
    used_cpu: float = 0.0
    used_mem: float = 0.0
    used_gpu: float = 0.0

    def fits(self, job) -> bool:
        r = job.resources
        return (self.used_cpu + r.cpu <= self.cpu
                and self.used_mem + r.mem_bytes <= self.mem_bytes
                and self.used_gpu + r.gpu <= self.gpu + 1e-9)

    def load(self) -> float:
        return max(self.used_cpu / max(self.cpu, 1e-9),
                   self.used_mem / max(self.mem_bytes, 1e-9))


@dataclass
class UserQuota:
    cpu: float = 64.0
    mem_bytes: float = 64 * 2**30
    gpu: float = 4.0
    used_cpu: float = 0.0
    used_mem: float = 0.0
    used_gpu: float = 0.0

    def fits(self, job) -> bool:
        r = job.resources
        return (self.used_cpu + r.cpu <= self.cpu
                and self.used_mem + r.mem_bytes <= self.mem_bytes
                and self.used_gpu + r.gpu <= self.gpu + 1e-9)


@dataclass(order=True)
class _QItem:
    sort_key: Tuple
    seq: int
    wf: WorkflowIR = field(compare=False)
    user: str = field(compare=False)
    priority: int = field(compare=False)
    submit_t: float = field(compare=False)


class MultiClusterEngine(Engine):
    """Event-driven simulation of the cross-cluster scheduling queue."""

    name = "cluster"

    def __init__(self, clusters: Optional[List[Cluster]] = None,
                 quotas: Optional[Dict[str, UserQuota]] = None):
        self.clusters = clusters or [
            Cluster("gpu-cluster", cpu=512, mem_bytes=2048 * 2**30, gpu=64),
            Cluster("cpu-cluster", cpu=2048, mem_bytes=8192 * 2**30),
            Cluster("far-storage", cpu=1024, mem_bytes=4096 * 2**30),
        ]
        self.quotas = quotas or {}
        self._seq = itertools.count()
        self.metrics = {"scheduled_jobs": 0, "completed_workflows": 0,
                        "failed_admission": 0, "makespan_s": 0.0,
                        "cluster_busy_s": {c.name: 0.0 for c in self.clusters}}

    def _quota(self, user: str) -> UserQuota:
        if user not in self.quotas:
            self.quotas[user] = UserQuota()
        return self.quotas[user]

    def _pick_cluster(self, job) -> Optional[Cluster]:
        """Weighted choice: prefer fitting cluster with the lowest load;
        GPU jobs must land on a GPU cluster."""
        cands = [c for c in self.clusters if c.fits(job)]
        if job.resources.gpu > 0:
            cands = [c for c in cands if c.gpu > 0]
        if not cands:
            return None
        return min(cands, key=lambda c: c.load())

    def submit_many(self, workflows: List[Tuple[WorkflowIR, str, int]]
                    ) -> Dict[str, WorkflowRun]:
        """Simulate scheduling a batch of (workflow, user, priority).

        Returns runs keyed by workflow name; self.metrics aggregates
        utilization & makespan."""
        queue: List[_QItem] = []
        for wf, user, prio in workflows:
            wf.validate()
            heapq.heappush(queue, _QItem((-prio, next(self._seq)),
                                         next(self._seq), wf, user, prio, 0.0))
        runs: Dict[str, WorkflowRun] = {}
        # active workflow state: remaining deps per job
        active: List[Dict] = []
        # (finish_time, seq, cluster, user, wf_state, job_name)
        events: List[Tuple[float, int, Cluster, str, Dict, str]] = []
        now = 0.0

        def admit_from_queue():
            admitted = True
            while queue and admitted:
                item = queue[0]
                st = {"wf": item.wf, "user": item.user,
                      "indeg": {n: len(item.wf.predecessors(n))
                                for n in item.wf.jobs},
                      "remaining": len(item.wf.jobs),
                      "run": WorkflowRun(workflow=item.wf)}
                for n in item.wf.jobs:
                    st["run"].steps[n] = StepRecord()
                heapq.heappop(queue)
                active.append(st)
                runs[item.wf.name] = st["run"]

        def launch_ready():
            for st in active:
                wf = st["wf"]
                for n, k in list(st["indeg"].items()):
                    if k != 0 or st["run"].steps[n].status != StepStatus.PENDING:
                        continue
                    job = wf.jobs[n]
                    q = self._quota(st["user"])
                    if not q.fits(job):
                        continue
                    c = self._pick_cluster(job)
                    if c is None:
                        self.metrics["failed_admission"] += 1
                        continue
                    r = job.resources
                    c.used_cpu += r.cpu
                    c.used_mem += r.mem_bytes
                    c.used_gpu += r.gpu
                    q.used_cpu += r.cpu
                    q.used_mem += r.mem_bytes
                    q.used_gpu += r.gpu
                    st["run"].steps[n].status = StepStatus.RUNNING
                    st["run"].steps[n].start = now
                    self.metrics["scheduled_jobs"] += 1
                    heapq.heappush(events, (now + job.est_time_s,
                                            next(self._seq), c, st["user"],
                                            st, n))

        admit_from_queue()
        launch_ready()
        while events:
            now, _, c, user, st, n = heapq.heappop(events)
            job = st["wf"].jobs[n]
            r = job.resources
            c.used_cpu -= r.cpu
            c.used_mem -= r.mem_bytes
            c.used_gpu -= r.gpu
            q = self._quota(user)
            q.used_cpu -= r.cpu
            q.used_mem -= r.mem_bytes
            q.used_gpu -= r.gpu
            self.metrics["cluster_busy_s"][c.name] += job.est_time_s * r.cpu
            rec = st["run"].steps[n]
            rec.status = StepStatus.SUCCEEDED
            rec.end = now
            st["remaining"] -= 1
            for s in st["wf"].successors(n):
                st["indeg"][s] -= 1
            if st["remaining"] == 0:
                st["run"].status = "Succeeded"
                st["run"].wall_time_s = now
                self.metrics["completed_workflows"] += 1
            launch_ready()
        self.metrics["makespan_s"] = now
        return runs

    def submit(self, wf: WorkflowIR, optimize: bool = True, user: str = "u0",
               priority: int = 0, **kw) -> WorkflowRun:
        return self.submit_many([(wf, user, priority)])[wf.name]
