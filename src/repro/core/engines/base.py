"""Engine interface + run bookkeeping (paper §II.F, App. B.B).

Every backend consumes the same IR. ``WorkflowRun`` persists step statuses
so a failed workflow can be restarted from the failure point, skipping
steps whose status is Succeeded / Skipped / Cached (paper App. B.B).
"""
from __future__ import annotations

import asyncio
import enum
import json
import time
import uuid
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.core.ir import WorkflowIR


class StepStatus(str, enum.Enum):
    PENDING = "Pending"
    RUNNING = "Running"
    SUCCEEDED = "Succeeded"
    FAILED = "Failed"
    SKIPPED = "Skipped"
    CACHED = "Cached"


@dataclass
class StepRecord:
    status: StepStatus = StepStatus.PENDING
    attempts: int = 0
    start: float = 0.0
    end: float = 0.0
    error: str = ""
    speculative: bool = False
    # streaming steps: chunks served from the chunk-granular cache vs
    # computed this run (whole-step CACHED means all chunks replayed)
    chunks_replayed: int = 0
    chunks_emitted: int = 0
    # content key the step's outputs were offered under — persisted so a
    # restarted engine can reconstruct the completion frontier from cache
    # hits (repro.core.faults.restore_frontier)
    cache_key: str = ""
    # compute-layer profile (LocalEngine profile_steps=True): compile_s /
    # execute_s split and device memory, folded into registry histograms
    # and span annotations by the gateway
    profile: Optional[Dict[str, float]] = None

    def duration(self) -> float:
        return max(0.0, self.end - self.start)


@dataclass
class WorkflowRun:
    workflow: WorkflowIR
    steps: Dict[str, StepRecord] = field(default_factory=dict)
    artifacts: Dict[str, Any] = field(default_factory=dict)
    status: str = "Pending"
    wall_time_s: float = 0.0
    submitted: float = field(default_factory=time.time)
    run_id: str = field(default_factory=lambda: uuid.uuid4().hex[:12])

    def succeeded(self) -> bool:
        return self.status == "Succeeded"

    def report(self):
        """Critical-path makespan breakdown for this run (a
        ``repro.core.obs.MakespanReport``). Requires the engine to have
        been observed — ``couler.observe(engine)`` — before the run."""
        ref = getattr(self, "_obs_collector", None)
        coll = ref() if ref is not None else None
        if coll is None:
            raise RuntimeError(
                "run was not traced: call couler.observe(engine) before "
                "submitting, then run.report()")
        rep = coll.report(self.run_id)
        if rep is None:
            raise RuntimeError(
                f"no span tree for run {self.run_id!r} (rotated out of "
                "the collector's LRU, or the run never finished)")
        return rep

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.steps.values():
            out[r.status.value] = out.get(r.status.value, 0) + 1
        return out

    # -- metadata persistence ("we persist workflow metadata into a
    #    database for automated management", App. B.B) -----------------
    def persist(self, db_dir: str = "out/workflow_db") -> Path:
        p = Path(db_dir)
        p.mkdir(parents=True, exist_ok=True)
        # the run_id suffix keeps two runs of the same workflow within one
        # second from overwriting each other — inevitable under concurrent
        # gateway submission
        f = p / f"{self.workflow.name}-{int(self.submitted)}-{self.run_id}.json"
        f.write_text(json.dumps({
            "workflow": self.workflow.name,
            "run_id": self.run_id,
            "status": self.status,
            "wall_time_s": self.wall_time_s,
            "steps": {k: {"status": r.status.value, "attempts": r.attempts,
                          "duration": r.duration(), "error": r.error,
                          "cache_key": r.cache_key}
                      for k, r in self.steps.items()},
        }, indent=1))
        return f


class Engine:
    name = "engine"

    def submit(self, wf: WorkflowIR, optimize: bool = True, **kw) -> WorkflowRun:
        raise NotImplementedError

    # -- static analysis ---------------------------------------------------
    def lint_context(self) -> Dict[str, Any]:
        """Capacity facts this engine contributes to the workflow linter
        (``repro.core.analysis``): e.g. ``clusters`` enables the CLR005
        fit check, ``max_inflight_steps`` the CLR006 streaming-depth
        check. The base engine knows nothing."""
        return {}

    def lint(self, wf: WorkflowIR, **overrides):
        """Lint ``wf`` with this engine's deployment context; returns a
        ``LintResult``. Submission paths run the same passes as a gate
        (``lint="error"|"warn"|"off"`` on ``submit``/``submit_async``)."""
        from repro.core.analysis import lint as _lint
        ctx = self.lint_context()
        ctx.update(overrides)
        return _lint(wf, **ctx)

    def resume(self, run: WorkflowRun, **kw) -> WorkflowRun:
        """Restart from failure: re-submit, skipping Succeeded/Skipped/Cached."""
        raise NotImplementedError

    async def submit_async(self, wf: WorkflowIR, optimize: bool = True,
                           tenant: str = "default", priority: int = 0, **kw):
        """Generic async adapter: run the blocking ``submit`` in a worker
        thread and return an ``AsyncWorkflowRun`` handle. Only the coarse
        ``WORKFLOW_ADMITTED`` / ``WORKFLOW_DONE`` events are emitted, and
        cancellation is not cooperative mid-run. Engines with a native
        async path (``LocalEngine``) override this with the gateway
        implementation, which adds per-step events, backpressure, and
        cooperative cancel."""
        from repro.core.gateway.events import EventType
        from repro.core.gateway.run import AsyncWorkflowRun
        handle = AsyncWorkflowRun(wf.name, tenant=tenant)
        handle._publish(EventType.WORKFLOW_ADMITTED)
        loop = asyncio.get_running_loop()
        # tenant maps onto the scheduler's user attribution (MultiCluster
        # quotas/fairness); engines accepting neither ignore the extras
        kw.setdefault("user", tenant)
        kw.setdefault("priority", priority)

        def work() -> None:
            try:
                run = self.submit(wf, optimize=optimize, **kw)
                handle.run = run
                handle._publish(EventType.WORKFLOW_DONE, status=run.status)
                handle._finish(run)
            except BaseException as e:  # noqa: BLE001
                handle._publish(EventType.WORKFLOW_DONE, status="Failed",
                                error=f"{type(e).__name__}: {e}")
                handle._fail(e)

        loop.run_in_executor(None, work)
        return handle


# The >20 abnormal cloud patterns the controller auto-retries (App. B.B).
TRANSIENT_ERROR_PATTERNS = [
    "ExceededQuotaErr", "TooManyRequestsErr", "EtcdTimeout", "APIServerBusy",
    "PodEvicted", "NodeNotReady", "ImagePullBackOff", "NetworkUnreachable",
    "ConnectionReset", "DNSFailure", "VolumeMountTimeout", "OOMKilledTransient",
    "LeaseLost", "WebhookTimeout", "SchedulerPreempted", "DiskPressure",
    "RegistryThrottled", "CertRotation", "TokenExpired", "IPAMExhausted",
    "ControllerRestart", "HeartbeatMissed",
]


class TransientError(RuntimeError):
    """An error matching a known-retryable abnormal pattern."""


def is_transient(err: BaseException) -> bool:
    if isinstance(err, TransientError):
        return True
    msg = str(err)
    return any(p in msg for p in TRANSIENT_ERROR_PATTERNS)
