"""Engine interface + run bookkeeping (paper §II.F, App. B.B).

Every backend consumes the same IR. ``WorkflowRun`` persists step statuses
so a failed workflow can be restarted from the failure point, skipping
steps whose status is Succeeded / Skipped / Cached (paper App. B.B).
"""
from __future__ import annotations

import enum
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.core.ir import WorkflowIR


class StepStatus(str, enum.Enum):
    PENDING = "Pending"
    RUNNING = "Running"
    SUCCEEDED = "Succeeded"
    FAILED = "Failed"
    SKIPPED = "Skipped"
    CACHED = "Cached"


@dataclass
class StepRecord:
    status: StepStatus = StepStatus.PENDING
    attempts: int = 0
    start: float = 0.0
    end: float = 0.0
    error: str = ""
    speculative: bool = False

    def duration(self) -> float:
        return max(0.0, self.end - self.start)


@dataclass
class WorkflowRun:
    workflow: WorkflowIR
    steps: Dict[str, StepRecord] = field(default_factory=dict)
    artifacts: Dict[str, Any] = field(default_factory=dict)
    status: str = "Pending"
    wall_time_s: float = 0.0
    submitted: float = field(default_factory=time.time)

    def succeeded(self) -> bool:
        return self.status == "Succeeded"

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.steps.values():
            out[r.status.value] = out.get(r.status.value, 0) + 1
        return out

    # -- metadata persistence ("we persist workflow metadata into a
    #    database for automated management", App. B.B) -----------------
    def persist(self, db_dir: str = "out/workflow_db") -> Path:
        p = Path(db_dir)
        p.mkdir(parents=True, exist_ok=True)
        f = p / f"{self.workflow.name}-{int(self.submitted)}.json"
        f.write_text(json.dumps({
            "workflow": self.workflow.name,
            "status": self.status,
            "wall_time_s": self.wall_time_s,
            "steps": {k: {"status": r.status.value, "attempts": r.attempts,
                          "duration": r.duration(), "error": r.error}
                      for k, r in self.steps.items()},
        }, indent=1))
        return f


class Engine:
    name = "engine"

    def submit(self, wf: WorkflowIR, optimize: bool = True, **kw) -> WorkflowRun:
        raise NotImplementedError

    def resume(self, run: WorkflowRun, **kw) -> WorkflowRun:
        """Restart from failure: re-submit, skipping Succeeded/Skipped/Cached."""
        raise NotImplementedError


# The >20 abnormal cloud patterns the controller auto-retries (App. B.B).
TRANSIENT_ERROR_PATTERNS = [
    "ExceededQuotaErr", "TooManyRequestsErr", "EtcdTimeout", "APIServerBusy",
    "PodEvicted", "NodeNotReady", "ImagePullBackOff", "NetworkUnreachable",
    "ConnectionReset", "DNSFailure", "VolumeMountTimeout", "OOMKilledTransient",
    "LeaseLost", "WebhookTimeout", "SchedulerPreempted", "DiskPressure",
    "RegistryThrottled", "CertRotation", "TokenExpired", "IPAMExhausted",
    "ControllerRestart", "HeartbeatMissed",
]


class TransientError(RuntimeError):
    """An error matching a known-retryable abnormal pattern."""


def is_transient(err: BaseException) -> bool:
    if isinstance(err, TransientError):
        return True
    msg = str(err)
    return any(p in msg for p in TRANSIENT_ERROR_PATTERNS)
