"""Local DAG executor — the reference COULER engine.

Implements the production behaviours of App. B:
  * topological scheduling with a worker pool (max parallelism, Eq. 1 goal)
  * automatic artifact caching (Algorithm 2) — steps whose outputs hit the
    cache are marked ``Cached`` and skipped; ``cache`` accepts the default
    single-tier ``CacheStore`` or a multi-tier ``TieredCacheStore``
    (``repro.core.cache``) — both expose the same offer/get surface
  * controller auto-retry with backoff on the known transient patterns
  * straggler mitigation: a speculative duplicate races any step exceeding
    ``straggler_factor x est_time_s`` when spare workers exist
  * big-workflow auto-split (Algorithm 3) before scheduling
  * restart-from-failure: ``resume(run)`` skips Succeeded/Skipped/Cached

Scheduling runs on the engine's ``WorkflowGateway``
(``repro.core.gateway``): one asyncio loop drives the push-based
completion callbacks for every in-flight workflow, sharing a single
worker pool, a single thread-safe cache store, and a backpressured
multi-tenant admission queue. ``submit``/``resume`` are thin sync facades
(enqueue + wait) over that path; ``submit_async`` exposes it natively as
an awaitable ``AsyncWorkflowRun`` with an event stream and cooperative
cancel. Call ``close()`` to stop the gateway loop, its background cache
promotion task, and the speculation executors.
"""
from __future__ import annotations

import asyncio
import concurrent.futures as cf
import hashlib
import itertools
import pickle
import threading
import time
from typing import Any, Dict, List, Optional

from repro.core.api import StepOutput
from repro.core.autosplit import Budget
from repro.core.caching import CacheStore, CoulerPolicy
from repro.core.engines.base import (Engine, StepRecord, StepStatus,
                                     WorkflowRun)
from repro.core.faults import (ChaosInjector, FaultPlan, FrontierStore,
                               RetryPolicy, WorkerLost, restore_frontier,
                               retry_after_transient)
from repro.core.gateway.channels import (StepContext, StreamBroken,
                                         StreamCancelled, StreamReader,
                                         StreamRewound)
from repro.core.ir import Job, WorkflowIR


def _hash_value(v: Any) -> str:
    try:
        b = pickle.dumps(v)
    except Exception:
        b = repr(v).encode()
    return hashlib.sha256(b).hexdigest()[:16]


def cache_key(job: Job, artifact_values: Dict[str, Any],
              stream_key: Optional[str] = None) -> str:
    """Content key for a step's outputs. For a chunk-wise consumer
    (``stream_key`` given) the streamed input's contribution is the
    *producer's* cache key instead of a hash of the (possibly not yet
    materialized) value — equal producer key implies equal chunk stream."""
    parts = [job.name, job.kind, job.image, ",".join(job.command)]
    if job.fn is not None and hasattr(job.fn, "__code__"):
        parts.append(hashlib.sha256(job.fn.__code__.co_code).hexdigest()[:12])
    for a in (job.args or ()):
        if isinstance(a, StepOutput):
            if stream_key is not None and a.artifact == job.stream_arg:
                parts.append(f"stream:{stream_key}")
            else:
                parts.append(_hash_value(artifact_values.get(a.artifact)))
        else:
            parts.append(repr(a))
    for k in sorted(job.kwargs or {}):
        parts.append(f"{k}={job.kwargs[k]!r}")
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:24]


class LocalEngine(Engine):
    name = "local"

    def __init__(self, max_workers: int = 8,
                 cache: Optional[CacheStore] = None,
                 budget: Optional[Budget] = None,
                 straggler_factor: float = 4.0,
                 retry_backoff_s: float = 0.02,
                 retry_backoff_max_s: float = 2.0,
                 enable_speculation: bool = True,
                 max_inflight_steps: Optional[int] = None,
                 max_inflight_workflows: Optional[int] = None,
                 promote_interval_s: float = 0.25,
                 admission=None,
                 check_events: bool = False,
                 fault_plan: Optional[FaultPlan] = None,
                 frontier: bool = False,
                 readmission=None,
                 telemetry_interval_s: float = 0.0,
                 anomaly=None,
                 slo=None,
                 telemetry_path=None,
                 profile_steps: bool = False):
        self.max_workers = max_workers
        # compute-layer profiling: jit compile-vs-execute split (AOT
        # lower/compile when the step fn supports it) recorded on
        # StepRecord.profile. Bypasses speculation — a profiled step is
        # measured, not raced.
        self.profile_steps = profile_steps
        self.cache = cache if cache is not None else CacheStore(
            capacity_bytes=1 << 30, policy=CoulerPolicy())
        self.budget = budget or Budget()
        self.straggler_factor = straggler_factor
        self.retry_backoff_s = retry_backoff_s
        # capped exponential backoff + decorrelated jitter (faults.retry);
        # the old inline 2**(attempt-1) formula was unbounded + jitterless
        self.retry_policy = RetryPolicy(base_s=retry_backoff_s,
                                        cap_s=retry_backoff_max_s)
        self.enable_speculation = enable_speculation
        # chaos injection: consulted at every step-attempt boundary (and
        # mid-step for checkpoint-wired jobs); None = no faults
        self.injector = ChaosInjector(fault_plan) if fault_plan else None
        # frontier checkpoint-resume: record per-step completion through
        # the artifact cache after each terminal step event so a fresh
        # engine sharing the cache can resume_from_frontier()
        self.frontier = FrontierStore(self.cache) if frontier else None
        # per-(workflow, step) straggler history: repeated stragglers get
        # their speculation budget shrunk so backups launch sooner
        self._straggler_counts: Dict[str, int] = {}
        # checkpoint sessions: one CheckpointManager per (run, step)
        self._ckpt_mgrs: Dict[tuple, Any] = {}
        self._ckpt_lock = threading.Lock()
        # free-list of persistent 2-worker speculation executors, reused
        # across step invocations instead of constructing one per step
        self._spec_pools: List[cf.ThreadPoolExecutor] = []
        self._spec_lock = threading.Lock()
        # asyncio submission gateway (lazily started on first submit)
        self._gateway = None
        self._gateway_lock = threading.Lock()
        self._gateway_opts = dict(max_inflight_steps=max_inflight_steps,
                                  max_inflight_workflows=max_inflight_workflows,
                                  promote_interval_s=promote_interval_s,
                                  admission=admission,
                                  check_events=check_events,
                                  readmission=readmission,
                                  telemetry_interval_s=telemetry_interval_s,
                                  anomaly=anomaly,
                                  slo=slo,
                                  telemetry_path=telemetry_path)

    # ------------------------------------------------------------------
    @property
    def gateway(self):
        """The engine's ``WorkflowGateway`` (created on first access)."""
        gw = self._gateway
        if gw is None:
            with self._gateway_lock:
                if self._gateway is None:
                    from repro.core.gateway import WorkflowGateway
                    self._gateway = WorkflowGateway(self,
                                                    **self._gateway_opts)
                gw = self._gateway
        return gw

    def lint_context(self):
        bound = self._gateway_opts["max_inflight_steps"] or \
            2 * self.max_workers
        return {"max_inflight_steps": bound}

    def submit(self, wf: WorkflowIR, optimize: bool = True,
               tenant: str = "default", priority: int = 0,
               lint: str = "error", **kw) -> WorkflowRun:
        """Sync facade: lint + enqueue on the gateway (blocking for queue
        space instead of shedding) and wait for the finished
        ``WorkflowRun``. Lint errors raise ``WorkflowLintError`` before
        anything is enqueued (``lint="warn"|"off"`` to opt out)."""
        handle = self.gateway.submit_nowait(wf, optimize=optimize,
                                            tenant=tenant, priority=priority,
                                            block=True, lint=lint)
        return handle.result()

    async def submit_async(self, wf: WorkflowIR, optimize: bool = True,
                           tenant: str = "default", priority: int = 0,
                           block: bool = False, lint: str = "error", **kw):
        """Native async path: admit ``wf`` into the gateway and return its
        ``AsyncWorkflowRun`` (await it, stream ``.events()``, or
        ``.cancel()``). Raises ``QueueFull`` when the tenant's admission
        queue is at capacity; ``block=True`` waits for space instead (the
        blocking offer parks on the queue's condition variable in a
        worker thread — no polling)."""
        from repro.core.gateway import QueueFull
        gw = self.gateway
        try:
            # fast path: space available, no executor hop
            return gw.submit_nowait(wf, optimize=optimize, tenant=tenant,
                                    priority=priority, lint=lint)
        except QueueFull:
            if not block:
                raise
        return await asyncio.get_running_loop().run_in_executor(
            None, lambda: gw.submit_nowait(wf, optimize=optimize,
                                           tenant=tenant, priority=priority,
                                           block=True, lint=lint))

    def resume(self, run: WorkflowRun, tenant: str = "default",
               **kw) -> WorkflowRun:
        """Restart from failure (App. B.B): steps already Succeeded, Skipped
        or Cached keep their artifacts; Failed/Pending steps re-run."""
        keep = {StepStatus.SUCCEEDED, StepStatus.SKIPPED, StepStatus.CACHED}
        for n, rec in run.steps.items():
            if rec.status not in keep:
                run.steps[n] = StepRecord()
        handle = self.gateway.submit_nowait(run.workflow, run=run,
                                            resume=True, tenant=tenant,
                                            block=True)
        return handle.result()

    def resume_from_frontier(self, wf: WorkflowIR, tenant: str = "default",
                             snapshot=None) -> WorkflowRun:
        """Crash recovery on a FRESH engine: reconstruct a run of ``wf``
        from the frontier snapshot persisted through the artifact cache
        (or an explicit ``snapshot`` — e.g. a ``WorkflowRun.persist``
        file loaded via ``faults.load_run_snapshot``) and resume it.
        Steps whose recorded cache keys still hit stay done (``Cached``,
        artifacts restored); everything else re-runs. Requires this
        engine's ``cache`` to be (or share a tier with) the one the
        crashed run wrote through."""
        if snapshot is None:
            store = self.frontier or FrontierStore(self.cache)
            snapshot = store.load(wf)
        wf.validate()
        run = restore_frontier(wf, snapshot, self.cache)
        handle = self.gateway.submit_nowait(wf, run=run, resume=True,
                                            tenant=tenant, block=True)
        return handle.result()

    def close(self) -> None:
        """Shut down the gateway loop (stopping the background cache
        promotion task cleanly) and the speculation executors."""
        gw = self._gateway
        if gw is not None:
            gw.stop()
        with self._spec_lock:
            pools, self._spec_pools = self._spec_pools, []
        for p in pools:
            p.shutdown(wait=False)

    # ------------------------------------------------------------------
    def _exec_step(self, job: Job, run: WorkflowRun,
                   ctx: Optional[StepContext] = None) -> StepStatus:
        if job.stream_output or job.stream_input:
            return self._exec_stream_step(job, run, ctx)
        rec = run.steps[job.name]
        rec.start = time.time()
        rec.status = StepStatus.RUNNING

        # condition (couler.when)
        if job.condition is not None and not job.condition.evaluate(run.artifacts):
            rec.status = StepStatus.SKIPPED
            rec.end = time.time()
            return rec.status

        # cache check (Algorithm 2 consumer side); non-cacheable steps skip
        # the key hash entirely (it is only ever used for get/offer)
        key = cache_key(job, run.artifacts) if job.cacheable else ""
        rec.cache_key = key             # persisted for frontier resume
        if job.cacheable:
            hit = self.cache.get(key)
            if hit is not None:
                for out in job.outputs:
                    run.artifacts[out] = hit.value
                rec.status = StepStatus.CACHED
                rec.end = time.time()
                return rec.status

        publish = ctx.publish if ctx is not None else None
        iterations = 0
        while True:                                   # exec_while loop
            value, dur = self._invoke_with_retry(job, run, rec, publish)
            iterations += 1
            if job.loop_condition is None:
                break
            for out in job.outputs:                   # loop cond reads output
                run.artifacts[out] = value
            if not job.loop_condition.evaluate(run.artifacts):
                break
            if iterations >= job.max_iterations:
                break

        for out in job.outputs:
            run.artifacts[out] = value
        # monitor feedback (App. B.B): measured duration refines the IR's
        # time estimate, which feeds Eq. 3's w_i on the next cache decision
        # (weights_version keys the scorer's memo, so bump it)
        job.est_time_s = 0.5 * job.est_time_s + 0.5 * dur
        run.workflow.note_weights_changed()
        if job.cacheable:
            self.cache.offer(key, value, compute_time_s=dur,
                             producer=job.name, workflow=run.workflow)
        rec.status = StepStatus.SUCCEEDED
        rec.end = time.time()
        return rec.status

    # -- streaming steps (couler.run_stream / couler.map_stream) --------
    #
    # A streaming step ALWAYS takes this path, gateway or not: its fn
    # returns a generator, and storing that raw generator as the artifact
    # (the non-streaming path would) is never right — without a channel
    # the chunks are simply materialized with no overlap.
    #
    # Chunk-granular caching: chunk i of a step with key K is offered as
    # "K#c{i}" and the chunk count as manifest "K#n". A later run replays
    # the longest cached prefix (chunks stream downstream immediately) and
    # recomputes only the tail by re-running the source and skipping the
    # first k items — valid because streams are deterministic: equal key
    # implies equal chunk sequence. All chunks cached => the step is
    # ``Cached`` without invoking its fn at all.
    def _exec_stream_step(self, job: Job, run: WorkflowRun,
                          ctx: Optional[StepContext]) -> StepStatus:
        rec = run.steps[job.name]
        rec.start = time.time()
        rec.status = StepStatus.RUNNING
        out_art = job.outputs[0] if job.outputs else None
        ch = ctx.channels.get(out_art) if (ctx and out_art) else None
        in_ch = (ctx.channels.get(job.stream_arg)
                 if (ctx and job.stream_input and job.stream_arg) else None)

        if job.condition is not None \
                and not job.condition.evaluate(run.artifacts):
            rec.status = StepStatus.SKIPPED
            rec.end = time.time()
            if ch is not None:
                ch.close(0)
            return rec.status

        key = ""
        if job.cacheable:
            if in_ch is not None:
                # the consumer's key substitutes the producer's key for the
                # streamed (unmaterialized) input; an uncacheable upstream
                # (empty source_key) cannot identify the stream => no key
                key = (cache_key(job, run.artifacts,
                                 stream_key=in_ch.source_key)
                       if in_ch.source_key else "")
            else:
                key = cache_key(job, run.artifacts)
        if ch is not None:
            ch.source_key = key
        rec.cache_key = key             # persisted for frontier resume

        publish = ctx.publish if ctx else None
        failures = 0
        t0 = time.time()
        try:
            while True:
                rec.attempts += 1
                try:
                    if self.injector is not None:
                        fault, _ = self.injector.begin_attempt(
                            run.workflow.name, job.name)
                        if fault is not None:
                            raise fault
                    chunks, fully_cached = self._stream_once(
                        job, run, rec, ch, in_ch, key, publish)
                    break
                except StreamRewound:
                    # upstream producer retried: restart (replaying our own
                    # cached prefix) without spending our retry budget
                    if ch is not None:
                        ch.rewind()
                    continue
                except StreamBroken as e:
                    rec.error = f"{type(e).__name__}: {e}"
                    rec.status = StepStatus.FAILED
                    rec.end = time.time()
                    if ch is not None:
                        ch.abort(e)
                    return rec.status
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    if retry_after_transient(
                            e, attempt=failures, retry_limit=job.retry_limit,
                            policy=self.retry_policy, step=job.name,
                            publish=publish):
                        # retried producer rewinds its channel: attached
                        # readers restart from chunk 0
                        if ch is not None:
                            ch.rewind()
                        continue
                    rec.error = f"{type(e).__name__}: {e}"
                    rec.status = StepStatus.FAILED
                    rec.end = time.time()
                    if ch is not None:
                        ch.abort(e)
                    raise
        except StreamCancelled:
            # cooperative cancel mid-stream: propagate so the gateway
            # reverts this step to Pending (the run stays resumable)
            raise

        dur = time.time() - t0
        if out_art is not None:
            run.artifacts[out_art] = chunks
        if fully_cached:
            rec.status = StepStatus.CACHED
            rec.end = time.time()
            return rec.status
        job.est_time_s = 0.5 * job.est_time_s + 0.5 * dur
        run.workflow.note_weights_changed()
        if key:
            # manifest last: its presence promises the full chunk run was
            # offered (individual chunks may still be evicted later — the
            # replay loop probes per chunk and recomputes the tail)
            self.cache.offer(f"{key}#n", len(chunks), compute_time_s=0.0,
                             producer=job.name, workflow=run.workflow)
        rec.status = StepStatus.SUCCEEDED
        rec.end = time.time()
        return rec.status

    def _stream_once(self, job: Job, run: WorkflowRun, rec: StepRecord,
                     ch, in_ch, key: str, publish):
        """One attempt at producing the full chunk sequence: replay the
        cached prefix, then compute the tail from the source (the fn's
        generator, or the upstream channel/materialized chunks for
        consumers). Returns (chunks, fully_cached)."""
        from repro.core.gateway.events import EventType
        rec.chunks_replayed = 0
        rec.chunks_emitted = 0
        chunks: List[Any] = []
        announced = [False]

        def emit(c: Any, replay: bool) -> None:
            if publish is not None and not announced[0]:
                announced[0] = True
                publish(EventType.STEP_STREAMING, step=job.name)
            if ch is not None:
                ch.put(c, replay=replay)   # blocks under backpressure
            chunks.append(c)
            if publish is not None:
                publish(EventType.STEP_CHUNK, step=job.name,
                        chunk=len(chunks) - 1)

        n_total: Optional[int] = None
        if key:
            m = self.cache.get(f"{key}#n")
            if m is not None:
                n_total = int(m.value)
            while n_total is None or len(chunks) < n_total:
                hit = self.cache.get(f"{key}#c{len(chunks)}")
                if hit is None:
                    break
                emit(hit.value, True)
                rec.chunks_replayed += 1
            if n_total is not None and len(chunks) >= n_total:
                if ch is not None:
                    ch.close(len(chunks))
                return chunks, True
        k = len(chunks)                    # cached prefix length

        reader: Optional[StreamReader] = None
        try:
            last = time.time()
            if job.stream_input:
                if in_ch is not None:
                    reader = in_ch.reader(job.name)
                    if k:
                        reader.seek(k)     # chunk j depends on input j only
                    indexed = enumerate(reader, start=k)
                else:
                    # producer already materialized (resume / other part /
                    # non-gateway execution): same chunks, no overlap
                    mat = run.artifacts.get(job.stream_arg)
                    it = iter(mat) if mat is not None else iter(())
                    indexed = enumerate(itertools.islice(it, k, None),
                                        start=k)
                per_chunk = self._stream_consumer_fn(job, run)
                for j, c_in in indexed:
                    c = per_chunk(c_in)
                    emit(c, False)
                    rec.chunks_emitted += 1
                    now = time.time()
                    if key:
                        self.cache.offer(f"{key}#c{j}", c,
                                         compute_time_s=now - last,
                                         producer=job.name,
                                         workflow=run.workflow)
                    last = now
            else:
                for j, c in enumerate(self._invoke_stream(job, run)):
                    if j < k:
                        continue           # deterministic prefix replayed
                    emit(c, False)
                    rec.chunks_emitted += 1
                    now = time.time()
                    if key:
                        self.cache.offer(f"{key}#c{j}", c,
                                         compute_time_s=now - last,
                                         producer=job.name,
                                         workflow=run.workflow)
                    last = now
        finally:
            if reader is not None:
                reader.close()
        if ch is not None:
            ch.close(len(chunks))
        return chunks, False

    def _stream_consumer_fn(self, job: Job, run: WorkflowRun):
        """Bind a chunk-wise consumer's non-stream args once; returns a
        callable chunk -> output chunk."""
        fn = job.fn
        if fn is None:
            return lambda c: c             # container placeholder: identity
        slots: List[Any] = []
        stream_idx = None
        for i, a in enumerate(job.args):
            if isinstance(a, StepOutput) and a.artifact == job.stream_arg \
                    and stream_idx is None:
                stream_idx = i
                slots.append(None)
            elif isinstance(a, StepOutput):
                slots.append(run.artifacts.get(a.artifact))
            else:
                slots.append(a)
        kwargs = job.kwargs

        if stream_idx is None:
            return lambda c: fn(c, *slots, **kwargs)

        def call(c: Any) -> Any:
            args = list(slots)
            args[stream_idx] = c
            return fn(*args, **kwargs)
        return call

    def _invoke_stream(self, job: Job, run: WorkflowRun):
        """Invoke a streaming producer's fn and return its chunk iterator.
        Speculation never applies here — racing a duplicate generator would
        double-emit chunks."""
        if job.fn is None:
            return iter([" ".join(job.command) or job.name])
        args = [run.artifacts.get(a.artifact) if isinstance(a, StepOutput)
                else a for a in job.args]
        res = job.fn(*args, **job.kwargs)
        return iter(res)

    def _invoke_with_retry(self, job: Job, run: WorkflowRun, rec: StepRecord,
                           publish=None):
        attempt = 0
        while True:
            attempt += 1
            rec.attempts = attempt
            t0 = time.time()
            try:
                mid_kill = None
                if self.injector is not None:
                    # chaos consult, one per attempt (the step boundary):
                    # crashes raise before the fn runs; worker loss runs
                    # the fn and loses the result with the slot — except
                    # for checkpoint-wired jobs, where the kill lands
                    # MID-STEP at an injector-chosen iteration instead
                    fault, kill_at = self.injector.begin_attempt(
                        run.workflow.name, job.name,
                        checkpointed=bool(job.checkpoint))
                    if fault is not None:
                        if kill_at is not None:
                            mid_kill = (fault, kill_at)
                        elif isinstance(fault, WorkerLost):
                            self._invoke(job, run)   # work done, result
                            raise fault              # died with the slot
                        else:
                            raise fault
                    # straggler injection (separate draw sequence): the
                    # delay lands inside the attempt, so rec.end-rec.start
                    # carries it and the telemetry straggler detector sees
                    # exactly what a slow worker would look like
                    d = self.injector.straggler_delay(
                        run.workflow.name, job.name)
                    if d > 0:
                        time.sleep(d)
                value = self._invoke(job, run, mid_kill=mid_kill)
                return value, time.time() - t0
            except Exception as e:  # noqa: BLE001
                if retry_after_transient(
                        e, attempt=attempt, retry_limit=job.retry_limit,
                        policy=self.retry_policy, step=job.name,
                        publish=publish):
                    continue
                rec.error = f"{type(e).__name__}: {e}"
                rec.status = StepStatus.FAILED
                rec.end = time.time()
                raise

    def _spec_pool_acquire(self) -> cf.ThreadPoolExecutor:
        with self._spec_lock:
            if self._spec_pools:
                return self._spec_pools.pop()
        return cf.ThreadPoolExecutor(max_workers=2,
                                     thread_name_prefix="speculation")

    def _spec_pool_release(self, pool: cf.ThreadPoolExecutor,
                           busy: bool) -> None:
        # A pool whose straggler is still running must NOT be reused (the
        # next occupant's backup would queue behind it) nor joined (the
        # backup already won); abandon it without waiting.
        if busy:
            pool.shutdown(wait=False)
            return
        with self._spec_lock:
            if len(self._spec_pools) < 2 * self.max_workers:
                self._spec_pools.append(pool)
                return
        pool.shutdown(wait=False)

    def _ckpt_session(self, job: Job, run: WorkflowRun, mid_kill):
        """Build the ``ckpt=`` session handed to a checkpoint-wired step.
        One ``CheckpointManager`` per (run, step) — shared across retry
        attempts AND re-admissions (same run_id), and rooted at the
        user-chosen directory so a fresh engine resumes from disk."""
        from repro.training.checkpoint import (CheckpointManager,
                                               StepCheckpointSession)
        mkey = (run.run_id, job.name)
        with self._ckpt_lock:
            mgr = self._ckpt_mgrs.get(mkey)
            if mgr is None:
                mgr = CheckpointManager(job.checkpoint)
                self._ckpt_mgrs[mkey] = mgr
        on_tick = None
        if mid_kill is not None:
            exc, kill_at = mid_kill

            def on_tick(it, _exc=exc, _at=kill_at):
                if it >= _at:
                    raise _exc
        return StepCheckpointSession(mgr, on_tick=on_tick)

    def _invoke(self, job: Job, run: WorkflowRun, mid_kill=None):
        if job.fn is None:
            return " ".join(job.command) or job.name   # container no-op
        args = [run.artifacts.get(a.artifact) if isinstance(a, StepOutput)
                else a for a in job.args]

        if job.checkpoint:
            # checkpoint-wired step: fn(..., ckpt=session) saves/restores
            # through training.checkpoint. No speculation — two racers
            # would share one checkpoint directory.
            kwargs = dict(job.kwargs)
            kwargs["ckpt"] = self._ckpt_session(job, run, mid_kill)
            return job.fn(*args, **kwargs)

        if self.profile_steps:
            return self._profiled_invoke(job, run, args)

        if not self.enable_speculation:
            return job.fn(*args, **job.kwargs)

        # straggler mitigation: race a speculative copy if the primary
        # exceeds straggler_factor x est_time_s. Executors come from a
        # persistent free-list (idle ones are reused across steps).
        spec_pool = self._spec_pool_acquire()
        futures: List[cf.Future] = []
        site = f"{run.workflow.name}/{job.name}"
        try:
            primary = spec_pool.submit(job.fn, *args, **job.kwargs)
            futures.append(primary)
            # repeated stragglers get speculation prioritized: each prior
            # straggler episode halves the patience before the backup
            budget_s = max(0.05, self.straggler_factor * job.est_time_s
                           / (1 + self._straggler_counts.get(site, 0)))
            try:
                return primary.result(timeout=budget_s)
            except cf.TimeoutError:
                # straggler observed (benign race on the counter: a lost
                # increment only delays the prioritization by one episode)
                self._straggler_counts[site] = \
                    self._straggler_counts.get(site, 0) + 1
                # the backup counts against the gateway's global
                # max_inflight_steps bound: reserve a slot (non-blocking) or
                # skip speculation — backups must not exceed the bound the
                # scheduled steps honour. Engines used without a gateway
                # have no bound to respect.
                gw = self._gateway
                if gw is not None and not gw.try_reserve_step_slot():
                    return primary.result()
                try:
                    backup = spec_pool.submit(job.fn, *args, **job.kwargs)
                except BaseException:
                    if gw is not None:
                        gw.release_step_slot()
                    raise
                if gw is not None:
                    # the slot stays held until the backup thread actually
                    # finishes, even when the primary wins the race
                    backup.add_done_callback(
                        lambda _f: gw.release_step_slot())
                futures.append(backup)
                done, _ = cf.wait([primary, backup],
                                  return_when=cf.FIRST_COMPLETED)
                f = done.pop()
                run.steps[job.name].speculative = True
                return f.result()
        finally:
            self._spec_pool_release(
                spec_pool, busy=any(not f.done() for f in futures))

    def _profiled_invoke(self, job: Job, run: WorkflowRun, args: List[Any]):
        """Invoke with compute-layer profiling (``profile_steps=True``):
        when the fn supports jax AOT (``fn.lower(...).compile()``) the
        compile and execute phases are timed separately; otherwise the
        plain call is timed whole. Only lower/compile failures fall back —
        an exception from the *compiled* call propagates (re-running via
        the plain path would double-execute user code). The profile lands
        on ``StepRecord.profile``; the gateway folds it into histograms
        and span annotations."""
        fn = job.fn
        prof: Dict[str, float] = {}
        compiled = None
        if hasattr(fn, "lower"):
            t0 = time.time()
            try:
                compiled = fn.lower(*args, **job.kwargs).compile()
                prof["compile_s"] = time.time() - t0
            except Exception:   # noqa: BLE001 — not AOT-able: plain call
                compiled = None
        if compiled is not None:
            t1 = time.time()
            value = compiled(*args, **job.kwargs)
            _block_until_ready(value)
            prof["execute_s"] = time.time() - t1
        else:
            t1 = time.time()
            value = fn(*args, **job.kwargs)
            _block_until_ready(value)
            prof["execute_s"] = time.time() - t1
        mem = _device_memory_bytes()
        if mem is not None:
            prof["device_bytes_in_use"] = float(mem)
        run.steps[job.name].profile = prof
        return value


def _block_until_ready(v: Any) -> None:
    """Force async jax dispatch to finish so execute_s measures real
    device time; a no-op for non-jax values."""
    if hasattr(v, "block_until_ready"):
        try:
            v.block_until_ready()
        except Exception:   # noqa: BLE001 — best-effort timing fence
            pass


def _device_memory_bytes() -> Optional[int]:
    """bytes_in_use of the first jax device, when the backend exposes
    memory_stats (CPU backends typically return None)."""
    try:
        import jax
        devs = jax.local_devices()
        if not devs:
            return None
        stats = devs[0].memory_stats()
        if stats:
            return stats.get("bytes_in_use")
    except Exception:   # noqa: BLE001 — profiling never fails a step
        return None
    return None
