"""Local threaded DAG executor — the reference COULER engine.

Implements the production behaviours of App. B:
  * topological scheduling with a worker pool (max parallelism, Eq. 1 goal)
  * automatic artifact caching (Algorithm 2) — steps whose outputs hit the
    cache are marked ``Cached`` and skipped
  * controller auto-retry with backoff on the known transient patterns
  * straggler mitigation: a speculative duplicate races any step exceeding
    ``straggler_factor x est_time_s`` when spare workers exist
  * big-workflow auto-split (Algorithm 3) before scheduling
  * restart-from-failure: ``resume(run)`` skips Succeeded/Skipped/Cached
"""
from __future__ import annotations

import concurrent.futures as cf
import hashlib
import pickle
import threading
import time
from typing import Any, Dict, List, Optional, Set

from repro.core.api import StepOutput
from repro.core.autosplit import Budget, split_workflow
from repro.core.caching import CacheStore, CoulerPolicy
from repro.core.engines.base import (Engine, StepRecord, StepStatus,
                                     TransientError, WorkflowRun,
                                     is_transient)
from repro.core.ir import Job, WorkflowIR


def _hash_value(v: Any) -> str:
    try:
        b = pickle.dumps(v)
    except Exception:
        b = repr(v).encode()
    return hashlib.sha256(b).hexdigest()[:16]


def cache_key(job: Job, artifact_values: Dict[str, Any]) -> str:
    parts = [job.name, job.kind, job.image, ",".join(job.command)]
    if job.fn is not None and hasattr(job.fn, "__code__"):
        parts.append(hashlib.sha256(job.fn.__code__.co_code).hexdigest()[:12])
    for a in (job.args or ()):
        if isinstance(a, StepOutput):
            parts.append(_hash_value(artifact_values.get(a.artifact)))
        else:
            parts.append(repr(a))
    for k in sorted(job.kwargs or {}):
        parts.append(f"{k}={job.kwargs[k]!r}")
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:24]


class LocalEngine(Engine):
    name = "local"

    def __init__(self, max_workers: int = 8,
                 cache: Optional[CacheStore] = None,
                 budget: Optional[Budget] = None,
                 straggler_factor: float = 4.0,
                 retry_backoff_s: float = 0.02,
                 enable_speculation: bool = True):
        self.max_workers = max_workers
        self.cache = cache if cache is not None else CacheStore(
            capacity_bytes=1 << 30, policy=CoulerPolicy())
        self.budget = budget or Budget()
        self.straggler_factor = straggler_factor
        self.retry_backoff_s = retry_backoff_s
        self.enable_speculation = enable_speculation

    # ------------------------------------------------------------------
    def submit(self, wf: WorkflowIR, optimize: bool = True, **kw) -> WorkflowRun:
        wf.validate()
        run = WorkflowRun(workflow=wf)
        for n in wf.jobs:
            run.steps[n] = StepRecord()
        if optimize:
            parts = split_workflow(wf, self.budget)
        else:
            parts = [wf]
        t0 = time.time()
        ok = True
        if len(parts) == 1:
            ok = self._run_part(parts[0], run)
        else:
            # maximum parallelism (Eq. 1): independent parts of a wave run
            # concurrently
            from repro.core.autosplit import schedule_parts
            waves = schedule_parts(wf, parts)
            for wave in waves:
                if not ok:
                    break
                if len(wave) == 1:
                    ok = self._run_part(parts[wave[0]], run)
                    continue
                with cf.ThreadPoolExecutor(max_workers=len(wave)) as wp:
                    futs = [wp.submit(self._run_part, parts[i], run)
                            for i in wave]
                    ok = all(f.result() for f in futs)
        run.wall_time_s = time.time() - t0
        run.status = "Succeeded" if ok else "Failed"
        run.persist()
        return run

    def resume(self, run: WorkflowRun, **kw) -> WorkflowRun:
        """Restart from failure (App. B.B): steps already Succeeded, Skipped
        or Cached keep their artifacts; Failed/Pending steps re-run."""
        wf = run.workflow
        keep = {StepStatus.SUCCEEDED, StepStatus.SKIPPED, StepStatus.CACHED}
        for n, rec in run.steps.items():
            if rec.status not in keep:
                run.steps[n] = StepRecord()
        t0 = time.time()
        ok = self._run_part(wf, run)
        run.wall_time_s += time.time() - t0
        run.status = "Succeeded" if ok else "Failed"
        run.persist()
        return run

    # ------------------------------------------------------------------
    def _run_part(self, wf: WorkflowIR, run: WorkflowRun) -> bool:
        self.cache.attach_workflow(run.workflow)
        done: Set[str] = {n for n, r in run.steps.items()
                          if n in wf.jobs and r.status in
                          (StepStatus.SUCCEEDED, StepStatus.SKIPPED,
                           StepStatus.CACHED)}
        failed = threading.Event()
        lock = threading.Lock()

        with cf.ThreadPoolExecutor(max_workers=self.max_workers) as pool:
            inflight: Dict[cf.Future, str] = {}

            def ready_jobs() -> List[str]:
                out = []
                for n in wf.jobs:
                    if n in done or n in inflight.values():
                        continue
                    if run.steps[n].status == StepStatus.RUNNING:
                        continue
                    preds = [p for p in run.workflow.predecessors(n)
                             if p in wf.jobs or p in run.steps]
                    if all(p in done or run.steps.get(
                            p, StepRecord()).status in
                            (StepStatus.SUCCEEDED, StepStatus.SKIPPED,
                             StepStatus.CACHED) for p in preds):
                        out.append(n)
                return out

            while len(done) < len(wf.jobs) and not failed.is_set():
                for n in ready_jobs():
                    fut = pool.submit(self._exec_step, wf.jobs[n], run)
                    inflight[fut] = n
                if not inflight:
                    break
                done_futs, _ = cf.wait(list(inflight),
                                       return_when=cf.FIRST_COMPLETED,
                                       timeout=10.0)
                for f in done_futs:
                    n = inflight.pop(f)
                    try:
                        status = f.result()
                    except Exception as e:  # noqa: BLE001
                        status = StepStatus.FAILED
                        run.steps[n].error = f"{type(e).__name__}: {e}"
                        run.steps[n].status = status
                    with lock:
                        if status == StepStatus.FAILED:
                            failed.set()
                        else:
                            done.add(n)
        return not failed.is_set()

    # ------------------------------------------------------------------
    def _exec_step(self, job: Job, run: WorkflowRun) -> StepStatus:
        rec = run.steps[job.name]
        rec.start = time.time()
        rec.status = StepStatus.RUNNING

        # condition (couler.when)
        if job.condition is not None and not job.condition.evaluate(run.artifacts):
            rec.status = StepStatus.SKIPPED
            rec.end = time.time()
            return rec.status

        # cache check (Algorithm 2 consumer side)
        key = cache_key(job, run.artifacts)
        if job.cacheable:
            hit = self.cache.get(key)
            if hit is not None:
                for out in job.outputs:
                    run.artifacts[out] = hit.value
                rec.status = StepStatus.CACHED
                rec.end = time.time()
                return rec.status

        iterations = 0
        while True:                                   # exec_while loop
            value, dur = self._invoke_with_retry(job, run, rec)
            iterations += 1
            if job.loop_condition is None:
                break
            for out in job.outputs:                   # loop cond reads output
                run.artifacts[out] = value
            if not job.loop_condition.evaluate(run.artifacts):
                break
            if iterations >= job.max_iterations:
                break

        for out in job.outputs:
            run.artifacts[out] = value
        # monitor feedback (App. B.B): measured duration refines the IR's
        # time estimate, which feeds Eq. 3's w_i on the next cache decision
        job.est_time_s = 0.5 * job.est_time_s + 0.5 * dur
        if job.cacheable:
            self.cache.offer(key, value, compute_time_s=dur,
                             producer=job.name)
        rec.status = StepStatus.SUCCEEDED
        rec.end = time.time()
        return rec.status

    def _invoke_with_retry(self, job: Job, run: WorkflowRun, rec: StepRecord):
        attempt = 0
        while True:
            attempt += 1
            rec.attempts = attempt
            t0 = time.time()
            try:
                value = self._invoke(job, run)
                return value, time.time() - t0
            except Exception as e:  # noqa: BLE001
                if is_transient(e) and attempt <= job.retry_limit:
                    time.sleep(self.retry_backoff_s * (2 ** (attempt - 1)))
                    continue
                rec.error = f"{type(e).__name__}: {e}"
                rec.status = StepStatus.FAILED
                rec.end = time.time()
                raise

    def _invoke(self, job: Job, run: WorkflowRun):
        if job.fn is None:
            return " ".join(job.command) or job.name   # container no-op
        args = [run.artifacts.get(a.artifact) if isinstance(a, StepOutput)
                else a for a in job.args]

        if not self.enable_speculation:
            return job.fn(*args, **job.kwargs)

        # straggler mitigation: race a speculative copy if the primary
        # exceeds straggler_factor x est_time_s. No context manager — we
        # must NOT join the straggler thread once the backup won.
        spec_pool = cf.ThreadPoolExecutor(max_workers=2)
        try:
            primary = spec_pool.submit(job.fn, *args, **job.kwargs)
            budget_s = max(0.05, self.straggler_factor * job.est_time_s)
            try:
                return primary.result(timeout=budget_s)
            except cf.TimeoutError:
                backup = spec_pool.submit(job.fn, *args, **job.kwargs)
                done, _ = cf.wait([primary, backup],
                                  return_when=cf.FIRST_COMPLETED)
                f = done.pop()
                run.steps[job.name].speculative = True
                return f.result()
        finally:
            spec_pool.shutdown(wait=False)
