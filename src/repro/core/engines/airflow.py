"""Apache Airflow backend: IR -> Airflow DAG python source (paper §II.F, §V).

Couler reports ~40-50% Airflow API coverage; this generator covers the DAG
structure, PythonOperator tasks, retries and trigger rules — the subset the
unified interface exercises.
"""
from __future__ import annotations

from typing import List

from repro.core.engines.base import Engine, StepRecord, StepStatus, WorkflowRun
from repro.core.ir import WorkflowIR


def to_airflow_dag(wf: WorkflowIR) -> str:
    wf.validate()
    lines: List[str] = [
        "from datetime import datetime",
        "from airflow import DAG",
        "from airflow.operators.python import PythonOperator",
        "",
        f"with DAG(dag_id={wf.name!r}, start_date=datetime(2024, 1, 1),",
        "         schedule=None, catchup=False) as dag:",
    ]
    ids = {}
    for name in wf.topo_order():
        job = wf.jobs[name]
        var = "t_" + name.replace("-", "_").replace(":", "_")
        ids[name] = var
        fn_name = getattr(job.fn, "__name__", "noop") if job.fn else "noop"
        lines.append(f"    {var} = PythonOperator(")
        lines.append(f"        task_id={name!r},")
        lines.append(f"        python_callable=lambda: {fn_name!r},")
        lines.append(f"        retries={job.retry_limit},")
        if job.condition is not None:
            lines.append("        trigger_rule='none_failed_min_one_success',")
        lines.append("    )")
    for s, d in sorted(wf.edges):
        lines.append(f"    {ids[s]} >> {ids[d]}")
    return "\n".join(lines) + "\n"


class AirflowSubmitter(Engine):
    name = "airflow"

    def submit(self, wf: WorkflowIR, optimize: bool = True, **kw) -> WorkflowRun:
        run = WorkflowRun(workflow=wf)
        run.artifacts["airflow:dag.py"] = to_airflow_dag(wf)
        for n in wf.jobs:
            run.steps[n] = StepRecord(status=StepStatus.PENDING)
        run.status = "Generated"
        return run
