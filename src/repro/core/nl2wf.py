"""NL -> unified programming interface (paper §III, Algorithm 1).

Step 1  Modular decomposition — a chain-of-thought pass segments the NL
        description into task modules classified against predefined task
        types (paper: "a series of predefined task types ... established to
        identify and extract pertinent tasks").
Step 2  Code generation — per subtask, retrieve reference code from the
        Code Lake and generate via the LLM interface.
Step 3  Self-calibration — LLM scores its own code; regenerate while
        s_i < S_b (bounded rounds; users may lower S_b, paper line 8 note).
Step 4  User feedback — optional callback revises the description and
        triggers regeneration.

``generated -> exec`` against ``repro.core.api`` builds a real WorkflowIR;
the pass@k benchmark grades structural properties of that IR.
"""
from __future__ import annotations

import re
import textwrap
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import api as couler_api
from repro.core.ir import WorkflowIR
from repro.core.llm import LLM, TemplateLLM

KNOWN_MODELS = ["resnet", "vit", "densenet", "lstm", "xgboost", "lightgbm",
                "bert", "gpt", "nanogpt", "cnn", "transformer", "mlp"]

TASK_TYPES = [
    ("load", ["load", "ingest", "read", "import", "fetch"]),
    ("preprocess", ["preprocess", "clean", "normalize", "tokenize",
                    "transform"]),
    ("augment", ["augment", "augmentation"]),
    ("split", ["split"]),
    ("train_multi", ["models", "each", "respectively", "candidates",
                     "compare"]),
    ("train", ["train", "fit", "fine-tune", "finetune", "fine tune"]),
    ("tune", ["hyperparameter", "tune", "search", "sweep"]),
    ("evaluate", ["evaluate", "validate", "validation", "metric", "assess"]),
    ("select", ["select", "choose", "best", "pick"]),
    ("deploy", ["deploy", "serve", "push", "release"]),
    ("report", ["report", "summary", "summarize"]),
    ("loop", ["until", "repeat", "repeatedly", "while"]),
    ("checkpoint", ["checkpoint", "save"]),
    ("concurrent", ["concurrently", "parallel", "same time"]),
]


def extract_entities(text: str) -> Dict[str, str]:
    t = text.lower()
    models = [m for m in KNOWN_MODELS if m in t]
    ents: Dict[str, str] = {}
    if models:
        ents["models"] = repr(models)
    m = re.search(r"(\d+)\s+(?:models|configurations|candidates|jobs|runs)", t)
    ents["count"] = m.group(1) if m else "3"
    m = re.search(r"dataset\s+(?:named\s+)?['\"]?([\w\-]+)", t)
    if m:
        ents["dataset"] = repr(m.group(1))
    for metric in ("accuracy", "f1", "auc", "loss", "perplexity"):
        if metric in t:
            ents["metric"] = repr(metric)
            break
    return ents


@dataclass
class Subtask:
    kind: str
    text: str


# canonical pipeline rank for the module spine ("predefined task types",
# paper §III step 1) — decomposition orders modules by ML-pipeline stage
_CANON = ["load", "preprocess", "augment", "split", "tune", "train_multi",
          "train", "loop", "concurrent", "evaluate", "select", "checkpoint",
          "deploy", "report"]


def decompose(description: str) -> List[Subtask]:
    """Step 1: chain-of-thought modular decomposition (rule-based CoT).

    Clauses are segmented aggressively (sentences, commas, connectives),
    classified against the predefined task types, de-duplicated by kind and
    re-ordered into the canonical pipeline spine."""
    many_models = len([m for m in KNOWN_MODELS
                       if m in description.lower()]) >= 2
    clauses = re.split(
        r"(?:[.;\n]|,|\b(?:then|and then|after that|next|finally)\b)",
        description)
    found: Dict[str, str] = {}
    for clause in clauses:
        c = clause.strip()
        if not c:
            continue
        cl = c.lower()
        for kind, kws in TASK_TYPES:
            if not any(k in cl for k in kws):
                continue
            if kind == "train_multi" and not many_models:
                continue
            if kind == "train":
                if many_models and ("each" in cl or "models" in cl
                                    or len([m for m in KNOWN_MODELS
                                            if m in cl]) >= 2):
                    kind = "train_multi"
            if kind not in found:
                found[kind] = c
            break
    if "train_multi" in found:
        found.pop("train", None)     # multi-model subsumes single train
    if "load" not in found:
        found["load"] = "load data from the dataset"
    if ("evaluate" not in found and ("select" in found
                                     or "train_multi" in found)):
        found["evaluate"] = "evaluate each trained model"
    if "preprocess" not in found and ("train" in found
                                      or "train_multi" in found):
        found["preprocess"] = "preprocess the raw data"
    return [Subtask(k, found[k]) for k in _CANON if k in found]


PRELUDE = textwrap.dedent("""\
    # auto-generated COULER workflow (NL -> unified interface)
    data = None; prep = None; trained = None; evals = []; best = None
""")


@dataclass
class GenerationResult:
    code: str
    subtask_codes: List[str]
    scores: List[float]
    rounds: List[int]
    tokens_used: int
    workflow: Optional[WorkflowIR] = None
    error: Optional[str] = None


def _assemble(subtask_codes: Sequence[str]) -> str:
    body = "".join(subtask_codes)
    # make sure identifiers exist even if a generation dropped a line
    return PRELUDE + body


def nl_to_workflow(description: str, llm: Optional[LLM] = None, *,
                   baseline_score: float = 0.55, max_rounds: int = 4,
                   temperature: float = 0.2, seed: int = 0,
                   feedback: Optional[Callable[[str, str], str]] = None,
                   execute: bool = True) -> GenerationResult:
    """Algorithm 1 end-to-end."""
    llm = llm or TemplateLLM("gpt-4")
    subtasks = decompose(description)                       # step 1
    codes, scores, rounds = [], [], []
    for i, st in enumerate(subtasks):
        prompt = (f"task: {st.kind}. {st.text}. "
                  f"||| context: {description[:300]}")
        best_code, best_score = "", -1.0
        r = 0
        for r in range(max_rounds):                         # steps 2-3
            code = llm.complete(prompt, temperature=temperature,
                                seed=seed * 131 + i * 17 + r)
            s = llm.score(prompt, code)
            if s > best_score:
                best_code, best_score = code, s
            if best_score >= baseline_score:
                break
        codes.append(best_code)
        scores.append(best_score)
        rounds.append(r + 1)

    code = _assemble(codes)
    if feedback is not None:                                # step 4
        revised = feedback(description, code)
        if revised and revised != description:
            return nl_to_workflow(revised, llm,
                                  baseline_score=baseline_score,
                                  max_rounds=max_rounds,
                                  temperature=temperature, seed=seed + 1,
                                  execute=execute)

    result = GenerationResult(code=code, subtask_codes=codes, scores=scores,
                              rounds=rounds,
                              tokens_used=getattr(llm, "tokens_used", 0))
    if execute:
        try:
            result.workflow = execute_generated(code)
        except Exception as e:  # noqa: BLE001
            result.error = f"{type(e).__name__}: {e}"
    return result


# ---------------------------------------------------------------------------
# execution sandbox for generated code
# ---------------------------------------------------------------------------

class _Steps:
    """Step zoo targeted by generated code (paper's 'step zoo')."""

    @staticmethod
    def load_data(dataset="data", **kw):
        return {"dataset": dataset, "rows": 1000}

    @staticmethod
    def preprocess(data=None, **kw):
        return {"prep": True}

    @staticmethod
    def augment(data=None, **kw):
        return {"aug": True}

    @staticmethod
    def split_data(data=None, **kw):
        return {"train": 0.8, "val": 0.2}

    @staticmethod
    def train_model(data=None, model="m", **kw):
        return {"model": str(model)}

    @staticmethod
    def finetune(data=None, model="m", **kw):
        return {"model": str(model), "finetuned": True}

    @staticmethod
    def evaluate(trained=None, metric="accuracy", **kw):
        return {"metric": metric, "value": 0.9}

    @staticmethod
    def select_best(*evals, **kw):
        return True

    @staticmethod
    def deploy(best=None, **kw):
        return "deployed"

    @staticmethod
    def report(best=None, **kw):
        return "report"

    @staticmethod
    def check(data=None, **kw):
        return True

    @staticmethod
    def save_checkpoint(trained=None, **kw):
        return "ckpt"

    @staticmethod
    def hp_grid(n=3):
        return [{"lr": 10 ** -(2 + i)} for i in range(int(n))]


def execute_generated(code: str, name: str = "generated") -> WorkflowIR:
    """Run generated COULER code in a sandbox; returns the built IR."""
    with couler_api.workflow(name) as ir:
        ns = {"couler": couler_api, "steps": _Steps}
        exec(compile(code, "<generated>", "exec"), ns)   # noqa: S102
    ir.validate()
    return ir
