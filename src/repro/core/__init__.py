"""COULER core: unified workflow interface, IR, and the paper's optimizers."""
from repro.core import api as couler
from repro.core.ir import Condition, Job, Resources, WorkflowIR
