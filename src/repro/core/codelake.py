"""Code Lake (paper §III step 2): a retrieval corpus of COULER snippets.

Each entry is (description, code template). Templates use {slot} holes
filled from entities extracted out of the NL subtask ("{models}", "{count}",
"{dataset}", "{metric}"). Generated programs exec against
``repro.core.api`` to build a real WorkflowIR — the pass@k grader runs them.
"""

SNIPPETS = [
    ("task: load. load data from the dataset into the pipeline ingest read input",
     "data = couler.run_step(steps.load_data, {dataset}, step_name='load-data')\n"),

    ("task: preprocess. preprocess clean transform normalize the raw data tokenize features",
     "prep = couler.run_step(steps.preprocess, data, step_name='preprocess')\n"),

    ("task: augment. augment the training data with transformations",
     "aug = couler.run_step(steps.augment, prep, step_name='augment')\n"),

    ("task: split. split the data into train and validation test sets",
     "splits = couler.run_step(steps.split_data, prep, step_name='split-data')\n"),

    ("task: train. train a single model on the training data fit",
     "trained = couler.run_step(steps.train_model, prep, {models}[0],"
     " step_name='train')\n"),

    ("task: train_multi. train each candidate model apply multiple models resnet vit densenet "
     "lstm xgboost lightgbm on the same training data",
     "trained = couler.map_(lambda m: couler.run_step(steps.train_model,"
     " prep, m, step_name='train-' + m), {models})\n"),

    ("task: evaluate. evaluate validate each trained model on the validation data compute "
     "metrics",
     "evals = couler.map_(lambda t: couler.run_step(steps.evaluate, t,"
     " {metric}, step_name='eval-' + t.job_name), trained)\n"),

    ("task: select. compare models and select choose the best one by metric",
     "best = couler.run_step(steps.select_best, *evals,"
     " step_name='select-best')\n"),

    ("task: deploy. deploy push the selected best model to serving if it passes the "
     "quality gate threshold",
     "couler.when(couler.equal(best, True),\n"
     "    lambda: couler.run_step(steps.deploy, best, step_name='deploy'))\n"),

    ("task: report. generate produce a prediction report summary of the results",
     "report = couler.run_step(steps.report, best, step_name='report')\n"),

    ("task: tune. tune hyperparameters search over learning rates batch sizes",
     "tuned = couler.map_(lambda h: couler.run_step(steps.train_model,"
     " prep, h, step_name='hp-' + str(h)), steps.hp_grid({count}))\n"),

    ("task: concurrent. run two training jobs concurrently in parallel xgboost lightgbm automl",
     "couler.concurrent([lambda: couler.run_step(steps.train_model, prep,"
     " {models}[0], step_name='train-a'),\n"
     "    lambda: couler.run_step(steps.train_model, prep, {models}[-1],"
     " step_name='train-b')])\n"),

    ("task: loop. retry keep flipping run repeatedly until the condition is met "
     "converges",
     "res = couler.run_step(steps.check, prep, step_name='check')\n"
     "couler.exec_while(couler.equal(res, False),"
     " lambda: couler.run_step(steps.check, prep, step_name='check'))\n"),

    ("task: checkpoint. checkpoint save the model weights to storage",
     "ckpt = couler.run_step(steps.save_checkpoint, trained,"
     " step_name='checkpoint')\n"),

    ("task: train finetune. fine tune finetune a pretrained language model on the corpus",
     "trained = couler.run_step(steps.finetune, prep, {models}[0],"
     " step_name='finetune')\n"),
]
