"""``TraceChecker`` — the executable spec of the gateway event invariants.

``repro.core.gateway`` documents the ordering invariants over each run's
``WorkflowEvent`` stream. This module encodes them as a linear-time
automaton: feed events in order through ``observe`` (O(1) amortized per
event) and any breach raises ``TraceViolation`` naming the invariant.

Usage:

* post-hoc — ``TraceChecker.check(events, wf=ir)`` replays a collected
  stream and runs the end-of-stream completeness checks;
* inline (sanitizer mode) — ``WorkflowGateway(check_events=True)``
  attaches a checker to every run's publish path, so the violating event
  raises at its source, with the publisher's stack.

Invariants (numbers match the gateway package docstring):

1. ``WORKFLOW_ADMITTED`` is first (seq 0) and precedes every ``STEP_*``.
2. Exactly one terminal ``WORKFLOW_DONE`` (status Succeeded / Failed /
   Cancelled), and nothing follows it.
3. Every step terminal event is preceded by its own ``STEP_STARTED``
   (at most one of each per stream); in a *Succeeded* run every started
   step also reached a terminal event. Cancel scoping: a step
   interrupted mid-stream by cancellation reverts to Pending with no
   terminal event, so the completeness half is skipped for runs that
   did not end ``Succeeded``.
4. ``STEP_STREAMING`` / ``STEP_CHUNK`` fall strictly between their own
   step's ``STEP_STARTED`` and terminal event; a chunk requires a prior
   ``STEP_STREAMING`` (each retry attempt re-announces before its first
   chunk).
5. Within an attempt chunk indices run 0,1,2,…; an index may only ever
   restart at 0 (a failure-triggered channel rewind), never skip.
6. A chunk-wise consumer's ``STEP_STARTED`` may precede its streaming
   producer's terminal event, but never the producer's
   ``STEP_STREAMING``. Needs workflow topology (``wf=``); checked
   leniently for producers with no events in this stream (already
   satisfied before a resume).
7. ``STEP_RETRY`` / ``WORKER_LOST`` fall strictly between their own
   step's ``STEP_STARTED`` and terminal event, and a step's
   ``STEP_RETRY`` attempt numbers strictly increase (within one
   admission epoch — see 8).
8. ``WORKFLOW_REQUEUED`` (a failed run re-entering admission after
   backoff) appears only after ``WORKFLOW_ADMITTED`` and before the
   terminal event, and opens a new *epoch*: per-step bookkeeping
   (started / streaming / terminal / chunks / retry attempts) resets, so
   re-executed steps legitimately re-announce ``STEP_STARTED``.
   ``CLUSTER_PREEMPTED`` is run-scope (the cluster simulator emits no
   step lifecycle) and may appear anywhere between admission and the
   terminal event.
9. ``ALERT`` (a streaming anomaly detector firing in-band) appears only
   between ``WORKFLOW_ADMITTED`` and the terminal event, and always
   names its detector in ``status``. Alerts are advisory: they affect
   no step bookkeeping and are collected on ``TraceChecker.alerts``.

``TraceViolation`` subclasses ``AssertionError`` so assertion-driven
harnesses (the sanity fuzzes) treat breaches like any failed check.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set

from repro.core.gateway.events import EventType, WorkflowEvent

_TERMINAL_STATUSES = ("Succeeded", "Failed", "Cancelled")


class TraceViolation(AssertionError):
    """One event stream broke a gateway invariant."""

    def __init__(self, invariant: int, message: str,
                 event: Optional[WorkflowEvent] = None):
        self.invariant = invariant
        self.event = event
        at = f" at {event}" if event is not None else ""
        super().__init__(f"invariant {invariant}: {message}{at}")


class TraceChecker:
    """Incremental automaton over one run's ordered event stream."""

    def __init__(self, wf=None):
        # consumer step -> its chunk-wise streaming producer step
        self._stream_producer: Dict[str, str] = {}
        if wf is not None:
            for job in wf.jobs.values():
                if job.stream_input and job.stream_arg:
                    p = job.stream_arg.split(":")[0]
                    pj = wf.jobs.get(p)
                    if pj is not None and pj.stream_output:
                        self._stream_producer[job.name] = p
        self.admitted = False
        self.terminal: Optional[WorkflowEvent] = None
        self.started: Set[str] = set()
        self.streaming: Set[str] = set()
        self.step_terminal: Set[str] = set()
        self.chunks: Dict[str, int] = {}
        self.retries: Dict[str, int] = {}   # step -> last STEP_RETRY attempt
        self.alerts: List[WorkflowEvent] = []   # in-band ALERT events seen
        self.epoch = 0                      # re-admissions observed
        self._last_seq: Optional[int] = None
        self.n_events = 0

    # ------------------------------------------------------------------
    def observe(self, ev: WorkflowEvent) -> WorkflowEvent:
        """Validate one event (raises ``TraceViolation``) and return it."""
        if ev.seq >= 0:
            if self._last_seq is None:
                if ev.seq != 0:
                    raise TraceViolation(1, "stream must start at seq 0",
                                         ev)
            elif ev.seq != self._last_seq + 1:
                raise TraceViolation(
                    2, f"seq not contiguous ({self._last_seq} -> "
                       f"{ev.seq})", ev)
            self._last_seq = ev.seq
        if self.terminal is not None:
            raise TraceViolation(2, "event after terminal WORKFLOW_DONE",
                                 ev)
        t = ev.type
        if t is EventType.WORKFLOW_ADMITTED:
            if self.n_events:
                raise TraceViolation(1, "WORKFLOW_ADMITTED is not the "
                                        "first event", ev)
            self.admitted = True
        elif t is EventType.WORKFLOW_DONE:
            if not self.admitted:
                raise TraceViolation(1, "WORKFLOW_DONE before "
                                        "WORKFLOW_ADMITTED", ev)
            if ev.status not in _TERMINAL_STATUSES:
                raise TraceViolation(
                    2, f"terminal status {ev.status!r} not in "
                       f"{_TERMINAL_STATUSES}", ev)
            self.terminal = ev
            if ev.status == "Succeeded":
                missing = sorted(self.started - self.step_terminal)
                if missing:
                    raise TraceViolation(
                        3, f"run Succeeded but started steps {missing} "
                           f"have no terminal step event", ev)
        elif t is EventType.WORKFLOW_REQUEUED:
            if not self.admitted:
                raise TraceViolation(8, "WORKFLOW_REQUEUED before "
                                        "WORKFLOW_ADMITTED", ev)
            # new admission epoch: the gateway reset unsatisfied steps to
            # Pending, so they may re-announce STEP_STARTED
            self.epoch += 1
            self.started.clear()
            self.streaming.clear()
            self.step_terminal.clear()
            self.chunks.clear()
            self.retries.clear()
        elif t is EventType.CLUSTER_PREEMPTED:
            if not self.admitted:
                raise TraceViolation(8, "CLUSTER_PREEMPTED before "
                                        "WORKFLOW_ADMITTED", ev)
        elif t is EventType.ALERT:
            if not self.admitted:
                raise TraceViolation(9, "ALERT before WORKFLOW_ADMITTED",
                                     ev)
            if not ev.status:
                raise TraceViolation(9, "ALERT without a detector name",
                                     ev)
            self.alerts.append(ev)
        elif ev.is_step_event:
            if not self.admitted:
                raise TraceViolation(1, f"{t.name} before "
                                        f"WORKFLOW_ADMITTED", ev)
            self._observe_step(ev)
        else:  # pragma: no cover - no other event types exist today
            raise TraceViolation(2, f"unknown event type {t!r}", ev)
        self.n_events += 1
        return ev

    def _observe_step(self, ev: WorkflowEvent) -> None:
        t, s = ev.type, ev.step
        if t is EventType.STEP_STARTED:
            if s in self.started:
                raise TraceViolation(3, f"duplicate STEP_STARTED for "
                                        f"{s!r}", ev)
            p = self._stream_producer.get(s)
            if (p is not None and p in self.started
                    and p not in self.streaming
                    and p not in self.step_terminal):
                raise TraceViolation(
                    6, f"chunk-wise consumer {s!r} started before its "
                       f"producer {p!r} announced STEP_STREAMING", ev)
            self.started.add(s)
        elif t is EventType.STEP_STREAMING:
            if s not in self.started:
                raise TraceViolation(4, f"STEP_STREAMING for {s!r} "
                                        f"before its STEP_STARTED", ev)
            if s in self.step_terminal:
                raise TraceViolation(4, f"STEP_STREAMING for {s!r} after "
                                        f"its terminal event", ev)
            self.streaming.add(s)
        elif t is EventType.STEP_CHUNK:
            if s not in self.streaming:
                raise TraceViolation(4, f"STEP_CHUNK for {s!r} before its "
                                        f"STEP_STREAMING", ev)
            if s in self.step_terminal:
                raise TraceViolation(4, f"STEP_CHUNK for {s!r} after its "
                                        f"terminal event", ev)
            prev = self.chunks.get(s, -1)
            if ev.chunk != prev + 1 and ev.chunk != 0:
                raise TraceViolation(
                    5, f"chunk index {ev.chunk} for {s!r} after {prev}: "
                       f"neither +1 nor a rewind restart at 0", ev)
            self.chunks[s] = ev.chunk
        elif t is EventType.STEP_RETRY:
            if s not in self.started:
                raise TraceViolation(7, f"STEP_RETRY for {s!r} before its "
                                        f"STEP_STARTED", ev)
            if s in self.step_terminal:
                raise TraceViolation(7, f"STEP_RETRY for {s!r} after its "
                                        f"terminal event", ev)
            prev = self.retries.get(s, 0)
            if ev.attempt <= prev:
                raise TraceViolation(
                    7, f"STEP_RETRY attempt {ev.attempt} for {s!r} not "
                       f"greater than previous attempt {prev}", ev)
            self.retries[s] = ev.attempt
        elif t is EventType.WORKER_LOST:
            if s not in self.started:
                raise TraceViolation(7, f"WORKER_LOST for {s!r} before its "
                                        f"STEP_STARTED", ev)
            if s in self.step_terminal:
                raise TraceViolation(7, f"WORKER_LOST for {s!r} after its "
                                        f"terminal event", ev)
        else:  # terminal step event
            if s not in self.started:
                raise TraceViolation(3, f"{t.name} for {s!r} before its "
                                        f"STEP_STARTED", ev)
            if s in self.step_terminal:
                raise TraceViolation(3, f"second terminal event for "
                                        f"{s!r}", ev)
            self.step_terminal.add(s)

    # ------------------------------------------------------------------
    def finish(self) -> "TraceChecker":
        """End-of-stream checks for a run believed complete."""
        if not self.admitted:
            raise TraceViolation(1, "no WORKFLOW_ADMITTED observed")
        if self.terminal is None:
            raise TraceViolation(2, "no terminal WORKFLOW_DONE observed")
        return self

    @classmethod
    def check(cls, events: Iterable[WorkflowEvent], wf=None
              ) -> "TraceChecker":
        """Replay a collected stream and run the completeness checks."""
        checker = cls(wf=wf)
        for ev in events:
            checker.observe(ev)
        return checker.finish()
