"""Typed diagnostics emitted by the workflow linter.

Every finding carries a stable ``CLR0xx`` code (the public contract —
tests, the CI lint gate and ``docs/diagnostics.md`` key on it), a
severity, the offending job and a one-line fix hint. ``LintResult``
aggregates one lint run; ``WorkflowLintError`` is what a submission-time
``lint="error"`` gate raises.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List


class Severity(str, Enum):
    ERROR = "error"       # rejects the workflow under lint="error"
    WARNING = "warning"   # recorded in wf.configs["lint_warnings"]
    INFO = "info"         # advisory only

    def __str__(self) -> str:  # noqa: D105
        return self.value


#: code -> (default severity, short meaning). The authoritative
#: code/severity/meaning/fix table lives in docs/diagnostics.md.
CODES: Dict[str, tuple] = {
    "CLR001": (Severity.ERROR, "dependency cycle"),
    "CLR002": (Severity.WARNING, "isolated step (no edges in or out)"),
    "CLR003": (Severity.ERROR, "condition on an artifact nothing produces"),
    "CLR004": (Severity.ERROR, "chunk-wise consumer with >1 streamed input"),
    "CLR005": (Severity.ERROR, "resource request fits no cluster"),
    "CLR006": (Severity.ERROR, "streaming pipeline deeper than the "
                               "in-flight step bound"),
    "CLR007": (Severity.WARNING, "nondeterministic source in a cacheable "
                                 "step"),
    "CLR008": (Severity.ERROR, "input artifact has no producing step"),
    "CLR009": (Severity.INFO, "chunk-wise consumer over a non-streamed "
                              "source"),
}


@dataclass(frozen=True)
class Diagnostic:
    code: str
    severity: Severity
    message: str
    job: str = ""          # offending step name; "" = whole-workflow
    fix: str = ""          # one-line fix hint

    def as_dict(self) -> Dict[str, Any]:
        return {"code": self.code, "severity": self.severity.value,
                "job": self.job, "message": self.message, "fix": self.fix}

    def __str__(self) -> str:
        where = f" [{self.job}]" if self.job else ""
        hint = f" (fix: {self.fix})" if self.fix else ""
        return f"{self.code} {self.severity}{where}: {self.message}{hint}"


@dataclass
class LintResult:
    """All diagnostics from one ``lint(wf)`` run."""
    workflow: str
    diagnostics: List[Diagnostic] = field(default_factory=list)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity is Severity.ERROR]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity is Severity.WARNING]

    @property
    def infos(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity is Severity.INFO]

    def codes(self) -> set:
        return {d.code for d in self.diagnostics}

    def ok(self) -> bool:
        return not self.errors

    def raise_on_error(self) -> "LintResult":
        if self.errors:
            raise WorkflowLintError(self)
        return self

    def summary(self) -> str:
        if not self.diagnostics:
            return f"{self.workflow}: clean"
        return f"{self.workflow}: " + "; ".join(str(d)
                                                for d in self.diagnostics)


class WorkflowLintError(ValueError):
    """Raised at submission time when lint="error" finds ERROR diagnostics.

    Carries the full ``LintResult`` as ``.result``.
    """

    def __init__(self, result: LintResult):
        self.result = result
        errs = "; ".join(str(d) for d in result.errors)
        super().__init__(
            f"workflow {result.workflow!r} rejected by lint "
            f"({len(result.errors)} error(s)): {errs} — "
            f"pass lint='warn' or lint='off' to submit anyway")
