"""Structural lint passes over ``WorkflowIR``.

Two traversals, each O(V+E), so the submission-time gate stays linear
and negligible against scheduling itself (``benchmarks/bench_analysis.py``
pins the <2% overhead claim):

* ``cycle_pass`` — one graph sweep (order-free Kahn) for CLR001;
* ``step_pass`` — one fused sweep over the jobs for every per-step
  concern (CLR002/003/004/005/007/008/009 plus the CLR006 streaming
  component check). The concerns are independent — they share a loop,
  not state — and each lives in its own labelled block below.

Every pass takes the workflow plus a ``LintContext`` of optional
capacity facts (clusters, in-flight step bound) and returns a list of
``Diagnostic``s.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.analysis.diagnostics import Diagnostic, Severity
from repro.core.analysis.ndet import nondeterminism_findings
from repro.core.ir import WorkflowIR


@dataclass
class LintContext:
    """Optional capacity facts an engine contributes to the lint run."""
    clusters: Optional[Sequence] = None          # engines.cluster.Cluster
    max_inflight_steps: Optional[int] = None     # gateway step-slot bound


def _producer(artifact: str) -> str:
    return artifact.split(":")[0]


def _find_cycle(wf: WorkflowIR) -> List[str]:
    """One offending cycle path (colored DFS); [] when acyclic."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color: Dict[str, int] = {n: WHITE for n in wf.jobs}
    for root in wf.jobs:
        if color[root] != WHITE:
            continue
        stack = [(root, iter(sorted(wf.successors(root))))]
        color[root] = GRAY
        path = [root]
        while stack:
            node, it = stack[-1]
            adv = next(it, None)
            if adv is None:
                color[node] = BLACK
                stack.pop()
                path.pop()
                continue
            if color[adv] == GRAY:                 # back edge: cycle found
                return path[path.index(adv):] + [adv]
            if color[adv] == WHITE:
                color[adv] = GRAY
                stack.append((adv, iter(sorted(wf.successors(adv)))))
                path.append(adv)
    return []


def cycle_pass(wf: WorkflowIR, ctx: LintContext) -> List[Diagnostic]:
    """CLR001 — dependency cycle (with the offending path). A cycle
    through streaming steps would additionally deadlock the bounded
    ``ArtifactChannel``s, so the message calls that out."""
    # Cheap acyclicity witnesses first; otherwise an order-free Kahn
    # sweep (cheaper than topo_order(): no determinism sort, no
    # defensive copies — this is the gate's hot path).
    if (not wf._has_back_edge            # all edges forward => acyclic
            or not wf.edges or wf._topo_cache is not None):
        return []
    preds, succs = wf._preds, wf._succs
    indeg = {n: len(preds[n]) for n in wf.jobs}
    ready = [n for n, k in indeg.items() if not k]
    seen = 0
    while ready:
        n = ready.pop()
        seen += 1
        for d in succs[n]:
            indeg[d] -= 1
            if not indeg[d]:
                ready.append(d)
    if seen == len(wf.jobs):
        return []
    path = _find_cycle(wf)
    streaming = any(wf.jobs[n].stream_output or wf.jobs[n].stream_input
                    for n in path)
    extra = ("; the cycle passes through streaming steps and would "
             "deadlock their bounded channels" if streaming else "")
    return [Diagnostic(
        code="CLR001", severity=Severity.ERROR, job=path[0] if path else "",
        message=f"dependency cycle: {' -> '.join(path)}{extra}",
        fix="remove one of the edges on the cycle")]


def step_pass(wf: WorkflowIR, ctx: LintContext) -> List[Diagnostic]:
    """All per-step concerns in one traversal:

    CLR002 (warning) — isolated steps (no edges at all) in a multi-step
    workflow; ``couler.concurrent`` builds these on purpose, but in
    hand-written DAGs they are usually a forgotten ``set_dependencies``
    or a misspelled step name.
    CLR003 — ``when``/``exec_while`` conditions referencing artifacts no
    step produces: the predicate could only ever see ``None``.
    CLR008 — declared inputs whose producing step is missing (e.g. a
    ``StepOutput`` smuggled in from another workflow context).
    CLR004 — a chunk-wise consumer fed more than one streamed input;
    only the ``stream_arg`` slot is chunk-wise, every other streamed
    input is silently materialized — overlap the author expects never
    happens.
    CLR009 (info) — ``map_stream`` over a source that is not streamed.
    CLR006 — a connected streaming component wider than
    ``max_inflight_steps``: all its steps must hold step slots
    simultaneously, so the pipeline deadlocks under that bound.
    CLR005 — a job requesting more cpu/mem/gpu than ANY cluster's total
    capacity can never be scheduled; today it silently pins its workflow
    in the queue forever.
    CLR007 (warning) — unseeded RNG / wall-clock / uuid inside a
    ``cacheable=True`` step fn: two runs produce different artifacts
    under the same cache key, so downstream consumers silently reuse a
    stale value (the chunk cache has no runtime detection for this).
    """
    out: List[Diagnostic] = []
    jobs = wf.jobs
    preds, succs = wf._preds, wf._succs
    multi = len(jobs) > 1
    clusters = ctx.clusters
    if clusters:
        # a request within every dimension's MINIMUM capacity fits every
        # cluster — one comparison chain accepts the common case, the
        # per-cluster joint-fit loop only runs for big requests
        env_cpu = min(c.cpu for c in clusters)
        env_mem = min(c.mem_bytes for c in clusters)
        env_gpu = min(c.gpu for c in clusters) + 1e-9
    comp: Dict[str, str] = {}          # union-find over stream edges

    def find(x: str) -> str:
        while comp.get(x, x) != x:
            comp[x] = comp.get(comp[x], comp[x])
            x = comp[x]
        return x

    def union(a: str, b: str) -> None:
        ra, rb = find(a), find(b)
        if ra != rb:
            comp.setdefault(ra, ra)
            comp[rb] = ra

    for n, job in jobs.items():
        # -- CLR002: orphan step ---------------------------------------
        if multi and not preds.get(n) and not succs.get(n):
            out.append(Diagnostic(
                code="CLR002", severity=Severity.WARNING, job=n,
                message=f"step {n!r} has no incoming or outgoing edges",
                fix="wire it into the DAG or drop it (intended for "
                    "couler.concurrent fan-outs)"))

        # -- CLR003: condition on an unproduced artifact ---------------
        if job.condition is not None or job.loop_condition is not None:
            for label, cond in (("condition", job.condition),
                                ("loop condition", job.loop_condition)):
                if cond is not None and _producer(cond.artifact) not in jobs:
                    out.append(Diagnostic(
                        code="CLR003", severity=Severity.ERROR, job=n,
                        message=f"{label} references artifact "
                                f"{cond.artifact!r} but no step named "
                                f"{_producer(cond.artifact)!r} produces it",
                        fix="add the producing step before the "
                            "conditional one, or drop the condition"))

        # -- CLR008: dangling declared input ---------------------------
        for art in job.inputs:
            if _producer(art) not in jobs:
                out.append(Diagnostic(
                    code="CLR008", severity=Severity.ERROR, job=n,
                    message=f"input artifact {art!r} has no producing "
                            f"step in this workflow",
                    fix=f"add a step named {_producer(art)!r} or remove "
                        f"the input"))

        # -- CLR004 / CLR009: streaming shape --------------------------
        if job.stream_input:
            streamed = [a for a in job.inputs
                        if _producer(a) in jobs
                        and jobs[_producer(a)].stream_output]
            if len(streamed) > 1:
                extras = [a for a in streamed if a != job.stream_arg]
                out.append(Diagnostic(
                    code="CLR004", severity=Severity.ERROR, job=n,
                    message=f"chunk-wise consumer {n!r} receives "
                            f"{len(streamed)} streamed inputs; only "
                            f"{job.stream_arg!r} is consumed chunk-wise — "
                            f"{', '.join(repr(a) for a in extras)} would "
                            f"be silently materialized whole",
                    fix="merge upstream streams into one producer, or "
                        "materialize the extra input through a plain "
                        "run_step stage"))
            if job.stream_arg:
                p = _producer(job.stream_arg)
                pj = jobs.get(p)
                if pj is not None and not pj.stream_output:
                    out.append(Diagnostic(
                        code="CLR009", severity=Severity.INFO, job=n,
                        message=f"chunk-wise consumer {n!r} maps over "
                                f"{job.stream_arg!r}, which is not "
                                f"streamed; chunks will be iterated from "
                                f"the materialized value with no overlap",
                        fix="produce the source with run_stream to "
                            "overlap the stages"))
                elif pj is not None:
                    union(p, n)

        # -- CLR005: fits no cluster -----------------------------------
        if clusters:
            r = job.resources
            if (r.cpu <= env_cpu and r.mem_bytes <= env_mem
                    and r.gpu <= env_gpu):
                pass                    # fits every cluster
            else:
                for c in clusters:
                    if (r.cpu <= c.cpu and r.mem_bytes <= c.mem_bytes
                            and r.gpu <= c.gpu + 1e-9):
                        break
                else:
                    caps = ", ".join(
                        f"{c.name}(cpu={c.cpu:g}, gpu={c.gpu:g})"
                        for c in clusters)
                    out.append(Diagnostic(
                        code="CLR005", severity=Severity.ERROR, job=n,
                        message=f"step {n!r} requests cpu={r.cpu:g} "
                                f"mem={r.mem_bytes} gpu={r.gpu:g}, "
                                f"exceeding every cluster's capacity: "
                                f"{caps}",
                        fix="shrink the request or add a cluster that "
                            "fits it"))

        # -- CLR007: nondeterministic cacheable step -------------------
        if job.cacheable and job.fn is not None:
            findings = nondeterminism_findings(job.fn)
            if findings:
                out.append(Diagnostic(
                    code="CLR007", severity=Severity.WARNING, job=n,
                    message=f"cacheable step {n!r} calls "
                            f"{', '.join(findings)} — nondeterministic "
                            f"output poisons the artifact cache",
                    fix="seed the RNG explicitly or mark the step "
                        "cacheable=False"))

    # -- CLR006: streaming component vs the in-flight bound ------------
    bound = ctx.max_inflight_steps
    if bound and comp:
        # component size = number of steps that must hold a slot at once
        sizes: Dict[str, int] = {}
        for n in list(comp):
            r = find(n)
            sizes[r] = sizes.get(r, 0) + 1
        for root, size in sizes.items():
            if size > bound:
                out.append(Diagnostic(
                    code="CLR006", severity=Severity.ERROR, job=root,
                    message=f"streaming pipeline of {size} chunk-wise "
                            f"connected steps needs {size} concurrent "
                            f"step slots but max_inflight_steps={bound}; "
                            f"the pipeline would deadlock",
                    fix=f"raise max_inflight_steps to >= {size} or break "
                        f"the pipeline into shorter stages"))
    return out


ALL_PASSES = (cycle_pass, step_pass)
