"""Workflow static analysis: pre-submission lint + executable event spec.

Two independent layers share this package:

* **Linter** — ``lint(wf)`` runs a pass pipeline over a ``WorkflowIR``
  and returns a ``LintResult`` of typed ``Diagnostic``s with stable
  ``CLR0xx`` codes (see ``docs/diagnostics.md`` for the full table).
  Structural passes catch dependency cycles, isolated steps, conditions
  on artifacts nothing produces, streaming misuse (chunk-wise fan-in,
  pipelines deeper than the in-flight step bound) and resource requests
  no cluster can ever satisfy; an AST pass flags nondeterministic
  (unseeded RNG / wall-clock / uuid) sources inside ``cacheable=True``
  step functions before they can poison the artifact cache. Engines run
  ``lint_gate`` at submission time: errors reject the workflow (opt out
  with ``lint="warn"`` or ``lint="off"``), warnings land in
  ``wf.configs["lint_warnings"]``.

* **Trace checker** — ``TraceChecker`` is the executable specification
  of the gateway's six event-ordering invariants
  (``repro.core.gateway``): a linear-time automaton consuming
  ``WorkflowEvent``s incrementally, either post-hoc
  (``TraceChecker.check(events, wf=...)``) or inline as a sanitizer
  (``WorkflowGateway(check_events=True)`` attaches one per run). A
  breach raises ``TraceViolation`` naming the invariant.
"""
from repro.core.analysis.diagnostics import (CODES, Diagnostic, LintResult,
                                             Severity, WorkflowLintError)
from repro.core.analysis.lint import lint, lint_gate
from repro.core.analysis.ndet import nondeterminism_findings
from repro.core.analysis.trace import TraceChecker, TraceViolation

__all__ = ["CODES", "Diagnostic", "LintResult", "Severity",
           "WorkflowLintError", "lint", "lint_gate",
           "nondeterminism_findings", "TraceChecker", "TraceViolation"]
