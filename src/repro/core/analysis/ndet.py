"""AST nondeterminism pass over step functions (feeds diagnostic CLR007).

A ``cacheable=True`` step whose fn draws from an unseeded RNG, the wall
clock, or uuid/urandom produces different artifacts on identical inputs —
exactly what the content-addressed cache (and the chunk-granular stream
cache) cannot detect at runtime. This pass inspects the *source* of the
step fn: it flags value-producing nondeterministic calls unless a seeding
call with an explicit argument (``random.seed(x)``,
``np.random.default_rng(x)``, ``jax.random.PRNGKey(x)``…) appears in the
same function.

The pass is deliberately conservative about what it cannot resolve:
methods on local variables (``rng.normal(...)``), lambdas whose source
does not parse standalone, and builtins without retrievable source are
all skipped — zero false positives beats completeness here. Results are
memoized per ``fn.__code__`` object, so linting thousands of workflows
that share step functions (the fleet-submission hot path) parses each
distinct function body exactly once.
"""
from __future__ import annotations

import ast
import inspect
import textwrap
from typing import Dict, Optional, Tuple

# value-producing wall-clock / uniqueness calls: nondeterministic no
# matter what was seeded (time.sleep is NOT here — it produces no value)
_CLOCK_SUFFIXES = ("time.time", "time.time_ns", "time.monotonic",
                   "time.monotonic_ns", "time.perf_counter",
                   "time.perf_counter_ns", "datetime.now",
                   "datetime.utcnow", "datetime.today")
_UNIQUE_SUFFIXES = ("uuid.uuid1", "uuid.uuid4", "os.urandom",
                    "secrets.token_bytes", "secrets.token_hex",
                    "secrets.token_urlsafe", "secrets.randbelow")

# calls that *seed* an RNG when given an explicit argument
_SEED_SUFFIXES = ("default_rng", "PRNGKey", "seed", "RandomState",
                  "Random")
# RNG constructors that are nondeterministic when called with NO argument
_RNG_CONSTRUCTORS = ("default_rng", "RandomState", "Random")

_memo: Dict[object, Tuple[str, ...]] = {}


def _dotted(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _is_rng_module_call(dotted: str) -> bool:
    """True for draws straight off a random *module* (random.random,
    np.random.rand, numpy.random.choice, …). ``jax.random`` is excluded:
    its functions are pure given an explicit key."""
    parts = dotted.split(".")
    if parts[0] == "jax":
        return False
    # "random" must appear as a module segment, not as the final call name
    # (rng.random() on a seeded generator is fine and unresolvable anyway)
    return "random" in parts[:-1] or (parts[0] == "random" and len(parts) > 1)


def _scan(tree: ast.AST) -> Tuple[str, ...]:
    findings = []
    seeded = False
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if not dotted:
            continue
        last = dotted.split(".")[-1]
        if last in _SEED_SUFFIXES and (node.args or node.keywords):
            seeded = True
            continue
        if any(dotted == s or dotted.endswith("." + s)
               for s in _CLOCK_SUFFIXES + _UNIQUE_SUFFIXES):
            findings.append((dotted, False))     # never excused by seeding
        elif _is_rng_module_call(dotted) or (last in _RNG_CONSTRUCTORS
                                             and not node.args
                                             and not node.keywords):
            findings.append((dotted, True))      # excused if fn seeds
    return tuple(f"{name}()" for name, excusable in findings
                 if not (excusable and seeded))


def nondeterminism_findings(fn) -> Tuple[str, ...]:
    """Nondeterministic call sites in ``fn``'s own source (non-transitive).

    Returns a tuple of call descriptions, empty when the function is
    clean or its source cannot be inspected.
    """
    code = getattr(fn, "__code__", None)
    if code is None:
        return ()
    hit = _memo.get(code)
    if hit is not None:
        return hit
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError, ValueError):
        _memo[code] = ()
        return ()
    out = _scan(tree)
    _memo[code] = out
    return out
