"""Lint orchestrator + the submission-time gate.

``lint(wf)`` is the user-facing entry (also exported as ``couler.lint``);
``lint_gate`` is what engines call on every fresh submission: it lints,
records warnings in the workflow's configs, and raises
``WorkflowLintError`` under the default ``lint="error"`` mode.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.analysis.diagnostics import LintResult, Severity
from repro.core.analysis.passes import ALL_PASSES, LintContext
from repro.core.ir import WorkflowIR

LINT_MODES = ("error", "warn", "off")


def lint(wf: WorkflowIR, *, engine=None,
         clusters: Optional[Sequence] = None,
         max_inflight_steps: Optional[int] = None) -> LintResult:
    """Run every analysis pass over ``wf`` and return the diagnostics.

    Capacity-dependent passes (CLR005 cluster fit, CLR006 streaming
    depth vs. the in-flight bound) only fire when the corresponding
    context is supplied — either explicitly or via ``engine``, whose
    ``lint_context()`` contributes what it knows about its deployment.
    """
    if engine is not None:
        ctx_kw = dict(engine.lint_context())
        if clusters is not None:
            ctx_kw["clusters"] = clusters
        if max_inflight_steps is not None:
            ctx_kw["max_inflight_steps"] = max_inflight_steps
        ctx = LintContext(**ctx_kw)
    else:
        ctx = LintContext(clusters=clusters,
                          max_inflight_steps=max_inflight_steps)
    res = LintResult(workflow=wf.name)
    diags = res.diagnostics
    for p in ALL_PASSES:
        found = p(wf, ctx)
        if found:
            diags.extend(found)
    return res


def lint_gate(wf: WorkflowIR, mode: str = "error",
              **context) -> Optional[LintResult]:
    """Submission-time gate. ``mode``:

    * ``"error"`` (default) — ERROR diagnostics raise
      ``WorkflowLintError``; warnings/infos are recorded in
      ``wf.configs["lint_warnings"]``.
    * ``"warn"`` — nothing raises; all diagnostics are recorded.
    * ``"off"`` — no analysis at all (returns None).
    """
    if mode == "off":
        return None
    if mode not in LINT_MODES:
        raise ValueError(f"lint mode must be one of {LINT_MODES}, "
                         f"got {mode!r}")
    res = lint(wf, **context)
    if res.diagnostics:
        non_err = [d.as_dict() for d in res.diagnostics
                   if d.severity is not Severity.ERROR]
        if non_err:
            wf.configs["lint_warnings"] = non_err
        if mode == "error":
            res.raise_on_error()
    return res
