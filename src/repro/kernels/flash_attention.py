"""Flash attention forward — Pallas TPU kernel.

Tiling: grid (BH, num_q_blocks, num_kv_blocks), kv innermost so the online
softmax statistics (m, l) and the output accumulator live in VMEM scratch
across kv steps. Block shapes default to (128, 128) — MXU-aligned (the
128x128 systolic array) and comfortably within the ~16MB/core VMEM:
q/k/v tiles at d<=256 use 3 * 128 * 256 * 4B ≈ 0.4MB plus a 128x256 fp32
accumulator. Causal masking is positional (block-level skipping is left to
the ops-level scheduler).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  num_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skipping: a kv block strictly above the diagonal
    # (k_min > q_max) contributes nothing — skip its two MXU matmuls
    # entirely (saves ~2x compute at long S; grid still visits the step,
    # only the body is predicated out)
    live = (qi * block_q + block_q - 1 >= ki * block_k) if causal else True

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, dv)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                           s.shape, 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                           s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_scr[...]
        m_blk = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """q,k,v: (BH, S, D) (v may have different last dim). Returns (BH,S,Dv).

    ``interpret=True`` executes on CPU for validation; on TPU pass False
    to lower through Mosaic.
    """
    BH, S, D = q.shape
    Dv = v.shape[-1]
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = D ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),     # m: running max
            pltpu.VMEM((block_q,), jnp.float32),     # l: running denom
            pltpu.VMEM((block_q, Dv), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)
