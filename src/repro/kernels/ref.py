"""Pure-jnp oracles for every kernel (the allclose ground truth).

Note the SSD oracle is the *sequential recurrence* — mathematically
independent from both the Pallas kernel and the chunked jnp formulation in
``repro.models.ssm``, so it validates both.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, *, causal: bool = True, scale=None):
    """q,k,v: (BH, S, D) -> (BH, S, Dv). Naive full-softmax attention."""
    S = q.shape[1]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)


def reference_ssd(x, dA, Bm, Cm):
    """Sequential SSD recurrence.

    x: (BH, S, P) inputs (already dt-scaled); dA: (BH, S) log-decays (<=0);
    Bm, Cm: (BH, S, N). Returns (y (BH,S,P), final_state (BH,N,P)).

        h_t = exp(dA_t) * h_{t-1} + B_t (x) x_t ;   y_t = C_t . h_t
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dat, bt, ct = inp
        h = h * jnp.exp(dat)[:, None, None] + jnp.einsum(
            "bn,bp->bnp", bt, xt)
        y = jnp.einsum("bn,bnp->bp", ct, h)
        return h, y

    h0 = jnp.zeros((BH, N, P), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2),
          dA.astype(jnp.float32).transpose(1, 0),
          Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), h


def reference_rmsnorm(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)
