"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid (BH, num_chunks), chunks innermost and sequential; the recurrent
(N, P) state lives in VMEM scratch across chunk steps (same persist-scratch
pattern as flash attention). Per chunk, the within-chunk quadratic term is
two MXU matmuls ((Q,N)@(N,Q) and (Q,Q)@(Q,P)) — the TPU-native SSD
formulation (DESIGN.md §3) — and the cross-chunk term is one (Q,N)@(N,P).

Block sizes: chunk Q=128/256 rows, state N<=256, head dim P<=128 keep the
working set (Q*N + Q*P + N*P + Q*Q fp32) well under 2MB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, state_scr, *,
                num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    da = da_ref[0].astype(jnp.float32)        # (Q,)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    cum = jnp.cumsum(da)                      # (Q,)
    # within-chunk decayed attention-like term
    seg = cum[:, None] - cum[None, :]         # l_t - l_s
    Q = x.shape[0]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    y = jax.lax.dot_general(cb * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)

    # contribution of previous chunks through the carried state
    state = state_scr[...]                    # (N, P)
    decay_in = jnp.exp(cum)                   # (Q,)
    y += jax.lax.dot_general(c * decay_in[:, None], state,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update: S <- S * exp(cum[-1]) + sum_s exp(cum[-1]-cum_s) B_s x_s
    decay_out = jnp.exp(cum[-1] - cum)        # (Q,)
    new_state = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        b * decay_out[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (N, P)
    state_scr[...] = new_state
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dA, Bm, Cm, *, chunk: int = 128, interpret: bool = True):
    """x: (BH, S, P); dA: (BH, S) log-decays; Bm/Cm: (BH, S, N).

    Returns y: (BH, S, P). Chunk must divide S.
    """
    BH, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, num_chunks=nc)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dA, Bm, Cm)
