"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (validation mode per task spec) and
False on TPU (Mosaic lowering). Model code calls these through
``attn_impl="flash"`` / ``ssd_impl="pallas"`` config switches.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=interpret)


def ssd_scan(x, dA, Bm, Cm, *, chunk: int = 128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd(x, dA, Bm, Cm, chunk=chunk, interpret=interpret)


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=interpret)
