"""Fused RMSNorm — Pallas TPU kernel.

Row-tiled: grid over row blocks, each step normalizes (block_rows, D) in
one VMEM-resident pass (read once, write once — the fusion avoids the
separate mean/var and scale passes XLA sometimes emits around mixed-dtype
residual streams).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = True):
    """x: (R, D); scale: (D,). Returns (R, D)."""
    R, D = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0, (R, block_rows)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, scale)
