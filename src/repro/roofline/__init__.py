from repro.roofline.analysis import (analyze_hlo, roofline_report,
                                     RooflineTerms)
