"""Roofline analysis from compiled SPMD HLO text.

``jax`` ``compiled.cost_analysis()`` counts ``while`` (= ``lax.scan``) bodies
ONCE — badly under-counting scanned-layer models (verified empirically; a
3-step scan reported 1 step of FLOPs). So we parse the optimized HLO module
ourselves, building the call graph (fusion/call/while/conditional) and
multiplying while-body costs by the trip count recovered from the loop
condition's comparison constant.

Per-device quantities (the SPMD module is the per-device program):
  flops            2 * prod(result dims) * prod(contract dims) per dot
  hbm bytes        sum of operand+result bytes of dots + collective traffic
                   (proxy; cost_analysis 'bytes accessed' is also reported)
  collective wire bytes, ring-model per participant:
     all-gather       result * (n-1)/n
     reduce-scatter   result * (n-1)
     all-reduce       result * 2(n-1)/n
     all-to-all       result * (n-1)/n
     collective-permute  result * 1

Roofline terms (v5e, per task spec): compute = flops/197e12,
memory = bytes/819e9, collective = wire_bytes/50e9.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+), body=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> float:
    """Bytes of a result type string (handles tuples)."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


def _group_size(line: str) -> int:
    m = _GROUPS_ITOA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_f32_bytes: float = 0.0     # portion of coll_bytes moving f32 data
    dot_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    # (callee, multiplier_kind): multiplier resolved later for while bodies
    calls: List[Tuple[str, object]] = dataclasses.field(default_factory=list)
    consts: List[int] = dataclasses.field(default_factory=list)
    directions: List[str] = dataclasses.field(default_factory=list)


def _parse_computations(hlo: str) -> Tuple[Dict[str, CompCost], Optional[str]]:
    comps: Dict[str, CompCost] = {}
    entry = None
    cur: Optional[CompCost] = None
    cur_name = None
    shapes: Dict[str, str] = {}

    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur_name = m.group(1)
                cur = CompCost()
                shapes = {}
                if line.startswith("ENTRY"):
                    entry = cur_name
            continue
        if line.startswith("}"):
            comps[cur_name] = cur
            cur = None
            continue

        for cm in _CONST_RE.finditer(line):
            cur.consts.append(int(cm.group(1)))
        dm = re.search(r"direction=(\w+)", line)
        if dm:
            cur.directions.append(dm.group(1))

        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        shapes[name] = rtype

        if op == "dot":
            # operands: first two %refs in rest
            refs = re.findall(r"%([\w.\-]+)", rest)
            lhs_t = shapes.get(refs[0], "") if refs else ""
            cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            contract = 1
            lhs_dims = _shape_dims(lhs_t)
            if cdims and cdims.group(1):
                for d in cdims.group(1).split(","):
                    i = int(d)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
            out_elems = 1
            for d in _shape_dims(rtype):
                out_elems *= d
            cur.flops += 2.0 * out_elems * contract
            opd_bytes = sum(_shape_bytes(shapes.get(r, "")) for r in refs[:2])
            cur.dot_bytes += _shape_bytes(rtype) + opd_bytes
        elif op == "convolution":
            out_elems = 1
            for d in _shape_dims(rtype):
                out_elems *= d
            refs = re.findall(r"%([\w.\-]+)", rest)
            k_elems = 1
            if len(refs) > 1:
                for d in _shape_dims(shapes.get(refs[1], "")):
                    k_elems *= d
            cur.flops += 2.0 * out_elems * k_elems
        else:
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                n = _group_size(line)
                b = _shape_bytes(rtype)
                if op.endswith("-start") and base in ("all-gather", "all-reduce",
                                                      "collective-permute"):
                    b /= 2.0  # (operand, result) alias tuple
                if base == "all-gather":
                    wire = b * (n - 1) / max(n, 1)
                elif base == "all-reduce":
                    wire = b * 2 * (n - 1) / max(n, 1)
                elif base == "reduce-scatter":
                    wire = b * (n - 1)
                elif base == "all-to-all":
                    wire = b * (n - 1) / max(n, 1)
                else:  # collective-permute
                    wire = b
                cur.coll_bytes += wire
                cur.coll_by_kind[base] = cur.coll_by_kind.get(base, 0.0) + wire
                if rtype.lstrip("(").startswith(("f32", "s32", "u32")):
                    cur.coll_f32_bytes += wire

        wm = _WHILE_RE.search(line)
        if op == "while" and wm:
            cur.calls.append((wm.group(2), ("while", wm.group(1))))
            continue
        cm = _CALLS_RE.search(line)
        if cm:
            cur.calls.append((cm.group(1), 1))
        tm = _TOAPPLY_RE.search(line)
        if tm:
            cur.calls.append((tm.group(1), 1))
        bm = _BRANCHES_RE.search(line)
        if bm:
            for b in re.findall(r"%([\w.\-]+)", bm.group(1)):
                cur.calls.append((b, ("branch", None)))
    return comps, entry


def _gather(comps, name, field, seen=None):
    seen = seen if seen is not None else set()
    if name in seen or name not in comps:
        return []
    seen.add(name)
    c = comps[name]
    vals = list(getattr(c, field))
    for callee, _ in c.calls:
        vals.extend(_gather(comps, callee, field, seen))
    return vals


def _trip_count(comps: Dict[str, CompCost], cond_name: str) -> int:
    """Trip count from the loop condition's comparison constant.

    lax.scan lowers to ``iter < N`` (trip N) or a count-down ``iter >= 0``
    starting at N-1 (trip N) — so for GE/GT conditions we add 1 to the max
    constant seen in the condition computation.
    """
    consts = _gather(comps, cond_name, "consts")
    if not consts:
        return 1
    trip = max(consts)
    dirs = _gather(comps, cond_name, "directions")
    if any(d in ("GE", "GT") for d in dirs):
        trip += 1
    return max(trip, 1)


def _roll_up(comps: Dict[str, CompCost], name: str, cache: Dict[str, Tuple],
             depth: int = 0):
    if name in cache:
        return cache[name]
    if depth > 64 or name not in comps:
        return (0.0, 0.0, 0.0, 0.0, {})
    c = comps[name]
    flops, coll, cf32, dotb = (c.flops, c.coll_bytes, c.coll_f32_bytes,
                               c.dot_bytes)
    by_kind = dict(c.coll_by_kind)
    branch_best = None
    for callee, mult in c.calls:
        f, cl, c32, db, bk = _roll_up(comps, callee, cache, depth + 1)
        if isinstance(mult, tuple) and mult[0] == "while":
            k = _trip_count(comps, mult[1])
            f, cl, c32, db = f * k, cl * k, c32 * k, db * k
            bk = {kk: vv * k for kk, vv in bk.items()}
        elif isinstance(mult, tuple) and mult[0] == "branch":
            # conservative: take the most expensive branch
            if branch_best is None or f > branch_best[0]:
                branch_best = (f, cl, c32, db, bk)
            continue
        flops += f
        coll += cl
        cf32 += c32
        dotb += db
        for kk, vv in bk.items():
            by_kind[kk] = by_kind.get(kk, 0.0) + vv
    if branch_best:
        flops += branch_best[0]
        coll += branch_best[1]
        cf32 += branch_best[2]
        dotb += branch_best[3]
        for kk, vv in branch_best[4].items():
            by_kind[kk] = by_kind.get(kk, 0.0) + vv
    cache[name] = (flops, coll, cf32, dotb, by_kind)
    return cache[name]


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device HLO flops (dots+convs, scans unrolled)
    coll_bytes: float            # per-device wire bytes
    coll_f32_bytes: float        # f32 portion (CPU float-normalization artifact)
    hbm_bytes: float             # per-device bytes proxy
    coll_by_kind: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    collective_s_bf16: float     # TPU-native estimate (f32 wires halved)
    dominant: str

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze_hlo(hlo_text: str, *, hbm_bytes_hint: Optional[float] = None
                ) -> RooflineTerms:
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    flops, coll, cf32, dotb, by_kind = _roll_up(comps, entry, {})
    hbm = hbm_bytes_hint if hbm_bytes_hint is not None else dotb
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll / ICI_BW
    # XLA:CPU float-normalization rewrites bf16 dots to f32 and hoists the
    # converts across collectives; XLA:TPU keeps bf16 wires. The adjusted
    # estimate halves the f32 portion (documented in EXPERIMENTS.md §Roofline).
    collective_s_bf16 = (coll - 0.5 * cf32) / ICI_BW
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda t: t[1])[0]
    return RooflineTerms(flops=flops, coll_bytes=coll, coll_f32_bytes=cf32,
                         hbm_bytes=hbm,
                         coll_by_kind=by_kind, compute_s=compute_s,
                         memory_s=memory_s, collective_s=collective_s,
                         collective_s_bf16=collective_s_bf16, dominant=dom)


def model_flops(cfg, shape, n_params_active: int) -> float:
    """6*N*D for train, 2*N*D for serve forward (D = tokens in the step)."""
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_params_active * tokens
    tokens = shape.global_batch            # decode: one token per sequence
    return 2.0 * n_params_active * tokens


def roofline_report(terms: RooflineTerms, cfg, shape, chips: int) -> Dict:
    counts = cfg.param_counts()
    mf = model_flops(cfg, shape, counts["active"])
    mf_per_chip = mf / chips
    return {
        "arch": cfg.name, "shape": shape.name, "chips": chips,
        "hlo_flops_per_chip": terms.flops,
        "coll_bytes_per_chip": terms.coll_bytes,
        "hbm_bytes_per_chip": terms.hbm_bytes,
        "coll_by_kind": terms.coll_by_kind,
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "collective_s_bf16adj": terms.collective_s_bf16,
        "dominant": terms.dominant,
        "model_flops_total": mf,
        "model_flops_per_chip": mf_per_chip,
        "useful_flops_ratio": (mf_per_chip / terms.flops) if terms.flops else 0.0,
        "roofline_bound_s": max(terms.compute_s, terms.memory_s,
                                terms.collective_s),
        "model_compute_s": mf_per_chip / PEAK_FLOPS,
        # fraction of ideal: ideal time = model flops at peak; achieved-bound
        # time = dominant term
        "roofline_fraction": (mf_per_chip / PEAK_FLOPS) /
                             max(terms.compute_s, terms.memory_s,
                                 terms.collective_s, 1e-30),
    }
