"""zamba2-1.2b [hybrid] — Mamba2 blocks + shared attention block. [arXiv:2411.15242; hf]

Zamba2 applies ONE weight-shared transformer block (attention+MLP) every
``shared_attn_interval`` Mamba2 blocks, with the block input being
concat(hidden, original_embedding) (2*d_model). LoRA-adapters on the shared
block are omitted (structural mechanism kept; see DESIGN.md §4).
"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,               # mamba2 blocks
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,                # shared block queries from concat(2*d_model)=4096
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,             # d_inner=4096 -> 64 ssd heads
    ssm_ngroups=1,
    ssm_chunk=256,
    shared_attn_interval=6,
    tie_embeddings=True,
    source="arXiv:2411.15242",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={})  # long_500k RUNS (hybrid)
