"""stablelm-1.6b [dense]. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100_352,
    source="hf:stabilityai/stablelm-2-1_6b",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

_SKIP = "pure full-attention arch: long_500k needs sub-quadratic attention (task spec)"
SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={"long_500k": _SKIP})
