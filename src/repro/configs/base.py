"""Config dataclasses for Couler-JAX.

Every assigned architecture is expressed as a ``ModelConfig`` (+ a
``TrainConfig`` for optimizer/remat policy).  Shapes (seq_len x global_batch
cells) are ``ShapeConfig``s shared across LM-family archs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention flavour
    attention: str = "gqa"          # gqa | mla | none
    rope_theta: float = 10_000.0
    prefix_lm: bool = False         # bidirectional prefix (vlm)

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0          # leading dense layers (deepseek)
    router_type: str = "softmax"    # softmax | sigmoid (deepseek v3)
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001

    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256

    # --- hybrid (zamba2) ---
    shared_attn_interval: int = 0   # apply the single shared attn block every k layers

    # --- encoder-decoder (whisper) ---
    num_enc_layers: int = 0
    enc_seq: int = 0                # stub frame count (post-conv)

    # --- vlm (paligemma) ---
    num_patches: int = 0            # stub patch-embedding count

    # misc
    norm_eps: float = 1e-5
    act: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = False
    mtp_depth: int = 0              # deepseek multi-token prediction heads
    pad_vocab_multiple: int = 256
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # source provenance (kept for DESIGN/EXPERIMENTS cross-reference)
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        if self.vocab_size == 0:
            return 0
        return _round_up(self.vocab_size, self.pad_vocab_multiple)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # parameter counting (analytic; used for MODEL_FLOPS and roofline)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        """Returns dict(total=..., active=...) parameter counts (analytic)."""
        D = self.d_model
        V = self.padded_vocab
        embed = V * D
        head = 0 if self.tie_embeddings else V * D

        def attn_params() -> int:
            if self.attention == "mla":
                p = 0
                if self.q_lora_rank:
                    p += D * self.q_lora_rank
                    p += self.q_lora_rank * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                else:
                    p += D * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                p += D * (self.kv_lora_rank + self.qk_rope_dim)
                p += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                p += self.num_heads * self.v_head_dim * D
                return p
            hd = self.head_dim
            return (D * self.num_heads * hd + 2 * D * self.num_kv_heads * hd
                    + self.num_heads * hd * D)

        def mlp_params(ff: int) -> int:
            mult = 3 if self.act == "swiglu" else 2
            return mult * D * ff

        def ssm_params() -> int:
            d_in = self.ssm_expand * D
            nheads = d_in // self.ssm_head_dim
            conv_dim = d_in + 2 * self.ssm_ngroups * self.ssm_state
            p = D * (2 * d_in + 2 * self.ssm_ngroups * self.ssm_state + nheads)  # in_proj
            p += conv_dim * self.ssm_conv                                        # conv1d
            p += nheads * 2                                                      # A_log, D
            p += d_in                                                             # gate norm
            p += d_in * D                                                         # out_proj
            return p

        total = embed + head
        active = embed + head
        if self.family == "ssm":
            per = ssm_params() + D
            total += self.num_layers * per
            active += self.num_layers * per
        elif self.family == "hybrid":
            per = ssm_params() + D
            total += self.num_layers * per
            active += self.num_layers * per
            # one shared attention block over concat(2D) input
            Dc = 2 * D
            hd = self.head_dim
            shared = (Dc * self.num_heads * hd + 2 * Dc * self.num_kv_heads * hd
                      + self.num_heads * hd * D + mlp_params(self.d_ff) + 2 * Dc)
            total += shared
            active += shared
        elif self.family == "moe":
            a = attn_params() + 2 * D
            total += self.num_layers * a
            active += self.num_layers * a
            n_moe = self.num_layers - self.first_k_dense
            total += self.first_k_dense * mlp_params(self.d_ff)
            active += self.first_k_dense * mlp_params(self.d_ff)
            per_exp = mlp_params(self.moe_d_ff)
            total += n_moe * (self.num_experts * per_exp
                              + self.num_shared_experts * per_exp
                              + D * self.num_experts)
            active += n_moe * (self.experts_per_token * per_exp
                               + self.num_shared_experts * per_exp
                               + D * self.num_experts)
            if self.mtp_depth:
                mtp = self.mtp_depth * (a + self.num_experts * per_exp * 0 + mlp_params(self.moe_d_ff) * self.experts_per_token + 2 * D * D)
                total += self.mtp_depth * (a + self.num_experts * per_exp + 2 * D * D)
                active += mtp
        elif self.family == "encdec":
            enc = attn_params() + mlp_params(self.d_ff) + 2 * D
            dec = 2 * attn_params() + mlp_params(self.d_ff) + 3 * D
            total += self.num_enc_layers * enc + self.num_layers * dec
            active += self.num_enc_layers * enc + self.num_layers * dec
        else:  # dense, vlm
            per = attn_params() + mlp_params(self.d_ff) + 2 * D
            total += self.num_layers * per
            active += self.num_layers * per
        return {"total": int(total), "active": int(active)}


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"        # adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    remat: str = "none"             # none | full | dots
    accum_steps: int = 1            # microbatch gradient accumulation
    grad_compression: str = "none"  # none | int8 (error-feedback DP compression)
    zero1: bool = False             # shard optimizer state over the data axis


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


@dataclass(frozen=True)
class ArchSpec:
    """An assigned architecture: model + train policy + shape applicability."""
    model: ModelConfig
    train: TrainConfig
    # shape-name -> None (runs) or reason string (skip)
    skips: dict = field(default_factory=dict)

    def applicable_shapes(self):
        return [s for s in LM_SHAPES if s.name not in self.skips]
