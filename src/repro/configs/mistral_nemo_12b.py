"""mistral-nemo-12b [dense] — 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    rope_theta=1_000_000.0,      # long-context rope base
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

_SKIP = "pure full-attention arch: long_500k needs sub-quadratic attention (task spec)"
SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={"long_500k": _SKIP})
