"""olmoe-1b-7b [moe] — 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,                  # (unused: every layer is MoE)
    moe_d_ff=1024,
    vocab_size=50_304,
    num_experts=64,
    experts_per_token=8,
    num_shared_experts=0,
    first_k_dense=0,
    router_type="softmax",
    source="arXiv:2409.02060",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

_SKIP = "pure full-attention arch: long_500k needs sub-quadratic attention (task spec)"
SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={"long_500k": _SKIP})
