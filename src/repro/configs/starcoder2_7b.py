"""starcoder2-7b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_432,
    vocab_size=49_152,
    act="gelu",                  # non-gated MLP
    source="arXiv:2402.19173",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

_SKIP = "pure full-attention arch: long_500k needs sub-quadratic attention (task spec)"
SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={"long_500k": _SKIP})
