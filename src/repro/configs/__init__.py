"""Architecture registry: ``get_arch(id)`` / ``reduced(cfg)`` / shape cells."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (ArchSpec, LM_SHAPES, ModelConfig, ShapeConfig,
                                SHAPES_BY_NAME, TrainConfig)

from repro.configs import (mamba2_370m, olmoe_1b_7b, deepseek_v3_671b,
                           paligemma_3b, starcoder2_7b, stablelm_1_6b,
                           mistral_nemo_12b, granite_3_8b, zamba2_1_2b,
                           whisper_large_v3)

ARCHS: Dict[str, ArchSpec] = {
    "mamba2-370m": mamba2_370m.SPEC,
    "olmoe-1b-7b": olmoe_1b_7b.SPEC,
    "deepseek-v3-671b": deepseek_v3_671b.SPEC,
    "paligemma-3b": paligemma_3b.SPEC,
    "starcoder2-7b": starcoder2_7b.SPEC,
    "stablelm-1.6b": stablelm_1_6b.SPEC,
    "mistral-nemo-12b": mistral_nemo_12b.SPEC,
    "granite-3-8b": granite_3_8b.SPEC,
    "zamba2-1.2b": zamba2_1_2b.SPEC,
    "whisper-large-v3": whisper_large_v3.SPEC,
}

ARCH_IDS: List[str] = list(ARCHS)


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    return ARCHS[arch_id]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (per task spec)."""
    kw = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=64,
        vocab_size=512,
        pad_vocab_multiple=16,
    )
    if cfg.attention != "none":
        kw.update(num_heads=4, head_dim=16,
                  num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads else 0)
        if cfg.num_kv_heads == 1:
            kw["num_kv_heads"] = 1
    if cfg.d_ff:
        kw["d_ff"] = 128
    if cfg.attention == "mla":
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_rope_dim=8,
                  qk_nope_dim=16, v_head_dim=16)
    if cfg.num_experts:
        kw.update(num_experts=8, experts_per_token=2, moe_d_ff=64,
                  first_k_dense=min(cfg.first_k_dense, 1),
                  mtp_depth=min(cfg.mtp_depth, 1))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)  # d_inner=128 -> 8 heads
    if cfg.shared_attn_interval:
        kw.update(shared_attn_interval=2, num_layers=4)
    if cfg.num_enc_layers:
        kw.update(num_enc_layers=2, enc_seq=16)
    if cfg.num_patches:
        kw.update(num_patches=8)
    return cfg.replace(**kw)
