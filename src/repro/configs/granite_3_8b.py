"""granite-3-8b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12_800,
    vocab_size=49_155,           # padded to 49408 (=256*193) for sharding
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

_SKIP = "pure full-attention arch: long_500k needs sub-quadratic attention (task spec)"
SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={"long_500k": _SKIP})
