"""whisper-large-v3 [audio] — enc-dec, conv frontend STUB. [arXiv:2212.04356; unverified]

Per task spec the modality frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (1500 frames post-conv, d_model). 32 encoder +
32 decoder layers. RoPE is used as the positional stand-in for whisper's
sinusoidal/learned embeddings (structural simplification, DESIGN.md §4).
Shape cells exercise the decoder at the assigned seq_len (beyond whisper's
real 448-token decoder, as specified).
"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,               # decoder layers
    num_enc_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51_866,
    enc_seq=1500,
    act="gelu",                  # non-gated
    tie_embeddings=True,
    source="arXiv:2212.04356",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

_SKIP = "enc-dec full attention; long_500k needs sub-quadratic attention (task spec)"
SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={"long_500k": _SKIP})
