"""The paper's own RQ2 workload models (§VI.A: "ViT and nanoGPT").

BONUS configs beyond the 10 assigned architectures — kept in a separate
registry so the 40-cell dry-run table is unchanged. nanoGPT is a dense
decoder (reuses the dense family verbatim); ViT is encoder-only (the vlm
family with prefix_len = everything, i.e. fully bidirectional, and a
classification readout in its workflow step).
"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

NANOGPT = ModelConfig(
    name="nanogpt-124m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=50_304,
    act="gelu",
    tie_embeddings=True,
    source="github:karpathy/nanoGPT (gpt2-124m shape)",
)

VIT_B16 = ModelConfig(
    name="vit-base-16",
    family="vlm",                 # patches frontend + transformer backbone
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=1024,              # class-token vocabulary (readout stub)
    num_patches=196,              # 224/16 squared
    prefix_lm=True,               # bidirectional over all patches
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2010.11929 (ViT-B/16 shape)",
)

TRAIN = TrainConfig(optimizer="adamw", remat="none", accum_steps=1)

BONUS_ARCHS = {
    "nanogpt-124m": ArchSpec(model=NANOGPT, train=TRAIN,
                             skips={"long_500k": "full attention"}),
    "vit-base-16": ArchSpec(model=VIT_B16, train=TRAIN,
                            skips={"long_500k": "encoder-only",
                                   "decode_32k": "encoder-only: no decode"}),
}
