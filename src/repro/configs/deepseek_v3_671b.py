"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP. [arXiv:2412.19437; hf]

Adam fp32 moments for 671B params would need ~5.4TB (21 GB/chip at 256 chips),
exceeding v5e 16GB HBM, so the assigned TrainConfig uses Adafactor (factored
second moment) + full remat + FSDPxTPxEP sharding. See EXPERIMENTS.md.
"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,            # MLA: latent cache, head count used for q/v
    head_dim=128,
    d_ff=18_432,                 # first_k_dense layers
    moe_d_ff=2048,
    vocab_size=129_280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    first_k_dense=3,
    router_type="sigmoid",
    mtp_depth=1,
    source="arXiv:2412.19437",
)

TRAIN = TrainConfig(optimizer="adafactor", remat="full", accum_steps=1)

_SKIP = "full-softmax attention (MLA compresses KV, not attention score cost); task spec: skip"
SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={"long_500k": _SKIP})
