"""paligemma-3b [vlm] — SigLIP + gemma backbone. [arXiv:2407.07726; hf]

The SigLIP vision tower is a STUB per task spec: ``input_specs()`` provides
256 precomputed patch embeddings; the transformer backbone (gemma-2B shape)
is real. Prefix-LM masking: image+prefix bidirectional, suffix causal.
"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,              # MQA
    head_dim=256,
    d_ff=16_384,
    vocab_size=257_216,
    prefix_lm=True,
    num_patches=256,
    act="geglu",
    tie_embeddings=True,
    source="arXiv:2407.07726",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

_SKIP = "pure full-attention arch: long_500k needs sub-quadratic attention (task spec)"
SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={"long_500k": _SKIP})
