"""mamba2-370m [ssm] — SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    d_ff=0,                      # attention-free, no MLP
    vocab_size=50_280,
    attention="none",
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,             # d_inner=2048 -> 32 ssd heads
    ssm_ngroups=1,
    ssm_chunk=256,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)

TRAIN = TrainConfig(optimizer="adamw", remat="full", accum_steps=1)

SPEC = ArchSpec(model=MODEL, train=TRAIN, skips={})  # long_500k RUNS (O(1)-state decode)
