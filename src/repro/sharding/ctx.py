"""Logical-axis sharding context (MaxText-style), with divisibility fallback.

Models annotate activations with *logical* axis names, e.g.
``shard(x, "batch", "seq", "embed")``. A ``use_mesh(mesh, rules)`` context
resolves logical names to physical mesh axes; outside a mesh context the
annotation is a no-op (so CPU smoke tests never see 512 fake devices).

Resolution drops a physical axis when (a) it is absent from the mesh or
(b) the dim size does not divide the axis size — this fallback is what lets
all 40 (arch x shape) dry-run cells share one rule set.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

Logical = Union[str, None, Tuple[str, ...]]


def _ctx():
    if not hasattr(_state, "mesh"):
        _state.mesh = None
        _state.rules = {}
        _state.strategy = "baseline"
    return _state


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Dict[str, Logical],
             strategy: str = "baseline"):
    st = _ctx()
    prev = (st.mesh, st.rules, getattr(st, "strategy", "baseline"))
    st.mesh, st.rules, st.strategy = mesh, dict(rules), strategy
    try:
        with mesh:
            yield
    finally:
        st.mesh, st.rules, st.strategy = prev


def axis_ctx() -> Tuple[Optional[Mesh], Dict[str, Logical]]:
    st = _ctx()
    return st.mesh, st.rules


def current_strategy() -> str:
    return getattr(_ctx(), "strategy", "baseline")


def mesh_axis_size(name: str) -> int:
    mesh, _ = axis_ctx()
    if mesh is None or name not in mesh.shape:
        return 1
    return mesh.shape[name]


def _resolve_one(logical: Optional[str], dim: int, mesh: Mesh,
                 rules: Dict[str, Logical], used: set):
    """Logical name -> physical axis entry for PartitionSpec, or None."""
    if logical is None:
        return None
    phys = rules.get(logical)
    if phys is None:
        return None
    if isinstance(phys, str):
        phys = (phys,)
    # keep only axes present in mesh, unused so far, whose product divides dim
    kept = []
    prod = 1
    for ax in phys:
        if ax not in mesh.shape or ax in used:
            continue
        if dim % (prod * mesh.shape[ax]) != 0:
            continue
        kept.append(ax)
        prod *= mesh.shape[ax]
    if not kept:
        return None
    for ax in kept:
        used.add(ax)
    return tuple(kept) if len(kept) > 1 else kept[0]


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    shape: Sequence[int],
                    mesh: Optional[Mesh] = None,
                    rules: Optional[Dict[str, Logical]] = None) -> P:
    if mesh is None or rules is None:
        m, r = axis_ctx()
        mesh = mesh or m
        rules = rules if rules is not None else r
    if mesh is None:
        return P()
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set = set()
    entries = [_resolve_one(lg, d, mesh, rules, used)
               for lg, d in zip(logical_axes, shape)]
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with a logical sharding constraint (no-op w/o mesh)."""
    mesh, rules = axis_ctx()
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
