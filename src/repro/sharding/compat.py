"""Version-tolerant ``shard_map`` / axis-introspection imports.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` (0.4.x) to the
top-level ``jax`` namespace (>= 0.5) and renamed the replication-check kwarg
``check_rep`` -> ``check_vma`` along the way. Import ``shard_map`` from here
and use either kwarg; the shim translates to whatever the installed jax
accepts. ``axis_size`` wraps ``jax.lax.axis_size`` (added ~0.5) with the
classic ``psum(1, axis)`` idiom for 0.4.x (psum of an unmapped constant is
folded to ``1 * axis_size`` at trace time, so the result stays concrete).
"""
from __future__ import annotations

import inspect

import jax

try:                                    # jax >= 0.5 exposes it top-level
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(*args, **kwargs):
    if _HAS_VMA:
        if "check_rep" in kwargs:
            kwargs["check_vma"] = kwargs.pop("check_rep")
    else:
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(*args, **kwargs)


def axis_size(axis_name: str) -> int:
    """Size of a mapped mesh axis, callable inside shard_map/pmap bodies."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return int(jax.lax.psum(1, axis_name))
