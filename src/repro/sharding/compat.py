"""Version-tolerant ``shard_map`` import.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` (0.4.x) to the
top-level ``jax`` namespace (>= 0.5) and renamed the replication-check kwarg
``check_rep`` -> ``check_vma`` along the way. Import ``shard_map`` from here
and use either kwarg; the shim translates to whatever the installed jax
accepts.
"""
from __future__ import annotations

import inspect

try:                                    # jax >= 0.5 exposes it top-level
    from jax import shard_map as _shard_map
except ImportError:                     # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_HAS_VMA = "check_vma" in inspect.signature(_shard_map).parameters


def shard_map(*args, **kwargs):
    if _HAS_VMA:
        if "check_rep" in kwargs:
            kwargs["check_vma"] = kwargs.pop("check_rep")
    else:
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(*args, **kwargs)
