from repro.sharding.ctx import (axis_ctx, logical_to_spec, mesh_axis_size,
                                 shard, use_mesh)
from repro.sharding.rules import (param_logical_axes, param_specs,
                                  batch_specs, DEFAULT_RULES)
