"""Path-based logical-axis assignment for parameter / cache / batch pytrees.

Each leaf of a pytree gets a tuple of *logical* axis names from its path and
rank; ``ctx.logical_to_spec`` then resolves those to a PartitionSpec under
the active mesh with divisibility fallback. One rule set drives all 40
(arch x shape) dry-run cells.

Rule sets:
  DEFAULT_RULES      TP/EP over ``model``, DP over ``pod``+``data``; params
                     replicated over ``data`` (small/medium archs).
  FSDP_RULES         additionally shards the d_model/lora dims of weights
                     over ``data`` (ZeRO-3-style) — used for >=7B archs where
                     replicated params + optimizer state exceed v5e HBM.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import tree_flatten_with_path, tree_unflatten

from repro.sharding.ctx import logical_to_spec

DEFAULT_RULES: Dict[str, object] = {
    "batch": ("pod", "data"),
    "seq_q": "model",          # blockwise-attention query rows
    "kv_seq": "model",         # split-KV decode fallback
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "lora": None,
    "embed": None,
    "tp": "model",
}

FSDP_RULES = dict(DEFAULT_RULES, embed="data", lora="data")

# ---- beyond-paper parallelism strategies (§Perf hillclimb) ----------------
# pure data parallelism over every mesh axis; params replicated, optimizer
# state ZeRO-1 sharded — optimal for small models where TP psums dominate
DP_ZERO1_RULES: Dict[str, object] = {
    "batch": ("pod", "data", "model"),
    "zero1": ("data", "model"),
    "seq_q": None, "kv_seq": ("data", "model"),
    "heads": None, "kv_heads": None, "mlp": None, "vocab": None,
    "expert": None, "ssm_inner": None, "ssm_heads": None,
    "lora": None, "embed": None, "tp": None,
}

# pure FSDP / ZeRO-3: batch over all axes, every weight's leading non-stack
# dim sharded over all axes (bf16 all-gather per use instead of f32
# activation all-reduces)
PURE_FSDP_RULES: Dict[str, object] = dict(
    DP_ZERO1_RULES, fsdp2=("data", "model"))

# archs whose params + optimizer state exceed v5e HBM when only TP-sharded
FSDP_ARCHS = {"deepseek-v3-671b", "mistral-nemo-12b", "granite-3-8b",
              "starcoder2-7b"}


def rules_for(arch_name: str, strategy: str = "baseline") -> Dict[str, object]:
    if strategy == "dp_zero1":
        return DP_ZERO1_RULES
    if strategy == "pure_fsdp":
        return PURE_FSDP_RULES
    if strategy in ("baseline", "moe_a2a", "moe_a2a_seqshard", "moe_rs"):
        return FSDP_RULES if arch_name in FSDP_ARCHS else DEFAULT_RULES
    raise ValueError(strategy)


# ---------------------------------------------------------------------------
# parameter logical axes
# ---------------------------------------------------------------------------

_PARAM_TABLE: Dict[str, Tuple[Optional[str], ...]] = {
    "table": ("vocab", "embed"),
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    "wo": ("heads", "embed"),
    "wq_a": ("embed", "lora"),
    "wq_b": ("lora", "heads"),
    "wkv_a": ("embed", "lora"),
    "wkv_b": ("lora", "heads"),
    "router": ("embed", "expert"),
    "in_z": ("embed", "ssm_inner"),
    "in_x": ("embed", "ssm_inner"),
    "in_B": ("embed", None),
    "in_C": ("embed", None),
    "in_dt": ("embed", "ssm_heads"),
    "dt_bias": ("ssm_heads",),
    "A_log": ("ssm_heads",),
    "D_skip": ("ssm_heads",),
    "conv_x": (None, "ssm_inner"),
    "conv_B": (None, None),
    "conv_C": (None, None),
    "out": ("ssm_inner", "embed"),
    "proj": ("embed", "tp"),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(str(e.idx))
    return tuple(names)


def param_logical_axes(path, shape) -> Tuple[Optional[str], ...]:
    names = _path_names(path)
    name = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""

    if name == "w" and parent == "lm_head":
        base: Tuple[Optional[str], ...] = ("embed", "vocab")
    elif name in ("scale", "bias"):
        base = ("ssm_inner",) if parent == "gate_norm" else (None,)
    elif name in ("gate", "up") and parent == "experts":
        base = ("expert", "embed", "mlp")
    elif name == "down" and parent == "experts":
        base = ("expert", "mlp", "embed")
    elif name in ("gate", "up"):
        base = ("embed", "mlp")
    elif name == "down":
        base = ("mlp", "embed")
    elif name in _PARAM_TABLE:
        base = _PARAM_TABLE[name]
    else:
        base = (None,) * len(shape)

    if len(base) > len(shape):          # e.g. 1D leaf matched 2D base
        base = base[-len(shape):]
    pad = len(shape) - len(base)        # leading layer/group stack dims
    return (None,) * pad + tuple(base)


# ---------------------------------------------------------------------------
# cache logical axes (decode-state pytrees)
# ---------------------------------------------------------------------------

_CACHE_TABLE: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("batch", "kv_heads", "kv_seq", None),
    "v": ("batch", "kv_heads", "kv_seq", None),
    "c_kv": ("batch", "kv_seq", None),
    "k_rope": ("batch", "kv_seq", None),
    "state": ("batch", "ssm_heads", None, None),
    "conv_x": ("batch", None, "ssm_inner"),
    "conv_B": ("batch", None, None),
    "conv_C": ("batch", None, None),
}


def cache_logical_axes(path, shape) -> Tuple[Optional[str], ...]:
    names = _path_names(path)
    name = names[-1] if names else ""
    base = _CACHE_TABLE.get(name, (None,) * len(shape))
    if len(base) > len(shape):
        base = base[-len(shape):]
    pad = len(shape) - len(base)
    return (None,) * pad + tuple(base)


# ---------------------------------------------------------------------------
# batch logical axes
# ---------------------------------------------------------------------------

_BATCH_TABLE: Dict[str, Tuple[Optional[str], ...]] = {
    "tokens": ("batch", None),
    "targets": ("batch", None),
    "token": ("batch", None),
    "patches": ("batch", None, None),
    "frames": ("batch", None, None),
    "index": (),
}


def batch_logical_axes(path, shape) -> Tuple[Optional[str], ...]:
    names = _path_names(path)
    name = names[-1] if names else ""
    base = _BATCH_TABLE.get(name, (None,) * len(shape))
    return tuple(base)[: len(shape)] + (None,) * max(0, len(shape) - len(base))


# ---------------------------------------------------------------------------
# tree -> spec tree
# ---------------------------------------------------------------------------

def _specs(tree, axes_fn, mesh, rules):
    leaves, treedef = tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        shape = getattr(leaf, "shape", ())
        axes = axes_fn(path, shape)
        out.append(logical_to_spec(axes, shape, mesh, rules))
    return tree_unflatten(treedef, out)


def _head_aware(axes_fn, cfg, mesh):
    """Attention-weight fallback when head counts don't divide TP.

    Sharding the fused (H*hd) dim when H % TP != 0 makes the later
    (B,S,H,hd) reshape cut across shard boundaries — XLA inserts per-layer
    all-gathers of full activations. Instead we shard those weights on the
    CONTRACTING dim ("tp" = row-parallel), which keeps FLOPs sharded at the
    cost of one psum per projection (measured in §Perf).
    """
    if cfg is None or mesh is None or "model" not in mesh.shape:
        return axes_fn
    tp = mesh.shape["model"]
    q_bad = cfg.num_heads and cfg.num_heads % tp != 0
    kv_bad = cfg.num_kv_heads and cfg.num_kv_heads % tp != 0

    def fn(path, shape):
        axes = axes_fn(path, shape)
        names = _path_names(path)
        name = names[-1] if names else ""
        if name in ("wq", "wo") and q_bad and cfg.attention != "mla":
            base = ("tp", None) if name == "wq" else ("tp", None)
            pad = len(shape) - len(base)
            return (None,) * pad + base
        if name in ("wk", "wv") and kv_bad:
            pad = len(shape) - 2
            return (None,) * pad + ("tp", None)
        return axes
    return fn


def _largest_dim_axes(name_for_dim: str):
    """Strategy wrapper: shard each leaf's LARGEST dim (most likely to be
    256-divisible and memory-dominant) over the strategy axes."""
    def fn(path, shape):
        if len(shape) == 0:
            return ()
        i = max(range(len(shape)), key=lambda j: shape[j])
        return tuple(name_for_dim if j == i else None
                     for j in range(len(shape)))
    return fn


def param_specs(tree, mesh=None, rules=None, cfg=None,
                strategy: str = "baseline"):
    if strategy == "pure_fsdp":
        return _specs(tree, _largest_dim_axes("fsdp2"), mesh, rules)
    if strategy == "dp_zero1":
        return _specs(tree, lambda p, s: (None,) * len(s), mesh, rules)
    return _specs(tree, _head_aware(param_logical_axes, cfg, mesh), mesh, rules)


def cache_specs(tree, mesh=None, rules=None):
    return _specs(tree, cache_logical_axes, mesh, rules)


def batch_specs(tree, mesh=None, rules=None):
    return _specs(tree, batch_logical_axes, mesh, rules)


def opt_state_specs(opt_shapes, mesh=None, rules=None, cfg=None,
                    strategy: str = "baseline"):
    """Optimizer-state tree: moments reuse the param axes of their subpath;
    Adafactor factored rows/cols drop the reduced dim's axis. Under
    dp_zero1, moments shard their largest dim over the 'zero1' axes (the
    partitioner then emits the ZeRO-1 grad reduce-scatter + param
    all-gather pattern)."""
    if strategy == "pure_fsdp":
        base_axes = _largest_dim_axes("fsdp2")
    elif strategy == "dp_zero1":
        base_axes = _largest_dim_axes("zero1")
    else:
        base_axes = _head_aware(param_logical_axes, cfg, mesh)

    def axes_fn(path, shape):
        names = _path_names(path)
        # find the optimizer-slot marker and strip everything up to it
        for i, n in enumerate(names):
            if n in ("mu", "nu", "v", "vr", "vc", "err"):
                slot = n
                sub = names[i + 1:]
                break
        else:
            return (None,) * len(shape)
        # reconstruct a pseudo-path of the param leaf
        class _K:  # minimal DictKey stand-in
            def __init__(self, k):
                self.key = k
        ppath = tuple(_K(n) for n in sub)
        if slot in ("mu", "nu", "v", "err"):
            return base_axes(ppath, shape)
        # factored: vr drops last dim, vc drops second-to-last
        if slot == "vr":
            return base_axes(ppath, tuple(shape) + (1,))[:-1]
        full = base_axes(ppath, tuple(shape[:-1]) + (1, shape[-1]))
        return full[:-2] + (full[-1],)
    return _specs(opt_shapes, axes_fn, mesh, rules)


def to_named(tree_of_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
