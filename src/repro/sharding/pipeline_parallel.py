"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Stages hold disjoint layer blocks (stacked params, leading ``stage`` dim);
microbatches stream through via ``ppermute`` in the classic (M + S - 1)-tick
schedule. Backward works through autodiff (ppermute transposes to the
reverse permute), giving GPipe semantics (full activation stash; combine
with remat for the memory-optimal variant).

This is the PP building block required "as appropriate" at scale —
the assigned production meshes use DP x TP (+EP/SP); PP composes on a
(stage, data, model) mesh for cross-pod layer sharding where ICI is scarce.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from repro.sharding.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, mesh: Mesh, *, stage_axis: str = "stage",
                   num_microbatches: int):
    """Returns f(stage_params, x) -> y running the pipeline.

    stage_params: pytree with leading [num_stages] dim on every leaf.
    x: (num_microbatches, mb, ...) input microbatches.
    stage_fn(params_slice, mb_input) -> mb_output (same shape as input).
    """
    S = mesh.shape[stage_axis]
    M = num_microbatches

    def local(params, x):
        # params: leaves sliced to this stage: leading dim 1 -> squeeze
        params = jax.tree.map(lambda p: p[0], params)
        sid = jax.lax.axis_index(stage_axis)
        x = x[0]                                   # (M, mb, ...) local copy
        mb_shape = x.shape[1:]
        buf = jnp.zeros(mb_shape, x.dtype)         # current carried activation
        outs = jnp.zeros_like(x)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range); others use recv'd buf
            mb_idx = jnp.clip(t, 0, M - 1)
            inp = jnp.where(sid == 0,
                            x[mb_idx],
                            buf)
            out = stage_fn(params, inp)
            # last stage records its finished microbatch (t - (S-1))
            done_idx = t - (S - 1)
            record = jnp.logical_and(sid == S - 1, done_idx >= 0)
            outs = jax.lax.cond(
                record,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.maximum(done_idx, 0), 0),
                lambda o: o, outs)
            # shift activations to the next stage
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf = jax.lax.ppermute(out, stage_axis, perm)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, M + S - 1, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast to all stages
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), stage_axis)
        return outs[None]

    pspec = P(stage_axis)

    def run(stage_params, x):
        in_specs = (jax.tree.map(lambda _: pspec, stage_params),
                    P(stage_axis))
        y = shard_map(local, mesh=mesh,
                      in_specs=in_specs, out_specs=P(stage_axis),
                      check_vma=False)(
            stage_params,
            jnp.broadcast_to(x[None], (S,) + x.shape))
        return y[0]
    return run
