"""Batched serving engine: cache-backed prefill + greedy/temperature decode.

Wraps the per-family decode paths (KV cache for attention families,
O(1) recurrent state for SSM/hybrid) behind one request-batch API. The
``serve_step`` this engine jits is the same function the ``decode_32k`` /
``long_500k`` dry-run cells lower at production scale.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T


@dataclass
class GenerationResult:
    tokens: list
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class ServingEngine:
    def __init__(self, cfg, params, *, max_len: int = 512,
                 cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self._step = jax.jit(
            lambda p, t, c, i: T.apply_lm_decode(p, cfg, t, c, i))

    def generate(self, prompts: jax.Array, gen_len: int,
                 temperature: float = 0.0, seed: int = 0) -> GenerationResult:
        """prompts: (B, P) int32 token batch -> greedy/temp decode."""
        B, P = prompts.shape
        assert P + gen_len <= self.max_len
        caches = T.init_caches(self.cfg, B, self.max_len, self.cache_dtype)
        key = jax.random.PRNGKey(seed)

        t0 = time.time()
        logits = None
        for i in range(P):                      # prefill via the decode path
            logits, caches = self._step(self.params, prompts[:, i:i + 1],
                                        caches, jnp.int32(i))
        prefill_s = time.time() - t0

        def sample(lg, k):
            if temperature <= 0:
                return jnp.argmax(lg[:, -1], -1)[:, None]
            return jax.random.categorical(k, lg[:, -1] / temperature)[:, None]

        t0 = time.time()
        tok = sample(logits, key)
        out = [tok]
        for i in range(P, P + gen_len - 1):
            logits, caches = self._step(self.params, tok, caches, jnp.int32(i))
            key = jax.random.fold_in(key, i)
            tok = sample(logits, key)
            out.append(tok)
        decode_s = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
        return GenerationResult(
            tokens=gen.tolist(), prefill_s=prefill_s, decode_s=decode_s,
            tokens_per_s=B * gen.shape[1] / max(decode_s, 1e-9))
