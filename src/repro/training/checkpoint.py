"""Sharded, versioned checkpointing with restart + elastic resharding.

Checkpoints are first-class COULER artifacts: saving registers them in the
artifact cache (so restart-from-failure skips re-training completed stages),
and the on-disk layout is one ``.npy`` blob per pytree leaf plus a JSON
manifest — trivially shardable (each host writes its leaf partitions) and
reshardable (load onto a *different* mesh: values are stored unsharded per
leaf, re-laid-out at restore via the current sharding rules — the elastic
scaling path).

``async_save`` overlaps serialization with the next train step (a real
background thread) — the compute/IO overlap trick used at scale.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path, tree_unflatten


def _path_str(path) -> str:
    out = []
    for e in path:
        if hasattr(e, "key"):
            out.append(str(e.key))
        elif hasattr(e, "idx"):
            out.append(str(e.idx))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3,
                 cache=None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.cache = cache
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any]) -> Path:
        d = self.root / f"step_{step:08d}"
        if d.exists():                         # idempotent (async + sync race)
            return d
        tmp = self.root / f".tmp_step_{step:08d}"
        tmp.mkdir(parents=True, exist_ok=True)
        leaves, treedef = tree_flatten_with_path(state)
        manifest: Dict[str, Any] = {"step": step, "leaves": [],
                                    "time": time.time()}
        for path, leaf in leaves:
            name = _path_str(path).replace("/", "__")
            arr = np.asarray(jax.device_get(leaf))
            np.save(tmp / f"{name}.npy", arr)
            manifest["leaves"].append(
                {"path": _path_str(path), "file": f"{name}.npy",
                 "shape": list(arr.shape), "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        tmp.rename(d)                              # atomic publish
        self._gc()
        if self.cache is not None:
            self.cache.offer(f"ckpt:{self.root.name}:{step}", str(d),
                             compute_time_s=1.0, producer=f"ckpt-{step}",
                             nbytes=sum(f.stat().st_size
                                        for f in d.glob("*.npy")))
        return d

    def async_save(self, step: int, state: Dict[str, Any]) -> threading.Thread:
        """Snapshot to host (blocking device_get) then write in background."""
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self.wait()
        t = threading.Thread(target=self.save, args=(step, host_state),
                             daemon=True)
        t.start()
        self._pending = t
        return t

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.root.glob("step_*"))
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[Any] = None,
                like: Optional[Any] = None) -> Dict[str, Any]:
        """Load a checkpoint; optionally re-shard onto the current mesh
        (``shardings`` is a matching pytree of NamedSharding — elastic
        scaling across different mesh shapes)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_path = {m["path"]: m for m in manifest["leaves"]}

        if like is None:
            # reconstruct a flat dict tree
            out: Dict[str, Any] = {}
            for m in manifest["leaves"]:
                out[m["path"]] = np.load(d / m["file"])
            return out
        leaves, treedef = tree_flatten_with_path(like)
        vals: List[Any] = []
        sh_leaves = (jax.tree.leaves(shardings,
                                     is_leaf=lambda x: hasattr(x, "spec"))
                     if shardings is not None else [None] * len(leaves))
        for (path, leaf), sh in zip(leaves, sh_leaves):
            m = by_path[_path_str(path)]
            arr = np.load(d / m["file"])
            if sh is not None:
                vals.append(jax.device_put(arr, sh))
            else:
                vals.append(arr)
        return tree_unflatten(treedef, vals)

    def _gc(self) -> None:
        steps = sorted(self.root.glob("step_*"))
        for p in steps[: max(0, len(steps) - self.keep)]:
            for f in p.glob("*"):
                f.unlink()
            p.rmdir()


class StepCheckpointSession:
    """The ``ckpt=`` handle a checkpoint-wired workflow step receives
    (``couler.add_job(..., checkpoint=dir)`` — see ``repro.core.faults``).

    Thin veneer over a ``CheckpointManager`` shared across the step's
    retry attempts: the fn probes ``latest_step()`` on entry, restores
    and continues if a prior (killed) attempt left progress, and calls
    ``save(step, state)`` as it goes. ``tick``/``save`` are also the
    runtime's mid-step interruption points — chaos worker-loss kills are
    delivered there, BEFORE the state persists, so a kill at iteration k
    resumes from k-1's checkpoint.
    """

    def __init__(self, manager: CheckpointManager,
                 on_tick: Optional[Callable[[int], None]] = None):
        self.manager = manager
        self._on_tick = on_tick
        self.resumed_from: Optional[int] = None

    def latest_step(self) -> Optional[int]:
        return self.manager.latest_step()

    def restore(self, step: Optional[int] = None, **kw) -> Dict[str, Any]:
        out = self.manager.restore(step=step, **kw)
        self.resumed_from = (step if step is not None
                             else self.manager.latest_step())
        return out

    def tick(self, iteration: int) -> None:
        """Announce an iteration boundary (an interruption point)."""
        if self._on_tick is not None:
            self._on_tick(iteration)

    def save(self, step: int, state: Dict[str, Any]) -> Path:
        self.tick(step)
        return self.manager.save(step, state)
