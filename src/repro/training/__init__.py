from repro.training.train import (init_train_state, make_eval_step,
                                  make_loss_fn, make_train_step)
