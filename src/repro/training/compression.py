"""Gradient compression: int8 quantized all-reduce with error feedback.

Wire format: per-leaf max-abs scale (fp32 scalar, psum-MAX'd) + int8 payload.
The reduction is chunked ring-style under ``shard_map``:

    all_to_all(int8 chunks) -> local int32 sum -> requantize -> all_gather

moving ~2x int8 bytes per device instead of 2x fp32 — a ~4x wire reduction
vs fp32 all-reduce (~2x vs bf16), at <1e-2 relative error with error
feedback absorbing the quantization residual across steps.

Integrated into ``make_dp_train_step`` for pure-DP meshes (the ``model``
axis must be trivial — with tensor parallelism the gradient psum is fused
into the backward pass by SPMD and cannot be intercepted at this layer; the
TP-side reduction-precision lever lives in the model code instead, see
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from repro.sharding.compat import axis_size, shard_map
from jax.sharding import PartitionSpec as P


def _quantize(g: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(g / scale * 127.0), -127, 127)
    return q.astype(jnp.int8)


def _dequantize(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    return q.astype(jnp.float32) * scale / 127.0 / n


def compressed_psum_mean(g: jax.Array, axis: str) -> jax.Array:
    """int8 ring all-reduce-mean over ``axis`` (call inside shard_map)."""
    n = axis_size(axis)
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    scale = jax.lax.pmax(jnp.max(jnp.abs(flat)) + 1e-12, axis)

    chunks = flat.reshape(n, -1)
    q = _quantize(chunks, scale)                       # (n, c) int8
    # reduce-scatter: every device receives peers' copy of ITS chunk
    mine = jax.lax.all_to_all(q[:, None, :], axis, split_axis=0,
                              concat_axis=1, tiled=False)
    # mine: (1, n, c) int8 -> int32 sum
    local_sum = jnp.sum(mine.astype(jnp.int32), axis=(0, 1))   # (c,)
    # requantize the partial sums and all-gather
    q_sum = jnp.clip(local_sum, -32767, 32767).astype(jnp.int16)
    full = jax.lax.all_gather(q_sum, axis, axis=0, tiled=False)  # (n, c)
    out = _dequantize(full.reshape(-1), scale, n)
    if pad:
        out = out[:-pad]
    return out.reshape(g.shape)


def compressed_tree_psum_mean(grads, axis: str, err=None):
    """Per-leaf compressed mean-reduce with error feedback state."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        red = compressed_psum_mean(g, axis)
        # residual between what we contributed and what quantization kept
        kept = compressed_psum_mean(jnp.zeros_like(g), axis) * 0 + red
        new_e = g - red                      # local error feedback
        return red, new_e
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def make_compressed_grad_fn(loss_fn, mesh, data_axes=("data",)):
    """Returns grads_fn(params, err, batch) -> (loss, grads, new_err) with the
    data-parallel reduction done via the int8 path under shard_map.

    Requires the model to be pure-DP (no TP constraints inside) — used by
    the compression benchmark/tests and pure-DP training configs."""
    axis = data_axes[0]

    def local_grads(params, err, batch):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        red, new_err = compressed_tree_psum_mean(g, axis, err)
        loss = jax.lax.pmean(loss, axis)
        return loss, red, new_err

    pspec = jax.tree.map(lambda _: P(), jax.tree.structure(None))  # unused

    def wrapped(params, err, batch):
        rep = lambda t: jax.tree.map(lambda _: P(), t)
        bspec = jax.tree.map(lambda _: P(axis), batch)
        return shard_map(local_grads, mesh=mesh,
                         in_specs=(rep(params), rep(err), bspec),
                         out_specs=(P(), rep(params), rep(err)),
                         check_vma=False)(params, err, batch)
    return wrapped
