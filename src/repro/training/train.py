"""Train-step factory: loss, grads, microbatch accumulation, clipping, update.

``make_train_step(cfg, tcfg)`` returns a pure ``(state, batch) -> (state,
metrics)`` suitable for ``jax.jit`` + pjit sharding. Cross-entropy is
computed against vocab-sharded fp32 logits without materializing a one-hot
(iota comparison), so the 129k-vocab 671B cell stays within HBM.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.training import optimizer as O


def cross_entropy(logits, targets) -> jax.Array:
    """logits: (B,S,V) fp32 (vocab-sharded ok); targets: (B,S) int32."""
    V = logits.shape[-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    picked = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0), axis=-1)
    return jnp.mean(lse - picked)


def make_loss_fn(cfg, tcfg):
    def loss_fn(params, batch):
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["frames"] = batch["frames"]
        if cfg.family == "vlm":
            kwargs["patches"] = batch["patches"]
        logits, aux = T.apply_lm(params, cfg, batch["tokens"],
                                 remat=tcfg.remat, **kwargs)
        if cfg.family == "vlm":                   # text positions only
            logits = logits[:, cfg.num_patches:, :]
        loss = cross_entropy(logits, batch["targets"])
        loss = loss + aux["moe_aux"]
        if "mtp_logits" in aux:
            loss = loss + 0.3 * cross_entropy(aux["mtp_logits"],
                                              jnp.roll(batch["targets"], -1, axis=1))
        return loss, {"ce": loss}
    return loss_fn


def init_train_state(cfg, tcfg, key) -> Dict[str, Any]:
    params = T.init_lm(key, cfg)
    return {"params": params,
            "opt": O.opt_init(tcfg.optimizer)(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg, tcfg):
    loss_fn = make_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    update = O.opt_update(tcfg.optimizer)

    def compute_grads(params, batch):
        if tcfg.accum_steps <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, grads
        n = tcfg.accum_steps
        micro = jax.tree.map(
            lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)

        def body(carry, mb):
            acc, lsum = carry
            (loss, _), g = grad_fn(params, mb)
            acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
            return (acc, lsum + loss), None
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), _ = jax.lax.scan(body, (zero, 0.0), micro)
        grads = jax.tree.map(lambda g: g / n, gsum)
        return lsum / n, grads

    def train_step(state, batch):
        loss, grads = compute_grads(state["params"], batch)
        grads, gnorm = O.clip_by_global_norm(grads, tcfg.grad_clip)
        new_params, new_opt = update(
            grads, state["opt"], state["params"],
            lr=tcfg.learning_rate,
            weight_decay=tcfg.weight_decay)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_eval_step(cfg, tcfg):
    loss_fn = make_loss_fn(cfg, tcfg)

    def eval_step(params, batch):
        loss, _ = loss_fn(params, batch)
        return {"loss": loss}
    return eval_step
