"""Optimizers: AdamW (fp32 moments) and Adafactor (factored second moment).

Hand-rolled (no optax in this container). Adafactor is the assigned
optimizer for deepseek-v3-671b: full Adam fp32 moments for 671B params are
~5.4 TB — 21 GB/chip at 256 chips — exceeding v5e HBM, while Adafactor's
factored statistics are ~O(rows+cols) (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    # scale in the gradient's own dtype: avoids materializing a full fp32
    # copy of the gradient tree (10+ GB/device for the 671B config)
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr, beta1=0.9, beta2=0.95,
                 eps=1e-8, weight_decay=0.1):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** c
    bc2 = 1.0 - beta2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = beta1 * m + (1 - beta1) * g
        v = beta2 * v + (1 - beta2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "count": count}


# ---------------------------------------------------------------------------
# Adafactor (no momentum, factored v; Shazeer & Stern 2018, simplified)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adafactor_init(params) -> Dict[str, Any]:
    def vr(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                else jnp.zeros((1,), jnp.float32))

    def vc(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p) else jnp.zeros((1,), jnp.float32))

    def v(p):
        return (jnp.zeros((1,), jnp.float32) if _factored(p)
                else jnp.zeros(p.shape, jnp.float32))
    return {"vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "v": jax.tree.map(v, params),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, *, lr, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0, beta2_cap=0.999):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    beta2 = jnp.minimum(beta2_cap, 1.0 - c ** -0.8)

    def upd(g, vr, vc, v, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p):
            vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            vhat = (vr / denom)[..., None] * vc[..., None, :]
            u = g * jax.lax.rsqrt(vhat + eps)
        else:
            v = beta2 * v + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(v + eps)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return new_p, vr, vc, v

    flat_g, tdef = jax.tree.flatten(grads)
    zipped = zip(flat_g, jax.tree.leaves(state["vr"]),
                 jax.tree.leaves(state["vc"]), jax.tree.leaves(state["v"]),
                 jax.tree.leaves(params))
    out = [upd(*t) for t in zipped]
    return (tdef.unflatten([o[0] for o in out]),
            {"vr": tdef.unflatten([o[1] for o in out]),
             "vc": tdef.unflatten([o[2] for o in out]),
             "v": tdef.unflatten([o[3] for o in out]),
             "count": count})


def opt_init(name: str):
    return {"adamw": adamw_init, "adafactor": adafactor_init}[name]


def opt_update(name: str):
    return {"adamw": adamw_update, "adafactor": adafactor_update}[name]
