"""ShapeDtypeStruct input stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the abstract batch for a (arch x shape)
cell; modality frontends are STUBS per the task spec — whisper gets
precomputed frame embeddings, paligemma gets precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    S = shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": sds((B, S), jnp.int32)}
        if shape.kind == "train":
            batch["targets"] = sds((B, S), jnp.int32)
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patches"] = sds((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against caches of length S
    return {"token": sds((B, 1), jnp.int32)}


def cache_specs_shapes(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract cache pytree for decode cells (eval_shape: no allocation)."""
    return jax.eval_shape(
        lambda: T.init_caches(cfg, shape.global_batch, shape.seq_len,
                              jnp.bfloat16))
