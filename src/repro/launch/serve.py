"""Serving driver: batched generation through the ServingEngine.

    python -m repro.launch.serve --arch mamba2-370m --batch 4 --gen-len 32
"""
import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    import jax
    from repro.configs import get_arch, reduced
    from repro.models import transformer as T
    from repro.serving.engine import ServingEngine

    cfg = reduced(get_arch(args.arch).model).replace(
        param_dtype="float32", compute_dtype="float32")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params,
                        max_len=args.prompt_len + args.gen_len + 1)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)
    res = eng.generate(prompts, args.gen_len, temperature=args.temperature)
    print(f"arch={args.arch} prefill={res.prefill_s:.2f}s "
          f"decode={res.decode_s:.2f}s ({res.tokens_per_s:.1f} tok/s)")
    print("first request tokens:", res.tokens[0][:16])


if __name__ == "__main__":
    main()
