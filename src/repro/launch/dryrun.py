import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real ``train_step`` / ``prefill_step`` /
``serve_step`` on the production mesh with explicit in/out shardings,
compiles it (AOT, no allocation), prints ``memory_analysis()`` /
``cost_analysis()`` and writes the roofline terms parsed from the SPMD HLO
(see ``repro.roofline.analysis``) to ``out/dryrun/<mesh>/<arch>/<shape>.json``.

Usage:
    python -m repro.launch.dryrun --arch stablelm-1.6b --shape train_4k
    python -m repro.launch.dryrun --all            # every applicable cell
    python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, get_shape
from repro.configs.base import LM_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cache_specs_shapes, input_specs
from repro.models import transformer as T
from repro.roofline.analysis import analyze_hlo, roofline_report
from repro.sharding.ctx import use_mesh
from repro.sharding.rules import (batch_specs, cache_specs, opt_state_specs,
                                  param_specs, rules_for, to_named)
from repro.training import train as TR

OUT_DIR = Path(os.environ.get("DRYRUN_OUT", "out/dryrun"))


def _metrics_sharding(tree, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               strategy: str = "baseline", remat: str = None,
               verbose: bool = True):
    """Returns (compiled, lowered, meta) for one cell."""
    spec = get_arch(arch_id)
    cfg, tcfg = spec.model, spec.train
    if remat is not None:
        import dataclasses
        tcfg = dataclasses.replace(tcfg, remat=remat)
    shape = get_shape(shape_name)
    if shape_name in spec.skips:
        raise SystemExit(f"SKIP {arch_id} x {shape_name}: {spec.skips[shape_name]}")

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(arch_id, strategy)
    t0 = time.time()

    with use_mesh(mesh, rules, strategy):
        batch_sds = input_specs(cfg, shape)
        batch_sh = to_named(batch_specs(batch_sds, mesh, rules), mesh)

        if shape.kind == "train":
            key = jax.random.PRNGKey(0)
            state_sds = jax.eval_shape(
                lambda: TR.init_train_state(cfg, tcfg, key))
            state_sh = {
                "params": to_named(param_specs(state_sds["params"], mesh, rules, cfg, strategy), mesh),
                "opt": to_named(opt_state_specs(state_sds["opt"], mesh, rules, cfg, strategy), mesh),
                "step": _metrics_sharding(state_sds["step"], mesh),
            }
            step_fn = TR.make_train_step(cfg, tcfg)
            metrics_sds = jax.eval_shape(step_fn, state_sds, batch_sds)[1]
            jfn = jax.jit(step_fn,
                          in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh,
                                         _metrics_sharding(metrics_sds, mesh)),
                          donate_argnums=(0,))
            lowered = jfn.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            params_sds = jax.eval_shape(
                lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
            params_sh = to_named(param_specs(params_sds, mesh, rules, cfg, strategy), mesh)

            def prefill_step(params, batch):
                kwargs = {}
                if cfg.family == "encdec":
                    kwargs["frames"] = batch["frames"]
                if cfg.family == "vlm":
                    kwargs["patches"] = batch["patches"]
                logits, _ = T.apply_lm(params, cfg, batch["tokens"],
                                       remat=tcfg.remat, **kwargs)
                return logits[:, -1, :]
            jfn = jax.jit(prefill_step, in_shardings=(params_sh, batch_sh))
            lowered = jfn.lower(params_sds, batch_sds)
        else:  # decode
            params_sds = jax.eval_shape(
                lambda: T.init_lm(jax.random.PRNGKey(0), cfg))
            params_sh = to_named(param_specs(params_sds, mesh, rules, cfg, strategy), mesh)
            caches_sds = cache_specs_shapes(cfg, shape)
            caches_sh = to_named(cache_specs(caches_sds, mesh, rules), mesh)
            from jax.sharding import NamedSharding, PartitionSpec as P
            idx_sds = jax.ShapeDtypeStruct((), jnp.int32)

            def serve_step(params, caches, token, index):
                return T.apply_lm_decode(params, cfg, token, caches, index)
            jfn = jax.jit(serve_step,
                          in_shardings=(params_sh, caches_sh, batch_sh["token"],
                                        NamedSharding(mesh, P())),
                          donate_argnums=(1,))
            lowered = jfn.lower(params_sds, caches_sds,
                                batch_sds["token"], idx_sds)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    meta = {"arch": arch_id, "shape": shape_name, "strategy": strategy,
            "multi_pod": multi_pod, "chips": mesh.size,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)}
    return compiled, lowered, meta, cfg, shape, mesh


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             strategy: str = "baseline", remat: str = None, tag: str = None,
             out_dir: Path = OUT_DIR, verbose: bool = True) -> dict:
    compiled, lowered, meta, cfg, shape, mesh = lower_cell(
        arch_id, shape_name, multi_pod=multi_pod, strategy=strategy,
        remat=remat)

    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
        mem["total_per_device_bytes"] = (mem["argument_bytes"]
                                         + mem["output_bytes"]
                                         + mem["temp_bytes"]
                                         - mem["alias_bytes"])
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    terms = analyze_hlo(hlo)
    report = roofline_report(terms, cfg, shape, mesh.size)

    rec = dict(meta)
    rec["memory_analysis"] = mem
    rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                            if isinstance(v, (int, float))
                            and k in ("flops", "bytes accessed",
                                      "transcendentals")}
    rec["roofline"] = report
    rec["hlo_instruction_count"] = hlo.count("\n")
    rec["status"] = "ok"

    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    label = tag or strategy
    fname = (f"{shape_name}.json" if label == "baseline"
             else f"{shape_name}.{label}.json")
    path = out_dir / mesh_tag / arch_id / fname
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1))
    if verbose:
        print(f"[{mesh_tag}] {arch_id} x {shape_name}: "
              f"compile={meta['compile_s']}s "
              f"mem/dev={mem.get('total_per_device_bytes', 0)/2**30:.2f}GiB "
              f"dom={report['dominant']} "
              f"terms(c/m/x)=({report['compute_s']:.4f},"
              f"{report['memory_s']:.4f},{report['collective_s']:.4f})s "
              f"useful={report['useful_flops_ratio']:.2f}")
    return rec


def all_cells(multi_pod: bool):
    for arch_id in ARCH_IDS:
        spec = get_arch(arch_id)
        for shape in LM_SHAPES:
            if shape.name in spec.skips:
                yield arch_id, shape.name, "skip", spec.skips[shape.name]
            else:
                yield arch_id, shape.name, "run", None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="baseline",
                    choices=["baseline", "dp_zero1", "pure_fsdp", "moe_a2a", "moe_rs"])
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "dots", "full"])
    ap.add_argument("--tag", default=None, help="suffix for the output json")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subprocess-per-cell", action="store_true",
                    help="isolate each cell's compile in a fresh process")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        failures = []
        for arch_id, shape_name, status, reason in all_cells(args.multi_pod):
            mesh_tag = "pod2x16x16" if args.multi_pod else "pod16x16"
            path = out_dir / mesh_tag / arch_id / f"{shape_name}.json"
            if status == "skip":
                path.parent.mkdir(parents=True, exist_ok=True)
                path.write_text(json.dumps(
                    {"arch": arch_id, "shape": shape_name, "status": "skip",
                     "reason": reason}, indent=1))
                print(f"[{mesh_tag}] {arch_id} x {shape_name}: SKIP ({reason})")
                continue
            if path.exists() and json.loads(path.read_text()).get("status") == "ok":
                print(f"[{mesh_tag}] {arch_id} x {shape_name}: cached")
                continue
            if args.subprocess_per_cell:
                import subprocess
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch_id, "--shape", shape_name,
                       "--out", str(out_dir)]
                if args.multi_pod:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd, timeout=7200)
                if r.returncode != 0:
                    failures.append((arch_id, shape_name))
            else:
                try:
                    run_cell(arch_id, shape_name, multi_pod=args.multi_pod,
                             out_dir=out_dir)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch_id, shape_name))
                    path.parent.mkdir(parents=True, exist_ok=True)
                    path.write_text(json.dumps(
                        {"arch": arch_id, "shape": shape_name,
                         "status": "error", "error": f"{type(e).__name__}: {e}",
                         "traceback": traceback.format_exc()[-4000:]}, indent=1))
                    print(f"FAIL {arch_id} x {shape_name}: {type(e).__name__}: {e}")
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        print("ALL CELLS OK")
        return

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   strategy=args.strategy, remat=args.remat, tag=args.tag,
                   out_dir=out_dir)
    print(json.dumps({k: rec[k] for k in ("memory_analysis", "roofline")},
                     indent=1))


if __name__ == "__main__":
    main()
