"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device.

Single pod : (data=16, model=16)            = 256 chips (v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def _mesh(shape, axes) -> Mesh:
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before jax init")
    return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (used by tests with small fake-device counts)."""
    return _mesh(tuple(shape), tuple(axes))


# v5e hardware constants (per task spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
