"""Distributed training driver.

On a real TPU slice this is the entry point per host:

    python -m repro.launch.train --arch stablelm-1.6b --steps 1000 \
        --strategy dp_zero1 --ckpt-dir gs://.../ckpt

On this CPU container, pass ``--fake-devices N`` to run a REAL sharded
training loop on N host devices (small mesh, reduced config) — the same
code path: mesh -> sharding rules -> device_put -> jitted train_step ->
async checkpoints -> restart-from-latest.
"""
import argparse
import os
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--strategy", default="baseline",
                    choices=["baseline", "dp_zero1", "pure_fsdp",
                             "moe_a2a", "moe_rs"])
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--mesh", default="2x4",
                    help="data x model (e.g. 2x4); 16x16 on a v5e pod")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="out/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.fake_devices}").strip()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch, reduced
    from repro.data.pipeline import synthetic_batches
    from repro.launch.mesh import make_mesh
    from repro.sharding.ctx import use_mesh
    from repro.sharding.rules import (batch_specs, opt_state_specs,
                                      param_specs, rules_for, to_named)
    from repro.training import train as TR
    from repro.training.checkpoint import CheckpointManager
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = get_arch(args.arch)
    cfg = spec.model
    tcfg = spec.train
    if args.reduced:
        cfg = reduced(cfg).replace(param_dtype="float32",
                                   compute_dtype="float32")
        tcfg = tcfg.__class__(optimizer=tcfg.optimizer, learning_rate=1e-3,
                              remat="none")
    dims = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dims, ("data", "model")[: len(dims)])
    rules = rules_for(args.arch, args.strategy)
    mgr = CheckpointManager(args.ckpt_dir)

    with use_mesh(mesh, rules, args.strategy):
        state = TR.init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        state_sh = {
            "params": to_named(param_specs(state["params"], mesh, rules, cfg,
                                           args.strategy), mesh),
            "opt": to_named(opt_state_specs(state["opt"], mesh, rules, cfg,
                                            args.strategy), mesh),
            "step": NamedSharding(mesh, P()),
        }
        start = mgr.latest_step()
        if start is not None:
            print(f"resuming from checkpoint step {start}")
            state = mgr.restore(like=jax.tree.map(
                lambda x: __import__("numpy").asarray(x), state))
        state = jax.device_put(state, state_sh)
        # pin out_shardings to the input specs: without it GSPMD may hand
        # the state back re-sharded (e.g. norm scales gathered onto
        # 'model'), and the next step_fn call rejects the committed arrays
        step_fn = jax.jit(TR.make_train_step(cfg, tcfg),
                          in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,))

        it = synthetic_batches(args.batch, args.seq, cfg.vocab_size,
                               n=args.steps + 1)
        for batch in it:
            if int(state["step"]) >= args.steps:
                break
            b = {k: jnp.asarray(v) for k, v in batch.items()}
            bsh = to_named(batch_specs(b, mesh, rules), mesh)
            b = jax.device_put(b, bsh)
            state, m = step_fn(state, b)
            s = int(state["step"])
            if s % args.log_every == 0:
                print(f"step {s:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f}")
            if s % args.ckpt_every == 0:
                mgr.async_save(s, state)
        mgr.wait()
        mgr.save(int(state["step"]), state)
        print(f"done at step {int(state['step'])}; "
              f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
