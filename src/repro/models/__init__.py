from repro.models.transformer import (apply_lm, apply_lm_decode, init_caches,
                                      init_lm)
